"""FlowTracer-at-scale (beyond paper Section IV-B): the paper scales by
adding processes/threads around per-flow SSH queries; our answer is the
vectorized engine — the full flow table walked through the *general*
compiled fabric in whole-array passes (core/vector_sim), with the
flowhash Pallas kernel as the optional TPU hash backend.

Two axes: flow count (single seed, big tables) and seed count (fixed
table, Monte-Carlo sweeps, per-flow CRC pass amortized away)."""

from __future__ import annotations

import numpy as np

from repro.core import (
    FIELDS_5TUPLE, build_paper_testbed, compile_fabric, flow_fields_matrix,
    simulate_paths, fim_vector,
)
from .common import emit, paper_setup, timeit


def _workload(total_flows: int):
    # the canonical 2-rack workload has 16 directed PairSpecs
    _, _, flows = paper_setup(flows_per_pair=total_flows // 16)
    return flows


def run() -> None:
    comp = compile_fabric(build_paper_testbed())

    # axis 1: flow count at one seed
    for n in (10_000, 100_000, 1_000_000):
        flows = _workload(n)
        fields = flow_fields_matrix(flows, FIELDS_5TUPLE)

        def job():
            res = simulate_paths(comp, flows, [7], field_matrix=fields)
            return fim_vector(res)

        t = timeit(job, repeats=3)
        f = float(job()[0])
        emit(f"bulk_scale_{n}_flows", t * 1e6,
             f"fim={f:.2f}% flows_per_sec={n / t:.3g}")

    # axis 2: seed count at the paper's 256-flow table
    flows = _workload(256)
    fields = flow_fields_matrix(flows, FIELDS_5TUPLE)
    for s in (64, 1024, 8192):
        seeds = np.arange(s)

        def sweep():
            res = simulate_paths(comp, flows, seeds, field_matrix=fields)
            return fim_vector(res)

        t = timeit(sweep, repeats=3)
        fims = sweep()
        emit(f"bulk_scale_{s}_seeds", t * 1e6,
             f"fim_mean={fims.mean():.2f}% seeds_per_sec={s / t:.3g}")
