"""FlowTracer-at-scale (beyond paper Section IV-B): the paper scales by
adding processes/threads around per-flow SSH queries; our TPU-native
answer is the flowhash kernel — the full flow table hashed in one
vectorized pass.  1M flows x 4 ECMP stages + FIM in milliseconds."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.kernels.flowhash.ops import link_loads_fim, simulate_paper_paths
from .common import emit, timeit


def run() -> None:
    rng = np.random.default_rng(1)
    for n in (10_000, 100_000, 1_000_000):
        fields = jnp.asarray(rng.integers(0, 2**31, (n, 5)), jnp.uint32)

        def job():
            ch = simulate_paper_paths(fields)
            ch["uplink"].block_until_ready()
            return ch

        t = timeit(job, repeats=3)
        ch = job()
        _, f = link_loads_fim(ch["uplink"], 16)
        emit(f"bulk_scale_{n}_flows", t * 1e6,
             f"fim_uplinks={f:.2f}% flows_per_sec={n / t:.3g}")
