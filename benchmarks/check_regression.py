"""CI benchmark-regression guard.

Diffs a freshly written ``BENCH_results.json`` against the last
committed entry and fails (exit 1) when any benchmark row slowed by more
than ``--threshold`` (default 2.5x).  Rows are matched by *bench and
shape*: the row name plus the ``BENCH_SEEDS`` override and the
``seeds=`` / ``flows=`` metrics the row itself reports — a tiny-shape
smoke row is never compared against a full-shape baseline row.  Rows
with no timing on either side (``us_per_call <= 0``, the derived-only
rows) are ignored, and a small absolute slack keeps microsecond-scale
rows from tripping the ratio on scheduler noise.

Baseline rows that no longer match anything in the new results
(renamed benches, drifted shapes) are listed as ``ORPHANED`` instead of
being silently skipped, so a partially stale baseline is visible long
before the all-rows-stale hard failure.

Noisy runners can opt out by setting ``BENCH_REGRESSION_SKIP=1``.

    python -m benchmarks.check_regression \
        --old benchmarks/BENCH_baseline_smoke.json --new BENCH_results.json
"""

from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_THRESHOLD = 2.5
# a row must slow by this many absolute microseconds *and* by the ratio
# before it counts — sub-50us rows are timer noise at smoke shapes
DEFAULT_ABS_SLACK_US = 50.0

SKIP_ENV = "BENCH_REGRESSION_SKIP"


def shape_key(payload: dict, row: dict) -> tuple:
    """Identity of a benchmark measurement: bench row + run shape.

    The ``BENCH_SEEDS`` override is read from the row itself when
    present (``benchmarks/run.py`` stamps it per row, because a subset
    run carries other benches' rows over from an earlier run that may
    have used a different override) and falls back to the payload-level
    field for pre-stamp history files.  The ``engine`` tag (numpy/jax
    compute backend, absent on host-only rows) is part of the identity:
    a numpy baseline must never absorb a jax timing of the same name and
    shape, or a backend swap would read as a 10x "regression"."""
    metrics = row.get("metrics", {})
    return (
        row.get("name"),
        row.get("bench_seeds_override",
                payload.get("bench_seeds_override")),
        metrics.get("seeds"),
        metrics.get("flows"),
        row.get("engine"),
    )


def timed_rows(payload: dict) -> dict[tuple, float]:
    """shape_key -> us_per_call for every row that actually carries a
    timing (derived-only rows emit 0.0 and are not comparable)."""
    out = {}
    for row in payload.get("rows", []):
        us = float(row.get("us_per_call", 0.0))
        if us > 0.0:
            out[shape_key(payload, row)] = us
    return out


def describe_key(key: tuple) -> str:
    name, override, seeds, flows, engine = key
    tag = f" engine={engine}" if engine is not None else ""
    return f"{name} [BENCH_SEEDS={override} seeds={seeds} flows={flows}{tag}]"


def orphaned_rows(old_payload: dict, new_payload: dict) -> list[tuple]:
    """Baseline shape-keys with no counterpart in the new results.

    An orphan means the baseline row no longer guards anything — the
    bench was renamed, its shape changed, or it stopped running.  The
    guard silently skipping them is how a baseline rots until the
    0-comparable hard failure; surfacing the list makes a partial drift
    visible the day it happens.
    """
    old = timed_rows(old_payload)
    new = timed_rows(new_payload)
    return sorted((key for key in old if key not in new), key=str)


def compare(
    old_payload: dict,
    new_payload: dict,
    *,
    threshold: float = DEFAULT_THRESHOLD,
    abs_slack_us: float = DEFAULT_ABS_SLACK_US,
) -> tuple[list[str], int]:
    """(regression messages, number of rows compared)."""
    old = timed_rows(old_payload)
    new = timed_rows(new_payload)
    regressions = []
    compared = 0
    for key, new_us in sorted(new.items(), key=str):
        old_us = old.get(key)
        if old_us is None:
            continue                      # new bench or different shape
        compared += 1
        if new_us > threshold * old_us and new_us - old_us > abs_slack_us:
            regressions.append(
                f"{describe_key(key)}: {old_us:.1f}us -> {new_us:.1f}us "
                f"({new_us / old_us:.2f}x, threshold {threshold}x)")
    return regressions, compared


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--old", required=True,
                        help="baseline BENCH_results.json (last committed)")
    parser.add_argument("--new", default="BENCH_results.json",
                        help="freshly produced BENCH_results.json")
    parser.add_argument("--threshold", type=float, default=DEFAULT_THRESHOLD)
    parser.add_argument("--abs-slack-us", type=float,
                        default=DEFAULT_ABS_SLACK_US)
    args = parser.parse_args(argv)

    if os.environ.get(SKIP_ENV):
        print(f"bench-regression guard skipped ({SKIP_ENV} set)")
        return 0
    with open(args.old) as f:
        old_payload = json.load(f)
    with open(args.new) as f:
        new_payload = json.load(f)
    regressions, compared = compare(
        old_payload, new_payload,
        threshold=args.threshold, abs_slack_us=args.abs_slack_us)
    orphans = orphaned_rows(old_payload, new_payload)
    if orphans:
        # advisory, not a failure (new benches legitimately widen the
        # matrix) — but never silent: these baseline rows guard nothing
        # anymore and should be refreshed away (recipe in ROADMAP.md)
        print(f"bench-regression guard: {len(orphans)} baseline row(s) have "
              "no counterpart in the new results (renamed bench or drifted "
              "shape) — refresh the baseline:")
        for key in orphans:
            print(f"  ORPHANED {describe_key(key)}")
    if regressions:
        print(f"bench-regression guard: {len(regressions)} regression(s) "
              f"over {compared} comparable row(s):")
        for line in regressions:
            print(f"  REGRESSION {line}")
        return 1
    if compared == 0 and timed_rows(old_payload) and timed_rows(new_payload):
        # both sides carry timings but nothing matched: the baseline is
        # stale (renamed rows, changed shapes) and the guard would
        # otherwise pass green forever — fail loudly instead
        print("bench-regression guard: 0 comparable rows between baseline "
              "and new results — baseline is stale or shapes drifted; "
              "refresh it (see ROADMAP) or set "
              f"{SKIP_ENV}=1 to bypass")
        return 1
    print(f"bench-regression guard: OK ({compared} comparable row(s), "
          f"threshold {args.threshold}x)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
