"""Shared helpers for the paper-figure benchmarks."""

from __future__ import annotations

import os
import time

from repro.core import (
    bipartite_pairs, build_paper_testbed, nic_ip, server_name, synthesize_flows,
)

# Every emitted row, for the machine-readable BENCH_results.json that
# benchmarks/run.py writes next to the CSV stream.
RESULTS: list[dict] = []


def bench_seeds(default: int) -> int:
    """Seed count for Monte-Carlo benchmarks; ``BENCH_SEEDS`` overrides it
    so CI can smoke the benchmark modules on tiny shapes."""
    return int(os.environ.get("BENCH_SEEDS", default))


def paper_setup(flows_per_pair: int = 16):
    fab = build_paper_testbed()
    rack0 = [server_name(i) for i in range(8)]
    rack1 = [server_name(8 + i) for i in range(8)]
    wl = bipartite_pairs(rack0, rack1, flows_per_pair=flows_per_pair)
    flows = synthesize_flows(wl, nic_ip=nic_ip, nics_per_server=2)
    return fab, wl, flows


def timeit(fn, *, repeats: int = 3) -> float:
    """Median wall seconds."""
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def _parse_derived(derived: str) -> dict[str, float]:
    """Pull ``k=v`` float metrics out of a derived string, best effort."""
    out = {}
    for tok in derived.split():
        if "=" in tok:
            k, _, v = tok.partition("=")
            try:
                out[k] = float(v.rstrip("x%"))
            except ValueError:
                pass
    return out


def emit(name: str, us_per_call: float, derived: str, *,
         engine: str | None = None) -> None:
    """Print one CSV row and record it for BENCH_results.json.

    ``engine`` tags rows whose timing depends on the compute backend
    ("numpy" / "jax"); it is part of the regression-guard identity, so a
    numpy baseline row is never compared against a jax measurement of
    the same name and shape.  Untagged rows (the host-only benches) stay
    backend-agnostic and keep matching historical baselines."""
    print(f"{name},{us_per_call:.1f},{derived}")
    row = {
        "name": name,
        "us_per_call": round(us_per_call, 1),
        "derived": derived,
        "metrics": _parse_derived(derived),
    }
    if engine is not None:
        row["engine"] = engine
    RESULTS.append(row)
