"""Shared helpers for the paper-figure benchmarks."""

from __future__ import annotations

import time

from repro.core import (
    bipartite_pairs, build_paper_testbed, nic_ip, server_name, synthesize_flows,
)


def paper_setup(flows_per_pair: int = 16):
    fab = build_paper_testbed()
    rack0 = [server_name(i) for i in range(8)]
    rack1 = [server_name(8 + i) for i in range(8)]
    wl = bipartite_pairs(rack0, rack1, flows_per_pair=flows_per_pair)
    flows = synthesize_flows(wl, nic_ip=nic_ip, nics_per_server=2)
    return fab, wl, flows


def timeit(fn, *, repeats: int = 3) -> float:
    """Median wall seconds."""
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
