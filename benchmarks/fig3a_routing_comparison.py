"""Paper Fig. 3(a): RoCE throughput distribution + FIM, ECMP vs static.

256 bipartite flows on the 2-rack testbed.  The paper measured
FIM = 36.5% (ECMP) vs 6.2% (static) and near-line-rate throughput for
static.  The paper 'repeated multiple times'; one vectorized
``simulate_paths`` pass (bit-identical to the hop-by-hop tracer) now
feeds BOTH the FIM distribution and the full per-pair max-min
throughput distribution over 256 hash seeds — the old code ran the
dict-based throughput model on just two representative seeds."""

from __future__ import annotations

import time

import numpy as np

from repro.core import (
    compile_fabric, fim, fim_from_counts, per_pair_throughput, simulate_paths,
    static_route_assignment, throughput_from_result,
)
from .common import bench_seeds, emit, paper_setup


def run() -> None:
    fab, wl, flows = paper_setup()
    comp = compile_fabric(fab)
    num_seeds = bench_seeds(256)
    seeds = np.arange(num_seeds)

    t0 = time.perf_counter()
    res = simulate_paths(comp, flows, seeds)
    ecmp_fims, _ = fim_from_counts(res.link_flow_counts(), comp)
    elapsed = time.perf_counter() - t0      # FIM sweep only: comparable
    t0 = time.perf_counter()                # with the PR-1 era row
    tp = throughput_from_result(res)
    tp_elapsed = time.perf_counter() - t0

    pair_min = tp.per_pair.min(axis=0)       # (S,) worst pair per seed
    pair_med = np.median(tp.per_pair, axis=0)

    _, static_paths = static_route_assignment(fab, flows)
    static_fim = fim(static_paths, fab)
    tp_s = sorted(per_pair_throughput(flows, static_paths).values())

    emit("fig3a_ecmp_fim_pct", elapsed / num_seeds * 1e6,
         f"mean={ecmp_fims.mean():.1f} "
         f"range=[{ecmp_fims.min():.1f},{ecmp_fims.max():.1f}] "
         f"p95={np.percentile(ecmp_fims, 95):.1f} paper=36.5")
    emit("fig3a_static_fim_pct", 0.0,
         f"value={static_fim:.2f} paper=6.2")
    emit("fig3a_ecmp_throughput_gbps", tp_elapsed / num_seeds * 1e6,
         f"min={pair_min.mean():.0f} med={pair_med.mean():.0f} "
         f"worst={tp.per_pair.min():.0f} line_rate=400 seeds={num_seeds}")
    emit("fig3a_static_throughput_gbps", 0.0,
         f"min={tp_s[0]:.0f} med={tp_s[len(tp_s)//2]:.0f} line_rate=400")
    emit("fig3a_imbalance_reduction_pct", 0.0,
         f"value={ecmp_fims.mean() - static_fim:.1f} paper=30.3")
