"""Paper Fig. 3(a), generalized: FIM + RoCE throughput per routing strategy.

The paper compares two configurations — ECMP hashing (FIM = 36.5%,
colliding flows far below line rate) and static routing (FIM = 6.2%,
near-line-rate) — on the 16-node 2-rack testbed with 256 bipartite
flows.  This benchmark turns that into a *strategy matrix*: every
registered vectorized routing strategy (baseline ECMP, PRIME-style
multi-part-entropy spraying, greedy congestion-aware selection) runs
from ONE shared fabric compile and one shared hash-field pass, and each
emits its FIM distribution and per-pair max-min throughput distribution
over the seed sweep (1024 seeds by default; ``BENCH_SEEDS`` overrides).
The static-routing rows are kept as the paper's deterministic anchor.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (
    FIELDS_5TUPLE, CongestionAware, EcmpStrategy, PrimeSpraying,
    compile_fabric, fim, fim_from_counts, flow_fields_matrix, max_min_rates,
    per_pair_throughput, simulate_paths, static_route_assignment,
    throughput_from_result,
)
from .common import bench_seeds, emit, paper_setup

# (row tag, strategy instance) — the matrix one run sweeps.  Paper
# anchors: ECMP FIM 36.5%, static 6.2%, line rate 400 Gb/s per pair.
STRATEGY_MATRIX = [
    ("ecmp", EcmpStrategy()),
    ("prime_spray", PrimeSpraying(flowlets=8)),
    ("congestion", CongestionAware()),
]


def run() -> None:
    fab, wl, flows = paper_setup()
    comp = compile_fabric(fab)              # ONE compile for every strategy
    num_seeds = bench_seeds(1024)
    seeds = np.arange(num_seeds)
    field_mat = flow_fields_matrix(flows, FIELDS_5TUPLE)  # one CRC pass

    num_pairs = len({(f.src, f.dst) for f in flows})
    pair_scale = len(flows) / num_pairs     # flow-mean -> per-pair Gb/s
    results = {}
    goodput = {}
    for tag, strategy in STRATEGY_MATRIX:
        t0 = time.perf_counter()
        res = simulate_paths(comp, flows, seeds, strategy=strategy,
                             field_matrix=field_mat)
        fims, _ = fim_from_counts(res.link_flow_counts(), comp)
        sim_elapsed = time.perf_counter() - t0
        t0 = time.perf_counter()
        fr = max_min_rates(res)
        tp = throughput_from_result(res, flowlet_rates=fr)
        tp_elapsed = time.perf_counter() - t0
        # the goodput pass is NOT inside the timed region (the
        # throughput row's us_per_call must keep measuring the fill
        # engine alone; goodput_exposure_model times the exposure pass)
        # and reuses the fill instead of running it a second time
        tpg = throughput_from_result(res, transport="roce-nack",
                                     flowlet_rates=fr)
        results[tag] = fims
        goodput[tag] = tpg

        pair_min = tp.per_pair.min(axis=0)   # (S,) worst pair per seed
        pair_med = np.median(tp.per_pair, axis=0)
        emit(f"fig3a_{tag}_fim_pct", sim_elapsed / num_seeds * 1e6,
             f"mean={fims.mean():.1f} "
             f"range=[{fims.min():.1f},{fims.max():.1f}] "
             f"p95={np.percentile(fims, 95):.1f} "
             f"flowlets={res.num_flowlets // res.num_flows}"
             + (" paper=36.5" if tag == "ecmp" else ""))
        emit(f"fig3a_{tag}_throughput_gbps", tp_elapsed / num_seeds * 1e6,
             f"mean={tp.rates.mean() * pair_scale:.0f} "
             f"min={pair_min.mean():.0f} med={pair_med.mean():.0f} "
             f"worst={tp.per_pair.min():.0f} line_rate=400 seeds={num_seeds}")
        emit(f"fig3a_{tag}_goodput_gbps", 0.0,
             f"mean={tpg.goodput.mean() * pair_scale:.0f} "
             f"eff={tpg.efficiency.mean():.2f} "
             f"exposure_p95={np.percentile(tpg.exposure, 95):.2f} "
             f"transport=roce-nack seeds={num_seeds}")

    _, static_paths = static_route_assignment(fab, flows)
    static_fim = fim(static_paths, fab)
    tp_s = sorted(per_pair_throughput(flows, static_paths).values())
    emit("fig3a_static_fim_pct", 0.0,
         f"value={static_fim:.2f} paper=6.2")
    emit("fig3a_static_throughput_gbps", 0.0,
         f"min={tp_s[0]:.0f} med={tp_s[len(tp_s)//2]:.0f} line_rate=400")
    emit("fig3a_imbalance_reduction_pct", 0.0,
         f"value={results['ecmp'].mean() - static_fim:.1f} paper=30.3")
    emit("fig3a_spray_vs_ecmp_fim_delta_pct", 0.0,
         f"value={results['ecmp'].mean() - results['prime_spray'].mean():.1f} "
         f"ecmp={results['ecmp'].mean():.1f} "
         f"spray={results['prime_spray'].mean():.1f}")
    # the other side of the spray trade: under a reordering-intolerant
    # transport the FIM win above costs goodput (paper Section V)
    g_ecmp = goodput["ecmp"].goodput.mean()
    g_spray = goodput["prime_spray"].goodput.mean()
    emit("fig3a_spray_goodput_penalty_pct", 0.0,
         f"value={(1.0 - g_spray / g_ecmp) * 100.0:.1f} "
         f"ecmp={g_ecmp * pair_scale:.0f} "
         f"spray={g_spray * pair_scale:.0f} transport=roce-nack")
