"""Paper Fig. 3(a): RoCE throughput distribution + FIM, ECMP vs static.

256 bipartite flows on the 2-rack testbed.  The paper measured
FIM = 36.5% (ECMP) vs 6.2% (static) and near-line-rate throughput for
static.  We sweep hash seeds (the paper's 'repeated multiple times') and
report the distribution.
"""

from __future__ import annotations

import statistics
import time

import numpy as np

from repro.core import (
    EcmpRouting, FlowTracer, fim, per_pair_throughput, static_route_assignment,
)
from .common import emit, paper_setup


def run() -> None:
    fab, wl, flows = paper_setup()
    ecmp_fims, tp_mins, tp_meds = [], [], []
    t0 = time.perf_counter()
    for seed in range(8):
        res = FlowTracer(fab, EcmpRouting(fab, seed=seed), wl, flows,
                         num_threads=8).trace()
        ecmp_fims.append(fim(res.paths, fab))
        tp = sorted(per_pair_throughput(flows, res.paths).values())
        tp_mins.append(tp[0])
        tp_meds.append(tp[len(tp) // 2])
    elapsed = time.perf_counter() - t0

    _, static_paths = static_route_assignment(fab, flows)
    static_fim = fim(static_paths, fab)
    tp_s = sorted(per_pair_throughput(flows, static_paths).values())

    emit("fig3a_ecmp_fim_pct", elapsed / 8 * 1e6,
         f"mean={statistics.mean(ecmp_fims):.1f} "
         f"range=[{min(ecmp_fims):.1f},{max(ecmp_fims):.1f}] paper=36.5")
    emit("fig3a_static_fim_pct", 0.0,
         f"value={static_fim:.2f} paper=6.2")
    emit("fig3a_ecmp_throughput_gbps", 0.0,
         f"min={statistics.mean(tp_mins):.0f} med={statistics.mean(tp_meds):.0f} line_rate=400")
    emit("fig3a_static_throughput_gbps", 0.0,
         f"min={tp_s[0]:.0f} med={tp_s[len(tp_s)//2]:.0f} line_rate=400")
    emit("fig3a_imbalance_reduction_pct", 0.0,
         f"value={statistics.mean(ecmp_fims) - static_fim:.1f} paper=30.3")
