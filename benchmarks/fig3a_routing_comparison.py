"""Paper Fig. 3(a), generalized: FIM + RoCE throughput per routing strategy.

The paper compares two configurations — ECMP hashing (FIM = 36.5%,
colliding flows far below line rate) and static routing (FIM = 6.2%,
near-line-rate) — on the 16-node 2-rack testbed with 256 bipartite
flows.  This benchmark turns that into a *strategy matrix*: every
registered vectorized routing strategy (baseline ECMP, PRIME-style
multi-part-entropy spraying, greedy congestion-aware selection) runs
from ONE shared fabric compile and one shared hash-field pass, and each
emits its FIM distribution and per-pair max-min throughput distribution
over the seed sweep (1024 seeds by default; ``BENCH_SEEDS`` overrides).
The static-routing rows are kept as the paper's deterministic anchor.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (
    FIELDS_5TUPLE, CongestionAware, EcmpStrategy, PrimeSpraying,
    compile_fabric, fim, fim_from_counts, flow_fields_matrix,
    per_pair_throughput, simulate_paths, static_route_assignment,
    throughput_from_result,
)
from .common import bench_seeds, emit, paper_setup

# (row tag, strategy instance) — the matrix one run sweeps.  Paper
# anchors: ECMP FIM 36.5%, static 6.2%, line rate 400 Gb/s per pair.
STRATEGY_MATRIX = [
    ("ecmp", EcmpStrategy()),
    ("prime_spray", PrimeSpraying(flowlets=8)),
    ("congestion", CongestionAware()),
]


def run() -> None:
    fab, wl, flows = paper_setup()
    comp = compile_fabric(fab)              # ONE compile for every strategy
    num_seeds = bench_seeds(1024)
    seeds = np.arange(num_seeds)
    field_mat = flow_fields_matrix(flows, FIELDS_5TUPLE)  # one CRC pass

    results = {}
    for tag, strategy in STRATEGY_MATRIX:
        t0 = time.perf_counter()
        res = simulate_paths(comp, flows, seeds, strategy=strategy,
                             field_matrix=field_mat)
        fims, _ = fim_from_counts(res.link_flow_counts(), comp)
        sim_elapsed = time.perf_counter() - t0
        t0 = time.perf_counter()
        tp = throughput_from_result(res)
        tp_elapsed = time.perf_counter() - t0
        results[tag] = fims

        pair_min = tp.per_pair.min(axis=0)   # (S,) worst pair per seed
        pair_med = np.median(tp.per_pair, axis=0)
        emit(f"fig3a_{tag}_fim_pct", sim_elapsed / num_seeds * 1e6,
             f"mean={fims.mean():.1f} "
             f"range=[{fims.min():.1f},{fims.max():.1f}] "
             f"p95={np.percentile(fims, 95):.1f} "
             f"flowlets={res.num_flowlets // res.num_flows}"
             + (" paper=36.5" if tag == "ecmp" else ""))
        emit(f"fig3a_{tag}_throughput_gbps", tp_elapsed / num_seeds * 1e6,
             f"mean={tp.rates.mean() * len(flows) / tp.per_pair.shape[0]:.0f} "
             f"min={pair_min.mean():.0f} med={pair_med.mean():.0f} "
             f"worst={tp.per_pair.min():.0f} line_rate=400 seeds={num_seeds}")

    _, static_paths = static_route_assignment(fab, flows)
    static_fim = fim(static_paths, fab)
    tp_s = sorted(per_pair_throughput(flows, static_paths).values())
    emit("fig3a_static_fim_pct", 0.0,
         f"value={static_fim:.2f} paper=6.2")
    emit("fig3a_static_throughput_gbps", 0.0,
         f"min={tp_s[0]:.0f} med={tp_s[len(tp_s)//2]:.0f} line_rate=400")
    emit("fig3a_imbalance_reduction_pct", 0.0,
         f"value={results['ecmp'].mean() - static_fim:.1f} paper=30.3")
    emit("fig3a_spray_vs_ecmp_fim_delta_pct", 0.0,
         f"value={results['ecmp'].mean() - results['prime_spray'].mean():.1f} "
         f"ecmp={results['ecmp'].mean():.1f} "
         f"spray={results['prime_spray'].mean():.1f}")
