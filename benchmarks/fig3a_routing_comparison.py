"""Paper Fig. 3(a): RoCE throughput distribution + FIM, ECMP vs static.

256 bipartite flows on the 2-rack testbed.  The paper measured
FIM = 36.5% (ECMP) vs 6.2% (static) and near-line-rate throughput for
static.  The paper 'repeated multiple times'; the vectorized engine
(bit-identical to the hop-by-hop tracer) lets us report the FIM
distribution over 256 hash seeds instead of 8, and the throughput model
runs on two representative seeds."""

from __future__ import annotations

import statistics
import time

import numpy as np

from repro.core import (
    compile_fabric, fim, monte_carlo_fim, per_pair_throughput, simulate_paths,
    static_route_assignment,
)
from .common import emit, paper_setup


def run() -> None:
    fab, wl, flows = paper_setup()
    comp = compile_fabric(fab)

    t0 = time.perf_counter()
    mc = monte_carlo_fim(comp, flows, np.arange(256))
    elapsed = time.perf_counter() - t0
    ecmp_fims = mc.aggregate

    # throughput spread on representative seeds (median / worst FIM)
    idx = [int(np.argsort(ecmp_fims)[len(ecmp_fims) // 2]),
           int(np.argmax(ecmp_fims))]
    res = simulate_paths(comp, flows, [int(mc.seeds[i]) for i in idx])
    tp_mins, tp_meds = [], []
    for i in range(len(idx)):
        tp = sorted(per_pair_throughput(flows, res.paths_for_seed(i)).values())
        tp_mins.append(tp[0])
        tp_meds.append(tp[len(tp) // 2])

    _, static_paths = static_route_assignment(fab, flows)
    static_fim = fim(static_paths, fab)
    tp_s = sorted(per_pair_throughput(flows, static_paths).values())

    emit("fig3a_ecmp_fim_pct", elapsed / 256 * 1e6,
         f"mean={ecmp_fims.mean():.1f} "
         f"range=[{ecmp_fims.min():.1f},{ecmp_fims.max():.1f}] "
         f"p95={np.percentile(ecmp_fims, 95):.1f} paper=36.5")
    emit("fig3a_static_fim_pct", 0.0,
         f"value={static_fim:.2f} paper=6.2")
    emit("fig3a_ecmp_throughput_gbps", 0.0,
         f"min={statistics.mean(tp_mins):.0f} med={statistics.mean(tp_meds):.0f} line_rate=400")
    emit("fig3a_static_throughput_gbps", 0.0,
         f"min={tp_s[0]:.0f} med={tp_s[len(tp_s)//2]:.0f} line_rate=400")
    emit("fig3a_imbalance_reduction_pct", 0.0,
         f"value={ecmp_fims.mean() - static_fim:.1f} paper=30.3")
