"""Paper Fig. 3(b,c): per-link flow distributions across the three link
layers, ECMP vs preprogrammed static routing.  The red line in the paper
is the ideal (4 flows/link); we report min/max/std per layer."""

from __future__ import annotations

import statistics
import time

from repro.core import (
    EcmpRouting, FlowTracer, analyze_paths, static_route_assignment,
)
from .common import emit, paper_setup

LAYERS = ["leaf-to-spine", "spine-to-leaf", "leaf-to-host"]


def _layer_stats(rep, layer):
    counts = list(rep.per_layer[layer].values())
    return (min(counts), max(counts), statistics.pstdev(counts),
            rep.ideal_per_layer[layer])


def run() -> None:
    fab, wl, flows = paper_setup()
    t0 = time.perf_counter()
    res = FlowTracer(fab, EcmpRouting(fab, seed=7), wl, flows,
                     num_threads=8).trace()
    elapsed = time.perf_counter() - t0
    rep_e = analyze_paths(res.paths, fab, layers=LAYERS)
    _, static_paths = static_route_assignment(fab, flows)
    rep_s = analyze_paths(static_paths, fab, layers=LAYERS)

    for layer in LAYERS:
        lo, hi, sd, ideal = _layer_stats(rep_e, layer)
        emit(f"fig3b_ecmp_{layer}", elapsed * 1e6,
             f"min={lo} max={hi} std={sd:.2f} ideal={ideal:.0f} "
             f"fim={rep_e.per_layer_fim[layer]:.1f}%")
    for layer in LAYERS:
        lo, hi, sd, ideal = _layer_stats(rep_s, layer)
        emit(f"fig3c_static_{layer}", 0.0,
             f"min={lo} max={hi} std={sd:.2f} ideal={ideal:.0f} "
             f"fim={rep_s.per_layer_fim[layer]:.1f}%")
