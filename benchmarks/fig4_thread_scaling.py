"""Paper Fig. 4: path-discovery completion time vs number of flows for
2/4/8 FlowTracer threads.  Real Python threads against the simulated
fabric with SSH-like latency (connect 3 ms, query 1 ms); the paper's
observed properties: time grows ~linearly with flows, more threads =>
shorter completion, ~2.6x gain at 128 flows for 8 vs 2 threads."""

from __future__ import annotations

from repro.core import EcmpRouting, FlowTracer, LatencyModel, WorkloadDescription
from .common import emit, paper_setup, timeit

LAT = LatencyModel(connect_s=0.003, query_s=0.001)


def run() -> None:
    fab, wl_full, flows = paper_setup(flows_per_pair=16)
    results = {}
    for n_flows in (16, 32, 64, 128):
        n_pairs = n_flows // 16
        wl = WorkloadDescription(pairs=wl_full.pairs[:n_pairs])
        for threads in (2, 4, 8):
            tracer = FlowTracer(fab, EcmpRouting(fab, seed=1), wl, flows,
                                num_threads=threads, latency=LAT)
            t = timeit(lambda: tracer.trace(), repeats=3)
            results[(n_flows, threads)] = t
            emit(f"fig4_flows{n_flows}_threads{threads}", t * 1e6,
                 f"seconds={t:.3f}")
    speedup = results[(128, 2)] / results[(128, 8)]
    emit("fig4_speedup_128flows_8v2", 0.0,
         f"value={speedup:.2f} paper=2.6")
