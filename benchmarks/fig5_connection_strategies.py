"""Paper Fig. 5: SSH connection strategies.

Baseline           = 1 thread, ad-hoc connections (reconnect per query);
Persistent         = 1 thread, one connection per device reused;
Parallel+Persistent = persistent + threads (8 for >=8 flows, else #flows).
"""

from __future__ import annotations

from repro.core import ADHOC, PERSISTENT, EcmpRouting, FlowTracer, LatencyModel, \
    WorkloadDescription
from .common import emit, paper_setup, timeit

LAT = LatencyModel(connect_s=0.003, query_s=0.001)


def run() -> None:
    fab, wl_full, flows = paper_setup(flows_per_pair=16)
    for n_flows in (16, 32, 64, 128):
        wl = WorkloadDescription(pairs=wl_full.pairs[: max(1, n_flows // 16)])
        cfgs = {
            "baseline": dict(connection_mode=ADHOC, num_threads=1),
            "persistent": dict(connection_mode=PERSISTENT, num_threads=1),
            "par_persistent": dict(connection_mode=PERSISTENT,
                                   num_threads=8 if n_flows >= 8 else n_flows),
        }
        times = {}
        for name, kw in cfgs.items():
            tracer = FlowTracer(fab, EcmpRouting(fab, seed=1), wl, flows,
                                latency=LAT, **kw)
            times[name] = timeit(lambda: tracer.trace(), repeats=3)
            emit(f"fig5_{name}_{n_flows}flows", times[name] * 1e6,
                 f"seconds={times[name]:.3f}")
        assert times["par_persistent"] <= times["baseline"], \
            "parallel+persistent must be fastest (paper Fig. 5)"
