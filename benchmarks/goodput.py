"""Reordering-cost model benchmark: exposure + efficiency on the hot path.

The flowlet reordering model (core/reordering.py) runs inside every
non-ideal ``throughput_from_result`` call, per strategy, per benchmark
row — segment reductions over the ``(Nf, S)`` flowlet tensors of a
sprayed result.  This module times that exposure/efficiency pass in
isolation (``goodput_exposure_model``, fed to the regression guard) and
emits the transport-profile comparison on the paper testbed: the same
sprayed allocation read through ``ideal`` / ``strack`` / ``roce-nack``
eyes, plus the headline ECMP-vs-spray goodput delta.
"""

from __future__ import annotations

import numpy as np

from repro.core import (
    FIELDS_5TUPLE, PrimeSpraying, compile_fabric, flow_fields_matrix,
    flowlet_exposure, max_min_rates, reordering_efficiency, simulate_paths,
    throughput_from_result,
)
from .common import bench_seeds, emit, paper_setup, timeit


def run() -> None:
    fab, wl, flows = paper_setup()
    comp = compile_fabric(fab)
    num_seeds = bench_seeds(256)
    seeds = np.arange(num_seeds)
    field_mat = flow_fields_matrix(flows, FIELDS_5TUPLE)

    res = simulate_paths(comp, flows, seeds,
                         strategy=PrimeSpraying(flowlets=8),
                         field_matrix=field_mat)
    flowlet_rates = max_min_rates(res)

    state: dict = {}
    elapsed = timeit(
        lambda: state.update(exp=flowlet_exposure(res, flowlet_rates)))
    exposure = state["exp"]
    emit("goodput_exposure_model", elapsed / num_seeds * 1e6,
         f"mean={exposure.mean():.3f} p95={np.percentile(exposure, 95):.3f} "
         f"seeds={num_seeds} flows={len(flows)} "
         f"flowlets={res.num_flowlets // res.num_flows}")

    for profile in ("ideal", "strack", "roce-nack"):
        eff = reordering_efficiency(exposure, profile)
        emit(f"goodput_spray_eff_{profile.replace('-', '_')}", 0.0,
             f"mean={eff.mean():.3f} p5={np.percentile(eff, 5):.3f} "
             f"seeds={num_seeds}")

    base = simulate_paths(comp, flows, seeds, field_matrix=field_mat)
    tp_b = throughput_from_result(base, transport="roce-nack")
    tp_s = throughput_from_result(res, transport="roce-nack",
                                  flowlet_rates=flowlet_rates)
    emit("goodput_spray_vs_ecmp_gbps", 0.0,
         f"ecmp={tp_b.goodput.mean():.2f} spray={tp_s.goodput.mean():.2f} "
         f"spray_rate={tp_s.rates.mean():.2f} transport=roce-nack "
         f"seeds={num_seeds} flows={len(flows)}")
