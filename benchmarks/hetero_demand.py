"""Strategy matrix under uniform vs byte-weighted demand (beyond-paper).

The paper's workload description names flow *volumes* as well as pairs,
and real LLM training traffic is heavily non-uniform: the committed
scenarios (``core/llm_workload.py``) mix multi-GB DP all-reduce ring
edges with MB-scale MoE all-to-all and a bytes-scale barrier — ~9
orders of magnitude of volume spread.  This benchmark runs every
registered routing strategy over those flows twice, once with the
historical unit-demand model and once byte-weighted
(``demand_mode="bytes"``), on both the paper testbed (every cross-host
edge on the Clos) and the 2-pod DCN fabric (only pod-crossing edges).

Unweighted FIM says "how evenly are *flows* spread"; weighted FIM says
"how evenly are *bytes* spread" — when two elephants hash onto one
link, the second story is much worse than the first, which is exactly
the delta the ``*_fim_delta`` rows report.

The ``*_goodput_gbps`` rows add the other side of the spraying trade
(core/reordering.py): under a reordering-intolerant transport, full
spraying taxes every flow's goodput, while demand-aware elephant-only
spraying (``prime_spray_elephant``: split only >= 64 MiB flows,
volume-proportional K) keeps near-spray *byte*-FIM — the elephants
carry the bytes — and recovers most of the per-flow goodput, because
the mice never leave their ECMP paths.

Rows are emitted *derived-only* (``us_per_call=0``, median-of-repeats
timings inside the derived string as ``sim_ms``/``fill_ms``): these
composite-scenario timings swing ~2x under scheduler noise at smoke
shapes, too close to the regression guard's 2.5x threshold, and the
engines they exercise are already guarded by the stable fig3a /
monte_carlo / throughput rows at the same shapes.
"""

from __future__ import annotations

import numpy as np

from repro.core import (
    DEMAND_BYTES, DEMAND_UNIFORM, ELEPHANT_MIN_BYTES, FIELDS_5TUPLE,
    CongestionAware, EcmpStrategy, PrimeSpraying, WaveCongestionAware,
    build_multipod_fabric,
    build_paper_testbed, compile_fabric, fim_from_counts, flow_fields_matrix,
    multipod_llm_workload, paper_testbed_llm_workload, simulate_paths,
    throughput_from_result,
)
from .common import bench_seeds, emit, timeit

# reordering cost model for the goodput columns: the reordering-
# intolerant extreme, where the spray-vs-elephant contrast is starkest
TRANSPORT = "roce-nack"

STRATEGY_MATRIX = [
    ("ecmp", EcmpStrategy),
    ("prime_spray", lambda: PrimeSpraying(flowlets=8)),
    ("prime_spray_elephant",
     lambda: PrimeSpraying(flowlets=8, min_bytes=ELEPHANT_MIN_BYTES,
                           volume_k=True)),
    ("congestion", CongestionAware),
    # byte-weighted LLM volumes are heterogeneous, so the wave variant
    # delegates to the sequential chain here — these rows document the
    # delegation parity (identical FIM to "congestion") rather than a
    # wave-path speedup; benchmarks/wave_route.py times the wave proper
    ("wave_congestion", WaveCongestionAware),
]


def run() -> None:
    num_seeds = bench_seeds(256)
    seeds = np.arange(num_seeds)
    scenarios = [
        ("paper", build_paper_testbed(), paper_testbed_llm_workload),
        ("multipod",
         build_multipod_fabric(num_pods=2, hosts_per_pod=8,
                               leaves_per_pod=2, num_spines=4),
         multipod_llm_workload),
    ]
    for scen_tag, fab, generator in scenarios:
        comp = compile_fabric(fab)          # ONE compile per scenario
        wl, flows, stats = generator()
        field_mat = flow_fields_matrix(flows, FIELDS_5TUPLE)  # one CRC pass
        gb = wl.total_bytes / 1e9
        fim_means: dict[tuple[str, str], float] = {}
        for tag, factory in STRATEGY_MATRIX:
            for demand_mode in (DEMAND_UNIFORM, DEMAND_BYTES):
                # median-of-repeats like tp_congestion_route: these rows
                # feed the 2.5x regression guard and single shots swing
                # >2x under scheduler noise at smoke shapes
                state: dict = {}

                def sim():
                    res = simulate_paths(comp, flows, seeds,
                                         strategy=factory(),
                                         field_matrix=field_mat,
                                         demand_mode=demand_mode)
                    state["res"] = res
                    state["fims"] = fim_from_counts(
                        res.link_flow_counts(), comp)[0]

                sim_elapsed = timeit(sim)
                res, fims = state["res"], state["fims"]
                fim_means[(tag, demand_mode)] = fims.mean()
                emit(f"hetero_{scen_tag}_{tag}_{demand_mode}_fim_pct", 0.0,
                     f"mean={fims.mean():.1f} p95={np.percentile(fims, 95):.1f} "
                     f"sim_ms={sim_elapsed * 1e3:.1f} "
                     f"seeds={num_seeds} flows={len(flows)} gbytes={gb:.1f}")
                if demand_mode == DEMAND_UNIFORM:
                    # the goodput story runs on per-flow-fair rates (RoCE
                    # max-min is per-flow, volumes drive only the spray
                    # decision): full spray pays the reordering tax on
                    # every flow, elephant-only spraying leaves the mice
                    # at efficiency 1
                    tp = throughput_from_result(res, transport=TRANSPORT)
                    emit(f"hetero_{scen_tag}_{tag}_goodput_gbps", 0.0,
                         f"rate={tp.rates.mean():.2f} "
                         f"goodput={tp.goodput.mean():.2f} "
                         f"eff={tp.efficiency.mean():.3f} "
                         f"transport={TRANSPORT} "
                         f"seeds={num_seeds} flows={len(flows)}")
                if demand_mode == DEMAND_BYTES:
                    tp_elapsed = timeit(
                        lambda: state.update(
                            tp=throughput_from_result(state["res"])))
                    tp = state["tp"]
                    # a flow's step time is bytes / rate: the slowest flow
                    # gates the training step, so report the p99 transfer
                    # time alongside the weighted rate distribution
                    b = np.array([f.bytes for f in flows], np.float64)
                    xfer_ms = (8.0 * b[:, None] / 1e9
                               / np.maximum(tp.rates, 1e-30)) * 1e3
                    emit(f"hetero_{scen_tag}_{tag}_weighted_tp_gbps", 0.0,
                         f"mean={tp.rates.mean():.1f} "
                         f"p50_xfer_ms={np.percentile(xfer_ms, 50):.1f} "
                         f"p99_xfer_ms={np.percentile(xfer_ms, 99):.1f} "
                         f"fill_ms={tp_elapsed * 1e3:.1f} "
                         f"seeds={num_seeds} flows={len(flows)}")
            delta = (fim_means[(tag, DEMAND_BYTES)]
                     - fim_means[(tag, DEMAND_UNIFORM)])
            emit(f"hetero_{scen_tag}_{tag}_fim_delta_pct", 0.0,
                 f"value={delta:.1f} "
                 f"uniform={fim_means[(tag, DEMAND_UNIFORM)]:.1f} "
                 f"bytes={fim_means[(tag, DEMAND_BYTES)]:.1f}")
