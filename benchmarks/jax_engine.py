"""Device-engine acceptance bench: numpy vs jax per pipeline stage.

Times the Monte-Carlo hot path at 4096 flows x ``bench_seeds(1024)``
seeds on the paper testbed, stage by stage — ECMP walk, max-min fill,
flowlet exposure (under prime-spraying, where flowlets actually exist),
and the fused end-to-end throughput front end — once per engine.  Every
row is tagged with its ``engine`` so the regression guard never compares
a numpy baseline against a jax timing (or vice versa), and the summary
row reports the measured end-to-end speedup/crossover on this host.

jax rows are timed after one warm-up call, so they measure steady-state
jit execution (including host<->device transfers), not compilation.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import (
    ELEPHANT_MIN_BYTES, PrimeSpraying, compile_fabric, flowlet_exposure,
    max_min_rates, monte_carlo_throughput, simulate_paths,
)
from .common import bench_seeds, emit, paper_setup, timeit

NUM_SEEDS = bench_seeds(1024)
FLOWS_PER_PAIR = 256         # 16 directed server pairs x 256 = 4096 flows


def run() -> None:
    fab, wl, flows = paper_setup(flows_per_pair=FLOWS_PER_PAIR)
    comp = compile_fabric(fab)
    seeds = np.arange(NUM_SEEDS)
    shape = f"seeds={NUM_SEEDS} flows={len(flows)}"
    # heterogeneous volumes (every 4th flow an elephant) so demand-aware
    # spraying produces a real flowlet structure for the exposure stage
    flows = [dataclasses.replace(
        f, bytes=(4 * ELEPHANT_MIN_BYTES if i % 4 == 0 else 1024 * 1024))
        for i, f in enumerate(flows)]
    spray = PrimeSpraying(flowlets=4, min_bytes=ELEPHANT_MIN_BYTES)
    # the exposure inputs are engine-independent (1e-9-identical rates);
    # prep once on the host engine so each engine's row times ONLY its
    # own exposure stage
    res_s = simulate_paths(comp, flows, seeds, strategy=spray)
    rates_s = max_min_rates(res_s)
    e2e: dict[str, float] = {}

    for engine in ("numpy", "jax"):
        def walk():
            return simulate_paths(comp, flows, seeds, engine=engine)

        walk()                                   # warm-up (jit compile)
        t = timeit(walk, repeats=1)
        emit(f"engine_walk_{engine}", t / NUM_SEEDS * 1e6, shape,
             engine=engine)

        res = walk()
        def fill():
            return max_min_rates(res, engine=engine)

        fill()
        t = timeit(fill, repeats=1)
        emit(f"engine_fill_{engine}", t / NUM_SEEDS * 1e6, shape,
             engine=engine)

        def exposure():
            return flowlet_exposure(res_s, rates_s, engine=engine)

        exposure()
        t = timeit(exposure, repeats=1)
        emit(f"engine_exposure_{engine}", t / NUM_SEEDS * 1e6, shape,
             engine=engine)

        def end_to_end():
            return monte_carlo_throughput(comp, flows, seeds,
                                          transport="roce-nack",
                                          engine=engine)

        end_to_end()
        t = timeit(end_to_end, repeats=1)
        e2e[engine] = t
        emit(f"engine_e2e_{engine}", t / NUM_SEEDS * 1e6, shape,
             engine=engine)

    # derived-only summary: the measured crossover on this host
    emit("engine_jax_vs_numpy", 0.0,
         f"speedup={e2e['numpy'] / e2e['jax']:.2f}x "
         f"numpy_s={e2e['numpy']:.3f} jax_s={e2e['jax']:.3f} {shape}")
