"""Monte-Carlo FIM: routing-scheme distributions over >=1024 hash seeds.

The acceptance benchmark for the vectorized engine: ECMP (5-tuple), VXLAN
outer-header, and broken-VTEP ip-pair hashing swept across 1024 per-switch
seed realizations on BOTH fabric families, vs the deterministic static
baseline — plus the measured speedup over the equivalent per-seed
``FlowTracer`` loop (tracer timed on a sample of seeds, extrapolated)."""

from __future__ import annotations

import time

import numpy as np

from repro.core import (
    FIELDS_5TUPLE, FIELDS_IP_PAIR, FIELDS_VXLAN, EcmpRouting, FlowTracer,
    bipartite_pairs, build_multipod_fabric,
    compile_fabric, fim, flow_fields_matrix, monte_carlo_fim, nic_ip,
    simulate_paths, static_route_assignment, synthesize_flows,
)
from .common import bench_seeds, emit, paper_setup

NUM_SEEDS = bench_seeds(1024)
MODES = {"ecmp_5tuple": FIELDS_5TUPLE, "vxlan": FIELDS_VXLAN,
         "ip_pair": FIELDS_IP_PAIR}


def _sweep(tag: str, fab, wl, flows) -> None:
    comp = compile_fabric(fab)
    seeds = np.arange(NUM_SEEDS)
    for name, mode in MODES.items():
        t0 = time.perf_counter()
        mc = monte_carlo_fim(comp, flows, seeds, fields=mode)
        dt = time.perf_counter() - t0
        v = mc.aggregate
        emit(f"mc_{tag}_{name}", dt / NUM_SEEDS * 1e6,
             f"mean={v.mean():.1f} p5={np.percentile(v, 5):.1f} "
             f"p95={np.percentile(v, 95):.1f} seeds={NUM_SEEDS}")
    _, static_paths = static_route_assignment(fab, flows)
    emit(f"mc_{tag}_static", 0.0, f"value={fim(static_paths, fab):.2f}")


def _speedup() -> None:
    """1024-seed x 256-flow acceptance sweep vs the per-seed tracer loop."""
    fab, wl, flows = paper_setup()
    comp = compile_fabric(fab)
    fields = flow_fields_matrix(flows, FIELDS_5TUPLE)
    seeds = np.arange(NUM_SEEDS)

    t0 = time.perf_counter()
    res = simulate_paths(comp, flows, seeds, field_matrix=fields)
    res.link_flow_counts()
    t_vec = time.perf_counter() - t0

    sample = 8  # tracer seeds actually run; wall time extrapolates linearly
    t0 = time.perf_counter()
    for s in range(sample):
        tr = FlowTracer(fab, EcmpRouting(fab, seed=s), wl, flows).trace()
        fim(tr.paths, fab)
    t_loop = (time.perf_counter() - t0) / sample * NUM_SEEDS
    emit("mc_speedup_vs_tracer", t_vec * 1e6,
         f"speedup={t_loop / t_vec:.0f}x tracer_est_s={t_loop:.1f} "
         f"vector_s={t_vec:.3f} seeds={NUM_SEEDS} flows={len(flows)}")


def run() -> None:
    fab, wl, flows = paper_setup()
    _sweep("paper", fab, wl, flows)

    mp = build_multipod_fabric(num_pods=2, hosts_per_pod=16,
                               leaves_per_pod=4, num_spines=8)
    pod0 = [f"host-{i}" for i in range(16)]
    pod1 = [f"host-{16 + i}" for i in range(16)]
    wl2 = bipartite_pairs(pod0, pod1, flows_per_pair=8)
    flows2 = synthesize_flows(wl2, nic_ip=nic_ip, nics_per_server=1)
    _sweep("multipod", mp, wl2, flows2)

    _speedup()
