"""Beyond-paper ablation: FlowTracer's insight driving the TRAINING JOB.

Takes the multi-pod all-reduce pattern our dry-run emits on the 'pod'
axis (ring over 512 chips), decomposes it into DCN flows, and compares:

  A. naive device order + ECMP                  (what you get by default)
  B. topology-aware ring order + ECMP           (fewer DCN flows)
  C. topology-aware ring + static path table    (FlowTracer feedback loop)

Metric: DCN leaf-spine FIM + pod-crossing edge count.  This is the
paper's §V 'optimize routing' future work, implemented.
"""

from __future__ import annotations

import time

from repro.core import (
    EcmpRouting, FlowTracer, WorkloadDescription, PairSpec,
    build_multipod_fabric, fim, ring_edge_stats, static_route_assignment,
    topology_aware_ring,
)
from repro.core.hlo_flows import CollectiveOp, collectives_to_flows
from .common import emit


def _coords(n_chips=512, per_pod=256, chips_per_host=4):
    return {d: (d // per_pod,
                d // chips_per_host,
                d % chips_per_host) for d in range(n_chips)}


def _interleaved_ring(n):            # worst case: alternate pods
    a = list(range(0, n // 2))
    b = list(range(n // 2, n))
    out = []
    for x, y in zip(a, b):
        out.extend([x, y])
    return out


def run() -> None:
    coords = _coords()
    bytes_ = 512 << 20               # 512 MiB gradient all-reduce
    t0 = time.perf_counter()

    def dcn_flows(ring):
        op = CollectiveOp(
            kind="all-reduce", result_bytes=bytes_, operand_bytes=bytes_,
            wire_bytes=0, groups=(tuple(ring),), pairs=(), channel_id=1,
            line_no=0)
        return collectives_to_flows([op], coords)

    naive = _interleaved_ring(512)
    aware = topology_aware_ring(naive, coords)
    st_naive = ring_edge_stats(naive, coords)
    st_aware = ring_edge_stats(aware, coords)
    emit("placement_ring_dcn_edges_naive", 0.0,
         f"inter_pod={st_naive['inter_pod']}")
    emit("placement_ring_dcn_edges_aware", 0.0,
         f"inter_pod={st_aware['inter_pod']} (theoretical_min=2)")

    # fabric-level FIM for the naive ring's DCN flows: ECMP vs static
    fab = build_multipod_fabric(num_pods=2, hosts_per_pod=64)
    flows, stats = dcn_flows(naive)
    pairs = sorted({(f.src, f.dst) for f in flows})
    wl = WorkloadDescription(pairs=[PairSpec(s, d, 1) for s, d in pairs])
    res = FlowTracer(fab, EcmpRouting(fab, seed=3), wl, flows,
                     num_threads=8).trace()
    f_ecmp = fim(res.paths, fab, layers=["leaf-to-spine", "spine-to-leaf"])
    table, static_paths = static_route_assignment(fab, flows)
    f_static = fim(static_paths, fab, layers=["leaf-to-spine", "spine-to-leaf"])
    elapsed = time.perf_counter() - t0
    emit("placement_dcn_fim_ecmp", elapsed * 1e6, f"value={f_ecmp:.1f}%")
    emit("placement_dcn_fim_static", 0.0, f"value={f_static:.1f}%")
    emit("placement_dcn_flow_count", 0.0,
         f"naive={stats.inter_pod_dcn} aware={ring_edge_stats(aware, coords)['inter_pod']}")
