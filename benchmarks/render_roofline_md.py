"""Render EXPERIMENTS.md §Roofline table from results/dryrun (+ deltas vs
results/dryrun_baseline when present).

    PYTHONPATH=src python -m benchmarks.render_roofline_md
"""

from __future__ import annotations

import glob
import json
import os


def _load(d):
    out = {}
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        r = json.load(open(p))
        out[(r["arch"], r["shape"])] = r
    return out


def run() -> str:
    opt = _load("results/dryrun/single")
    base = _load("results/dryrun_baseline/single")
    lines = [
        "| arch | shape | compute | memory | collective | dom | useful | "
        "resident GiB | coll vs baseline |",
        "|---|---|---:|---:|---:|---|---:|---:|---:|",
    ]
    for (arch, shape), r in sorted(opt.items()):
        t = r["roofline"]
        b = base.get((arch, shape))
        delta = ""
        if b:
            b_c = b["roofline"]["collective_s"]
            if b_c > 0 and t["collective_s"] > 0:
                delta = f"{b_c / t['collective_s']:.1f}x better" \
                    if b_c > t["collective_s"] * 1.05 else \
                    ("~same" if b_c > t["collective_s"] * 0.95 else
                     f"{t['collective_s']/b_c:.1f}x worse")
        lines.append(
            f"| {arch} | {shape} | {t['compute_s']*1e3:.1f} ms | "
            f"{t['memory_s']*1e3:.1f} ms | {t['collective_s']*1e3:.1f} ms | "
            f"{t['dominant'].replace('_s','')} | {t['useful_flop_ratio']:.2f} | "
            f"{r['memory']['resident_analytic']['total']/2**30:.1f} | {delta} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(run())
