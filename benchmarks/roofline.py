"""Roofline table from the dry-run records (results/dryrun/single/*.json):
per (arch x shape), the three terms, dominant bottleneck, and the
MODEL_FLOPS / HLO_FLOPs 'useful' ratio.  See EXPERIMENTS.md §Roofline."""

from __future__ import annotations

import glob
import json

from .common import emit


def run() -> None:
    records = []
    for path in sorted(glob.glob("results/dryrun/single/*.json")):
        with open(path) as f:
            records.append(json.load(f))
    if not records:
        emit("roofline_missing", 0.0,
             "run: PYTHONPATH=src python -m repro.launch.dryrun --all")
        return
    for r in records:
        t = r["roofline"]
        emit(
            f"roofline_{r['arch']}__{r['shape']}",
            t["bound_s"] * 1e6,
            f"dom={t['dominant'].replace('_s','')} "
            f"compute={t['compute_s']*1e3:.1f}ms "
            f"mem={t['memory_s']*1e3:.1f}ms "
            f"coll={t['collective_s']*1e3:.1f}ms "
            f"useful={t['useful_flop_ratio']:.2f} "
            f"resident_gib={r['memory']['resident_analytic']['total']/2**30:.1f}",
        )
    doms = {}
    for r in records:
        doms[r["roofline"]["dominant"]] = doms.get(r["roofline"]["dominant"], 0) + 1
    emit("roofline_dominant_histogram", 0.0,
         " ".join(f"{k.replace('_s','')}={v}" for k, v in sorted(doms.items())))
