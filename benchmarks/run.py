"""Benchmark harness: one module per paper figure + beyond-paper extras.
Prints ``name,us_per_call,derived`` CSV rows and writes the same rows to
``BENCH_results.json`` so the perf trajectory is machine-trackable
across PRs.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run fig3a ...  # subset
    BENCH_SEEDS=8 python -m benchmarks.run fig3a       # tiny smoke shapes
"""

import json
import os
import platform
import sys
import time
import traceback

from . import (
    bulk_scale, fig3a_routing_comparison, fig3bc_flow_distributions,
    fig4_thread_scaling, fig5_connection_strategies, hetero_demand,
    monte_carlo_fim, placement_ablation, roofline, throughput_sweep,
    vxlan_entropy,
)
from .common import RESULTS

BENCHES = {
    "fig3a": fig3a_routing_comparison.run,
    "fig3bc": fig3bc_flow_distributions.run,
    "fig4": fig4_thread_scaling.run,
    "fig5": fig5_connection_strategies.run,
    "bulk_scale": bulk_scale.run,
    "hetero": hetero_demand.run,
    "monte_carlo": monte_carlo_fim.run,
    "throughput": throughput_sweep.run,
    "placement": placement_ablation.run,
    "vxlan": vxlan_entropy.run,
    "roofline": roofline.run,
}

RESULTS_PATH = "BENCH_results.json"


def main() -> None:
    names = sys.argv[1:] or list(BENCHES)
    unknown = [n for n in names if n not in BENCHES]
    if unknown:
        raise SystemExit(f"unknown bench(es): {unknown}; have {list(BENCHES)}")
    print("name,us_per_call,derived")
    errors: dict[str, str] = {}
    for name in names:
        # a failing bench must not silently truncate the run: the rest of
        # the matrix still executes and lands rows, the failure is recorded
        # in the payload, and the process exits non-zero at the end
        try:
            BENCHES[name]()
        except Exception as exc:
            traceback.print_exc()
            errors[name] = f"{type(exc).__name__}: {exc}"
    payload = {
        "schema": 1,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "python": platform.python_version(),
        "machine": platform.machine(),
        "benches": names,
        # smoke runs (BENCH_SEEDS=8 in CI) are tagged so trajectory
        # tooling never mistakes tiny-shape numbers for the baseline
        "bench_seeds_override": os.environ.get("BENCH_SEEDS"),
        "rows": RESULTS,
    }
    if errors:
        payload["errors"] = errors
    with open(RESULTS_PATH, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    if errors:
        raise SystemExit(
            f"bench module(s) failed: {sorted(errors)} "
            f"(partial rows written to {RESULTS_PATH})")


if __name__ == "__main__":
    main()
