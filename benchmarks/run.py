"""Benchmark harness: one module per paper figure + beyond-paper extras.
Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run fig3a ...  # subset
"""

import sys

from . import (
    bulk_scale, fig3a_routing_comparison, fig3bc_flow_distributions,
    fig4_thread_scaling, fig5_connection_strategies, monte_carlo_fim,
    placement_ablation, roofline, vxlan_entropy,
)

BENCHES = {
    "fig3a": fig3a_routing_comparison.run,
    "fig3bc": fig3bc_flow_distributions.run,
    "fig4": fig4_thread_scaling.run,
    "fig5": fig5_connection_strategies.run,
    "bulk_scale": bulk_scale.run,
    "monte_carlo": monte_carlo_fim.run,
    "placement": placement_ablation.run,
    "vxlan": vxlan_entropy.run,
    "roofline": roofline.run,
}


def main() -> None:
    names = sys.argv[1:] or list(BENCHES)
    print("name,us_per_call,derived")
    for name in names:
        BENCHES[name]()


if __name__ == "__main__":
    main()
