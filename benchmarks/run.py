"""Benchmark harness: one module per paper figure + beyond-paper extras.
Prints ``name,us_per_call,derived`` CSV rows and writes the same rows to
``BENCH_results.json`` (always at the repo root, wherever invoked from)
so the perf trajectory is machine-trackable across PRs.  Rows carry a
``bench`` tag and a subset invocation replaces only its own benches'
rows, carrying the rest of the existing payload over — so a quick
``fig3a`` check never wipes the other benches' history (rows carried
from a different ``BENCH_SEEDS`` shape surface as ORPHANED in the
regression guard rather than silently matching).

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run fig3a ...  # subset
    BENCH_SEEDS=8 python -m benchmarks.run fig3a       # tiny smoke shapes
"""

import json
import os
import platform
import sys
import time
import traceback

from . import (
    bulk_scale, fig3a_routing_comparison, fig3bc_flow_distributions,
    fig4_thread_scaling, fig5_connection_strategies, goodput, hetero_demand,
    jax_engine, monte_carlo_fim, placement_ablation, roofline,
    throughput_sweep, timeline, vxlan_entropy, wave_route,
)
from .common import RESULTS

BENCHES = {
    "fig3a": fig3a_routing_comparison.run,
    "fig3bc": fig3bc_flow_distributions.run,
    "fig4": fig4_thread_scaling.run,
    "fig5": fig5_connection_strategies.run,
    "bulk_scale": bulk_scale.run,
    "goodput": goodput.run,
    "hetero": hetero_demand.run,
    "monte_carlo": monte_carlo_fim.run,
    "throughput": throughput_sweep.run,
    "timeline": timeline.run,
    "jax_engine": jax_engine.run,
    "wave_route": wave_route.run,
    "placement": placement_ablation.run,
    "vxlan": vxlan_entropy.run,
    "roofline": roofline.run,
}

# anchored to the repo root (the parent of this package), NOT the CWD:
# a relative path would scatter perf history wherever the harness happens
# to be invoked from and silently desync the CI regression guard
RESULTS_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_results.json")


def carried_state(path: str, names: list[str]) -> tuple[list[dict],
                                                        dict[str, str]]:
    """(rows, errors) of benches NOT in this run, carried over from the
    existing payload so a subset invocation updates its own rows instead
    of wiping every other bench's trajectory.  Errors travel with their
    rows: a bench that failed partway leaves partial rows, and dropping
    its error record would launder them into a clean-looking payload.
    Rows are attributed via the ``bench`` tag stamped below; untagged
    rows (pre-tag payloads), rows of benches that no longer exist in
    ``BENCHES`` (renamed/deleted — carrying their frozen timings forward
    would let them satisfy the regression guard forever), and unreadable
    files carry nothing."""
    try:
        with open(path) as f:
            prior = json.load(f)
    except (OSError, ValueError):
        return [], {}
    keep = set(BENCHES) - set(names)
    rows = [r for r in prior.get("rows", []) if r.get("bench") in keep]
    errors = {bench: msg for bench, msg in prior.get("errors", {}).items()
              if bench in keep}
    return rows, errors


def main() -> None:
    names = sys.argv[1:] or list(BENCHES)
    unknown = [n for n in names if n not in BENCHES]
    if unknown:
        raise SystemExit(f"unknown bench(es): {unknown}; have {list(BENCHES)}")
    print("name,us_per_call,derived")
    errors: dict[str, str] = {}
    for name in names:
        # a failing bench must not silently truncate the run: the rest of
        # the matrix still executes and lands rows, the failure is recorded
        # in the payload, and the process exits non-zero at the end
        before = len(RESULTS)
        try:
            BENCHES[name]()
        except Exception as exc:
            traceback.print_exc()
            errors[name] = f"{type(exc).__name__}: {exc}"
        # per-row provenance: the owning bench (subset-merge attribution)
        # and the shape override it ran under, so carried-over rows keep
        # their true shape identity in the regression guard
        for row in RESULTS[before:]:
            row["bench"] = name
            row["bench_seeds_override"] = os.environ.get("BENCH_SEEDS")
    prior_rows, prior_errors = carried_state(RESULTS_PATH, names)
    payload = {
        "schema": 1,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "python": platform.python_version(),
        "machine": platform.machine(),
        "benches": names,
        # smoke runs (BENCH_SEEDS=8 in CI) are tagged so trajectory
        # tooling never mistakes tiny-shape numbers for the baseline
        "bench_seeds_override": os.environ.get("BENCH_SEEDS"),
        "rows": prior_rows + RESULTS,
    }
    if errors or prior_errors:
        payload["errors"] = {**prior_errors, **errors}
    with open(RESULTS_PATH, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    if errors:
        raise SystemExit(
            f"bench module(s) failed: {sorted(errors)} "
            f"(partial rows written to {RESULTS_PATH})")


if __name__ == "__main__":
    main()
