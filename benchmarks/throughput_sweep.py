"""Monte-Carlo throughput: per-pair max-min rate distributions at scale.

The acceptance benchmark for the vectorized max-min engine
(``core/vector_throughput.py``): 1024 hash-seed realizations x 256 RoCE
flows on the paper testbed, reporting

* the per-pair throughput distribution ECMP produces (the paper's
  Fig. 3a throughput story, over three orders of magnitude more seeds),
* the measured speedup of the batched engine over the per-seed scalar
  loop (``paths_for_seed`` + dict ``per_pair_throughput`` — exactly what
  fig3a ran before the rewire; scalar timed on a seed sample and
  extrapolated linearly),
* the end-to-end speedup of the full vectorized pipeline (simulate +
  fill) over the hop-by-hop tracer + scalar fill toolchain.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (
    FIELDS_5TUPLE, EcmpRouting, FlowTracer, compile_fabric,
    flow_fields_matrix, per_pair_throughput, simulate_paths,
    throughput_from_result,
)
from .common import bench_seeds, emit, paper_setup, timeit

SCALAR_BATCH = 8     # seeds per scalar timing batch; the best batch
SCALAR_BATCHES = 3   # average extrapolates linearly over the full sweep
TRACER_SAMPLE = 4


def run() -> None:
    fab, wl, flows = paper_setup()
    comp = compile_fabric(fab)
    num_seeds = bench_seeds(1024)
    seeds = np.arange(num_seeds)
    fields = flow_fields_matrix(flows, FIELDS_5TUPLE)

    t0 = time.perf_counter()
    res = simulate_paths(comp, flows, seeds, field_matrix=fields)
    t_sim = time.perf_counter() - t0

    # Both sides are deterministic, so best-of-repeats compares steady-
    # state capability; the repeats interleave so scheduler noise hits
    # both sides alike.  The scalar loop is exactly what fig3a ran before
    # the rewire: per-seed paths_for_seed + dict per_pair_throughput.
    batch = min(SCALAR_BATCH, num_seeds)
    t_vec, per_seed = float("inf"), float("inf")
    for _ in range(SCALAR_BATCHES):
        t0 = time.perf_counter()
        tp = throughput_from_result(res)
        t_vec = min(t_vec, time.perf_counter() - t0)
        t0 = time.perf_counter()
        for i in range(batch):
            per_pair_throughput(flows, res.paths_for_seed(i))
        per_seed = min(per_seed, (time.perf_counter() - t0) / batch)
    t_scalar = per_seed * num_seeds

    # end-to-end: hop-by-hop tracer + scalar fill vs simulate + batched fill
    tsample = min(TRACER_SAMPLE, num_seeds)
    t0 = time.perf_counter()
    for s in range(tsample):
        tr = FlowTracer(fab, EcmpRouting(fab, seed=s), wl, flows).trace()
        per_pair_throughput(flows, tr.paths)
    t_tracer = (time.perf_counter() - t0) / tsample * num_seeds

    pair_min = tp.per_pair.min(axis=0)          # (S,) worst pair per seed
    pair_med = np.median(tp.per_pair, axis=0)
    emit("tp_sweep_pair_throughput_gbps", t_vec / num_seeds * 1e6,
         f"min={tp.per_pair.min():.0f} p5={np.percentile(pair_min, 5):.0f} "
         f"med={pair_med.mean():.0f} line_rate=400 "
         f"seeds={num_seeds} flows={len(flows)}")
    emit("tp_speedup_vs_scalar_loop", t_vec * 1e6,
         f"speedup={t_scalar / t_vec:.0f}x scalar_est_s={t_scalar:.2f} "
         f"vector_s={t_vec:.3f} seeds={num_seeds} flows={len(flows)}")
    emit("tp_speedup_end_to_end", (t_sim + t_vec) * 1e6,
         f"speedup={t_tracer / (t_sim + t_vec):.0f}x "
         f"tracer_est_s={t_tracer:.1f} sim_s={t_sim:.3f} fill_s={t_vec:.3f}")

    # sanity anchor: batched rates == scalar rates on one seed
    scalar = per_pair_throughput(flows, res.paths_for_seed(0))
    vec0 = tp.pair_throughput_for_seed(0)
    drift = max(abs(vec0[k] - v) / v for k, v in scalar.items())
    emit("tp_sweep_differential_drift", 0.0,
         f"max_rel={drift:.2e} tol=1e-9 "
         f"rates={tp.rates.shape[0]}x{tp.rates.shape[1]}")

    # congestion-aware route: the one remaining per-flow Python loop on
    # the hot path (greedy placement is inherently sequential over flows,
    # vectorized over seeds, hop tallies fused) — tracked here so the
    # regression guard catches it slipping back toward per-hop scatters.
    # Median-of-repeats: the loop is Python-overhead-bound and a single
    # shot swings >2x under scheduler noise at smoke shapes
    t_cong = timeit(lambda: simulate_paths(
        comp, flows, seeds, strategy="congestion-aware",
        field_matrix=fields))
    emit("tp_congestion_route", t_cong / num_seeds * 1e6,
         f"total_s={t_cong:.3f} per_flow_us={t_cong / len(flows) * 1e6:.0f} "
         f"seeds={num_seeds} flows={len(flows)}")
