"""Time-expanded simulation benchmark: per-step engine cost + the two
headline directional results of the time axis.

The timed rows cover ``simulate_timeline`` over the paper-testbed LLM
sequential schedule — five ``simulate_paths`` + FIM + weighted-fill
passes over one compiled fabric — under both timing models, normalized
per seed, which is what the regression guard tracks.  The derived rows
pin the modeling claims: the merged snapshot *overstates* byte-FIM on
the committed multipod disjoint-elephant schedule (the bug the time
axis fixes); event-timed replay turns that same schedule into a
per-strategy job-completion-time ranking (the headline — ECMP's hash
collisions *lengthen* the elephant step, spray/wave placement shorten
it); and adaptive per-RTT re-spray beats static spraying's mean goodput
under the reordering-intolerant ``roce-nack`` transport even after
paying the re-spray reordering tax.
"""

from __future__ import annotations

import numpy as np

from repro.core import (
    AdaptiveSpraying, CH_GRAD_AR, CH_MOE_A2A, PrimeSpraying, SimSpec,
    TIMING_EVENT, TimelineStep, build_multipod_fabric, build_paper_testbed,
    compile_fabric, flow_channel, merged_step, multipod_llm_schedule,
    paper_testbed_llm_schedule, simulate_paths, simulate_timeline,
    throughput_from_result,
)
from .common import bench_seeds, emit, paper_setup, timeit


def run() -> None:
    num_seeds = bench_seeds(64)
    seeds = np.arange(num_seeds)

    # --- timed: the phased engine on the paper-testbed LLM schedule ----
    _, flows, _, schedule = paper_testbed_llm_schedule()
    comp = compile_fabric(build_paper_testbed())
    state: dict = {}
    elapsed = timeit(lambda: state.update(tl=simulate_timeline(
        comp, flows, schedule, seeds, demand_mode="bytes",
        transport="roce-nack", strategy="prime-spray-elephant")))
    tl = state["tl"]
    emit("timeline_phased_engine", elapsed / num_seeds * 1e6,
         f"fim={tl.fim.mean():.2f} goodput={tl.goodput.mean():.2f} "
         f"steps={tl.num_steps} seeds={num_seeds} flows={len(flows)}")

    # --- timed: the same schedule under event-timed replay -------------
    estate: dict = {}
    elapsed = timeit(lambda: estate.update(tl=simulate_timeline(
        comp, flows, schedule, seeds, spec=SimSpec(
            demand_mode="bytes", transport="roce-nack",
            strategy="prime-spray-elephant", timing=TIMING_EVENT))))
    etl = estate["tl"]
    emit("timeline_event_engine", elapsed / num_seeds * 1e6,
         f"jct={etl.job_completion.mean():.4f}s fim={etl.fim.mean():.2f} "
         f"steps={etl.num_steps} seeds={num_seeds} flows={len(flows)}")

    # --- derived: merged overstates the disjoint-elephant schedule -----
    mcomp = compile_fabric(build_multipod_fabric())
    _, mflows, _, _ = multipod_llm_schedule(param_bytes=20_000_000_000)
    sub = [f for f in mflows
           if flow_channel(f) in (CH_GRAD_AR, CH_MOE_A2A)]
    sched = [TimelineStep("grad-all-reduce", (CH_GRAD_AR,)),
             TimelineStep("moe-all-to-all", (CH_MOE_A2A,))]
    phased = simulate_timeline(mcomp, sub, sched, seeds,
                               demand_mode="bytes")
    merged = simulate_timeline(mcomp, sub, [merged_step(sched)], seeds,
                               demand_mode="bytes")
    emit("timeline_merged_vs_phased_fim", 0.0,
         f"merged={merged.fim.mean():.2f} phased={phased.fim.mean():.2f} "
         f"overstatement={merged.fim.mean() / phased.fim.mean():.3f}x "
         f"seeds={num_seeds}")

    # --- derived: per-strategy JCT on the disjoint-elephant schedule ---
    jct = {}
    for strategy in ("ecmp", "prime-spray", "wave-congestion-aware"):
        etl2 = simulate_timeline(mcomp, sub, sched, seeds, spec=SimSpec(
            demand_mode="bytes", strategy=strategy, timing=TIMING_EVENT))
        jct[strategy] = etl2.job_completion.mean()
    emit("timeline_event_jct", 0.0,
         f"ecmp={jct['ecmp']:.4f}s spray={jct['prime-spray']:.4f}s "
         f"wave={jct['wave-congestion-aware']:.4f}s "
         f"spray_speedup={jct['ecmp'] / jct['prime-spray']:.3f}x "
         f"wave_speedup={jct['ecmp'] / jct['wave-congestion-aware']:.3f}x "
         f"seeds={num_seeds}")

    # --- derived: adaptive re-spray vs static spray under roce-nack ----
    fab, _, bflows = paper_setup()
    bcomp = compile_fabric(fab)
    static = throughput_from_result(
        simulate_paths(bcomp, bflows, seeds, strategy=PrimeSpraying(8)),
        transport="roce-nack")
    adaptive = throughput_from_result(
        simulate_paths(bcomp, bflows, seeds, strategy=AdaptiveSpraying(8)),
        transport="roce-nack")
    emit("timeline_adaptive_vs_static_goodput", 0.0,
         f"static={static.goodput.mean():.2f} "
         f"adaptive={adaptive.goodput.mean():.2f} "
         f"gain={adaptive.goodput.mean() / static.goodput.mean():.3f}x "
         f"transport=roce-nack seeds={num_seeds} flows={len(bflows)}")
