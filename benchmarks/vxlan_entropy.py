"""Paper Section II quantified: FIM vs ECMP hash-field visibility.

5tuple  = native RoCE (transit switches see the inner 5-tuple);
vxlan   = RFC 7348 VTEP (outer sport = folded inner hash, 14 bits);
ip-pair = degenerate outer-IP-only hashing (legacy/broken VTEP).
"""

from __future__ import annotations

import statistics
import time

from repro.core import (
    FIELDS_5TUPLE, FIELDS_IP_PAIR, FIELDS_VXLAN, EcmpRouting, FlowTracer, fim,
)
from .common import emit, paper_setup


def run() -> None:
    fab, wl, flows = paper_setup()
    t0 = time.perf_counter()
    for mode in (FIELDS_5TUPLE, FIELDS_VXLAN, FIELDS_IP_PAIR):
        vals = []
        for seed in range(6):
            res = FlowTracer(fab, EcmpRouting(fab, seed=seed, fields=mode),
                             wl, flows, num_threads=8).trace()
            vals.append(fim(res.paths, fab))
        emit(f"vxlan_entropy_{mode}", (time.perf_counter() - t0) * 1e6 / 6,
             f"mean_fim={statistics.mean(vals):.1f}% "
             f"range=[{min(vals):.1f},{max(vals):.1f}]")
