"""Wave-parallel vs sequential congestion-aware placement (tentpole).

The sequential ``CongestionAware`` greedy loop places one flow at a
time — a Python-level chain over all flows x seeds that dominates the
routing cost well before the paper's bulk shapes.  The wave variant
routes the whole wave against a frozen load snapshot and repairs only
the conflicted subset per round, so its cost scales with rounds (a
small constant), not flows.

This bench times both at 10x the historical ``tp_congestion_route``
shape (2560 flows vs 256, same 8-seed default) on the paper testbed,
once per engine for the wave (the sequential chain is host-only), and
emits a derived speedup row plus both demand-weighted FIM means — the
wave must match or beat sequential balance while winning the wall
clock.  Uniform demand keeps the comparison on the wave path proper:
heterogeneous per-flow weights delegate to the sequential chain by
design (see ``WaveCongestionAware``), which would time the same code
twice.

jax rows are timed after one warm-up call, so they measure steady-state
jit execution, not compilation.
"""

from __future__ import annotations

import numpy as np

from repro.core import (
    CongestionAware, WaveCongestionAware, compile_fabric, fim_vector,
    simulate_paths,
)
from .common import bench_seeds, emit, paper_setup, timeit

NUM_SEEDS = bench_seeds(8)
FLOWS_PER_PAIR = 160         # 16 directed server pairs x 160 = 2560 flows


def run() -> None:
    fab, wl, flows = paper_setup(flows_per_pair=FLOWS_PER_PAIR)
    comp = compile_fabric(fab)
    seeds = np.arange(NUM_SEEDS)
    shape = f"seeds={NUM_SEEDS} flows={len(flows)}"

    def seq():
        return simulate_paths(comp, flows, seeds, strategy=CongestionAware())

    t_seq = timeit(seq)
    fim_seq = fim_vector(seq()).mean()
    emit("wave_route_sequential", t_seq / NUM_SEEDS * 1e6,
         f"fim={fim_seq:.2f} {shape}", engine="numpy")

    t_wave: dict[str, float] = {}
    for engine in ("numpy", "jax"):
        def wave():
            return simulate_paths(comp, flows, seeds,
                                  strategy=WaveCongestionAware(),
                                  engine=engine)

        wave()                                  # warm-up (jit compile)
        t_wave[engine] = timeit(wave)
        fim_wave = fim_vector(wave()).mean()
        emit(f"wave_route_wave_{engine}", t_wave[engine] / NUM_SEEDS * 1e6,
             f"fim={fim_wave:.2f} {shape}", engine=engine)

    # derived-only summary: the acceptance row (wave >= 5x sequential at
    # 10x the historical tp_congestion_route flow count)
    emit("wave_vs_sequential", 0.0,
         f"speedup={t_seq / t_wave['numpy']:.2f}x "
         f"jax_speedup={t_seq / t_wave['jax']:.2f}x "
         f"seq_s={t_seq:.3f} wave_s={t_wave['numpy']:.3f} {shape}")
