"""Quickstart: FlowTracer on the paper's 2-rack testbed in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds the fabric, generates the paper's 256-flow bipartite RoCE
workload, traces every flow hop-by-hop under ECMP, prints the per-layer
Flow Imbalance Metric, then computes the preprogrammed static routing
that fixes it (paper Fig. 3).
"""

from repro.core import (
    EcmpRouting, FlowTracer, StaticRouting, analyze_paths, bipartite_pairs,
    build_paper_testbed, nic_ip, per_pair_throughput, server_name,
    static_route_assignment, synthesize_flows,
)


def main() -> None:
    fabric = build_paper_testbed()
    rack0 = [server_name(i) for i in range(8)]
    rack1 = [server_name(8 + i) for i in range(8)]
    workload = bipartite_pairs(rack0, rack1, flows_per_pair=16)
    flows = synthesize_flows(workload, nic_ip=nic_ip)

    print("== standard ECMP ==")
    tracer = FlowTracer(fabric, EcmpRouting(fabric, seed=7), workload, flows,
                        num_threads=8)
    result = tracer.trace()
    print(analyze_paths(result.paths, fabric).summary())
    tp = sorted(per_pair_throughput(flows, result.paths).values())
    print(f"  pair throughput Gb/s: min={tp[0]:.0f} median={tp[len(tp)//2]:.0f} "
          f"max={tp[-1]:.0f} (line rate 400)")

    print("\n== preprogrammed static routing (computed by placement.py) ==")
    table, static_paths = static_route_assignment(fabric, flows)
    print(analyze_paths(static_paths, fabric).summary())
    tp = sorted(per_pair_throughput(flows, static_paths).values())
    print(f"  pair throughput Gb/s: min={tp[0]:.0f} median={tp[len(tp)//2]:.0f} "
          f"max={tp[-1]:.0f}")
    print(f"  static table entries: {len(table)} (device, flow) -> egress port")

    # the table is a real routing policy: the tracer can audit it
    audit = FlowTracer(fabric, StaticRouting(fabric, table), workload, flows,
                       num_threads=8).trace()
    assert len(audit.paths) == 256
    print("  audit: tracer reproduces the planned paths OK")


if __name__ == "__main__":
    main()
