"""Batched serving example: prefill + token-by-token decode with a KV
cache, greedy and sampled generation.

    PYTHONPATH=src python examples/serve_batch.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.models import Model
from repro.serve import ServeEngine


def main() -> None:
    cfg = ARCHS["granite-3-2b"].reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {cfg.name} (reduced, {n/1e6:.2f}M params)")

    B, S0, steps = 4, 8, 24
    engine = ServeEngine(model, batch_size=B, max_len=S0 + steps)
    prompts = (jnp.arange(B * S0).reshape(B, S0) * 13 % cfg.vocab).astype(jnp.int32)

    t0 = time.perf_counter()
    out = engine.generate(params, prompts, steps=steps)
    dt = time.perf_counter() - t0
    print(f"greedy: generated {B}x{steps} tokens in {dt:.2f}s "
          f"({B*steps/dt:.0f} tok/s incl. compile)")
    print("sequences:")
    for row in out.tolist():
        print("  ", row)

    t0 = time.perf_counter()
    out2 = engine.generate(params, prompts, steps=steps)
    dt = time.perf_counter() - t0
    print(f"warm: {B*steps/dt:.0f} tok/s")
    assert (out == out2).all(), "greedy generation must be deterministic"

    out3 = engine.generate(params, prompts, steps=steps, temperature=0.8,
                           key=jax.random.PRNGKey(1))
    diff = int((out3[:, S0:] != out[:, S0:]).sum())
    print(f"sampled (T=0.8): {diff}/{B*steps} tokens differ from greedy")


if __name__ == "__main__":
    main()
