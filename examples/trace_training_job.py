"""FlowTracer applied to a real compiled training job — the paper's tool
closing the loop on OUR multi-pod dry-run.

    PYTHONPATH=src python examples/trace_training_job.py --arch granite-3-2b

1. AOT-compiles the arch's train step on the 2-pod 512-chip mesh (no
   device memory touched);
2. extracts every collective from the compiled HLO (trip-count aware) and
   decomposes pod-crossing ring edges into RoCE flows between host NICs;
3. traces those flows across the DCN leaf-spine fabric model under ECMP
   vs automated static routing and reports FIM — i.e., exactly what an
   operator would do before launching a 512-chip job.

NOTE: must run in its own process (forces 512 host devices).
"""

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse

import jax

from repro.configs import get_arch, get_shape
from repro.core import (
    EcmpRouting, FlowTracer, PairSpec, WorkloadDescription, analyze_paths,
    build_multipod_fabric, extract_collectives, fim, static_route_assignment,
    summarize, collectives_to_flows,
)
from repro.launch.mesh import device_coords, make_production_mesh
from repro.launch.specs import build_cell


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--shape", default="train_4k")
    args = ap.parse_args()

    arch, shape = get_arch(args.arch), get_shape(args.shape)
    mesh = make_production_mesh(multi_pod=True)
    print(f"compiling {arch.name} x {shape.name} on {dict(mesh.shape)} ...")
    cell = build_cell(arch, shape, mesh)
    with jax.set_mesh(mesh):
        compiled = jax.jit(
            cell.fn, in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
            donate_argnums=cell.donate_argnums).lower(*cell.args).compile()

    ops = extract_collectives(compiled.as_text())
    summ = summarize(ops)
    print(f"collectives: {summ.per_kind_count}")
    print(f"wire bytes/device/step: {summ.total_wire_bytes/2**20:.0f} MiB")

    coords = device_coords(mesh)
    flows, stats = collectives_to_flows(ops, coords)
    print(f"ring edges: intra-host={stats.intra_host} "
          f"ICI={stats.intra_pod_ici} DCN={stats.inter_pod_dcn}")
    print(f"DCN traffic: {stats.dcn_bytes/2**20:.0f} MiB/step across "
          f"{len(flows)} flows")
    if not flows:
        print("no pod-crossing flows (nothing for the DCN analysis)")
        return

    fabric = build_multipod_fabric(num_pods=2, hosts_per_pod=64)
    pairs = sorted({(f.src, f.dst) for f in flows})
    wl = WorkloadDescription(pairs=[PairSpec(s, d, 0) for s, d in pairs])
    res = FlowTracer(fabric, EcmpRouting(fabric, seed=1), wl, flows,
                     num_threads=8).trace()
    layers = ["leaf-to-spine", "spine-to-leaf"]
    print("\n== DCN path analysis (ECMP) ==")
    print(analyze_paths(res.paths, fabric, layers=layers).summary())

    table, static_paths = static_route_assignment(fabric, flows)
    print("\n== after FlowTracer-driven static repath ==")
    print(analyze_paths(static_paths, fabric, layers=layers).summary())
    print(f"\nFIM: ECMP {fim(res.paths, fabric, layers=layers):.1f}% -> "
          f"static {fim(static_paths, fabric, layers=layers):.1f}%")


if __name__ == "__main__":
    main()
