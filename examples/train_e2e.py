"""End-to-end training driver: ~100M-parameter granite-family model for a
few hundred steps on CPU, with checkpointing, restart-on-failure, and
straggler monitoring — the full production loop at laptop scale.

    PYTHONPATH=src python examples/train_e2e.py --steps 300

Expected: loss falls from ~6.2 to < 3 on the structured synthetic stream
(the stream is 8-fold repetitive, so sub-1 loss is learnable); a
checkpoint lands every 50 steps; `--inject-failure` kills step 120 once
and the loop resumes exactly from the last checkpoint.
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, restore, save
from repro.configs import ARCHS
from repro.data import SyntheticDataset
from repro.ft import HostFailure, StragglerDetector, run_with_restarts
from repro.models import Model
from repro.train import AdamWConfig, TrainConfig, init_train_state, make_train_step


def build_100m():
    """granite-family, ~100M params, CPU-trainable."""
    return dataclasses.replace(
        ARCHS["granite-3-2b"],
        num_layers=6, d_model=512, num_heads=8, num_kv_heads=4, head_dim=64,
        d_ff=1536, vocab=8192,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    ap.add_argument("--inject-failure", action="store_true")
    args = ap.parse_args()

    cfg = build_100m()
    model = Model(cfg)
    tc = TrainConfig(optimizer=AdamWConfig(
        lr=1e-3, warmup_steps=20, decay_steps=args.steps))
    ds = SyntheticDataset(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch, seed=0)
    step_fn = jax.jit(make_train_step(model, tc))
    detector = StragglerDetector()
    state: dict = {"failed": False}

    def train_loop(_start: int) -> int:
        if latest_step(args.ckpt_dir) is not None:
            tpl = init_train_state(model, tc, jax.random.PRNGKey(0))
            restored, s0 = restore(args.ckpt_dir,
                                   {"params": tpl[0], "opt": tpl[1]})
            params, opt = restored["params"], restored["opt"]
            print(f"[restore] resumed from step {s0}")
        else:
            params, opt = init_train_state(model, tc, jax.random.PRNGKey(0))
            s0 = 0
            n = sum(x.size for x in jax.tree.leaves(params))
            print(f"[init] {n/1e6:.1f}M params")
        for i in range(s0, args.steps):
            t0 = time.perf_counter()
            batch = {k: jnp.asarray(v) for k, v in ds.batch(i).items()}
            params, opt, metrics = step_fn(params, opt, batch)
            metrics = jax.block_until_ready(metrics)  # sync for honest timing
            dt = time.perf_counter() - t0
            detector.record("host-0", dt)
            if args.inject_failure and i == 120 and not state["failed"]:
                state["failed"] = True
                raise HostFailure("injected failure at step 120")
            if (i + 1) % args.ckpt_every == 0 or i + 1 == args.steps:
                save(args.ckpt_dir, i + 1, {"params": params, "opt": opt})
            if (i + 1) % 20 == 0 or i == s0:
                print(f"step {i+1:4d}  loss={float(metrics['loss']):.4f}  "
                      f"gnorm={float(metrics['grad_norm']):.2f}  "
                      f"lr={float(metrics['lr']):.2e}  {dt*1e3:.0f}ms")
        return args.steps

    run_with_restarts(train_loop, max_restarts=2)
    print("done.")


if __name__ == "__main__":
    main()
