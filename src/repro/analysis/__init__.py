"""flowcheck: engine-invariant static analysis for the FlowTracer repo.

The correctness story of this codebase rests on invariants that no
general-purpose linter knows about: the numpy and jax engines must stay
bit-identical, jitted code must not retrace or host-sync, registries
must be populated at import time, the ``SimSpec`` API surface must stay
consistent across all four Monte-Carlo front ends, and benchmark rows
must stay in lockstep with the committed smoke baseline.  ``flowcheck``
encodes each of those contracts as an AST-level rule family and fails CI
on *new* violations (a committed ``flowcheck_baseline.json`` suppresses
— with justification — the pre-existing ones).

    PYTHONPATH=src python -m repro.analysis.flowcheck

The package is deliberately stdlib-only (``ast`` + ``json``): the CI job
needs no numpy/jax install to run it.
"""

from .common import Context, Finding

__all__ = ["Context", "Finding", "collect_findings", "main"]


def __getattr__(name):
    # lazy: importing .flowcheck eagerly would double-import it under
    # `python -m repro.analysis.flowcheck` (runpy warns)
    if name in ("collect_findings", "main"):
        from . import flowcheck
        return getattr(flowcheck, name)
    raise AttributeError(name)
