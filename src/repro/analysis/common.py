"""Shared plumbing for the flowcheck rules: findings, parsed sources,
pragma comments, and the analysis context handed to every rule.

Rules address files by repo-relative path through a ``Context`` so the
same rule code runs unchanged against the real tree and against the
miniature fixture trees the tests build under ``tmp_path``.
"""

from __future__ import annotations

import ast
import dataclasses
from pathlib import Path

#: Trailing-comment pragma marker.  Recognized directives:
#:   ``# flowcheck: disable=FT-RULE-ID[,FT-OTHER]`` — suppress those rules
#:     on this physical line;
#:   ``# flowcheck: disable`` — suppress every rule on this line;
#:   ``# flowcheck: new-bench-row`` — declare an emitted bench row as
#:     intentionally absent from the committed smoke baseline.
PRAGMA_MARKER = "flowcheck:"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation.  ``message`` is deliberately line-free and
    names the construct it anchors to, so the fingerprint survives
    unrelated edits that shift line numbers."""

    rule: str      # e.g. "FT-JIT-BRANCH"
    file: str      # repo-relative posix path
    line: int
    message: str
    hint: str = ""

    @property
    def fingerprint(self) -> str:
        return f"{self.rule}::{self.file}::{self.message}"

    def format(self) -> str:
        out = f"{self.file}:{self.line}: {self.rule}: {self.message}"
        if self.hint:
            out += f"\n    hint: {self.hint}"
        return out

    def to_json(self) -> dict:
        return {
            "rule": self.rule, "file": self.file, "line": self.line,
            "message": self.message, "hint": self.hint,
            "fingerprint": self.fingerprint,
        }


class SourceFile:
    """One parsed source file: text, lines, AST, and pragma lookups."""

    def __init__(self, path: Path, root: Path):
        self.path = path
        self.rel = path.relative_to(root).as_posix()
        self.text = path.read_text()
        self.lines = self.text.splitlines()
        self.tree = ast.parse(self.text, filename=str(path))

    def pragmas(self, lineno: int) -> set[str]:
        """Directive tokens of the ``# flowcheck:`` pragma on a physical
        line (empty set when there is none).  ``disable=A,B`` expands to
        ``{"disable", "disable=A", "disable=B"}`` so callers can test
        either the bare or the rule-qualified form."""
        if not 1 <= lineno <= len(self.lines):
            return set()
        text = self.lines[lineno - 1]
        marker = text.find("#")
        if marker < 0:
            return set()
        comment = text[marker:]
        idx = comment.find(PRAGMA_MARKER)
        if idx < 0:
            return set()
        out: set[str] = set()
        for token in comment[idx + len(PRAGMA_MARKER):].split():
            token = token.strip().rstrip(";,")
            if not token:
                continue
            if token.startswith("disable="):
                out.add("disable")
                for rule in token[len("disable="):].split(","):
                    if rule:
                        out.add(f"disable={rule}")
            else:
                out.add(token)
        return out

    def disabled(self, lineno: int, rule: str) -> bool:
        prag = self.pragmas(lineno)
        if not prag:
            return False
        if f"disable={rule}" in prag:
            return True
        # a bare `disable` (no rule list) silences everything
        return "disable" in prag and not any(
            p.startswith("disable=") for p in prag)


@dataclasses.dataclass
class Context:
    """Analysis context: the repo root plus a parsed-source cache.

    Rules resolve all files through ``source``/``sources`` so tests can
    point a Context at a miniature tree with the same relative layout.
    """

    root: Path
    _cache: dict[str, SourceFile | None] = dataclasses.field(
        default_factory=dict)

    def source(self, rel: str) -> SourceFile | None:
        """Parsed source for a repo-relative path; None when absent."""
        if rel not in self._cache:
            path = self.root / rel
            self._cache[rel] = (
                SourceFile(path, self.root) if path.is_file() else None)
        return self._cache[rel]

    def sources(self, rel_dir: str, pattern: str = "*.py") -> list[SourceFile]:
        """Parsed sources for every matching file under a directory,
        sorted by path for deterministic finding order."""
        base = self.root / rel_dir
        if not base.is_dir():
            return []
        out = []
        for path in sorted(base.rglob(pattern)):
            sf = self.source(path.relative_to(self.root).as_posix())
            if sf is not None:
                out.append(sf)
        return out


def call_name(node: ast.Call) -> str:
    """Dotted name of a call's callee, best effort: ``np.arange(...)``
    -> ``"np.arange"``, ``emit(...)`` -> ``"emit"``, anything fancier
    -> ``""``."""
    parts: list[str] = []
    cur = node.func
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        parts.append(cur.id)
        return ".".join(reversed(parts))
    return ""


def keyword_names(node: ast.Call) -> set[str]:
    return {kw.arg for kw in node.keywords if kw.arg is not None}


def iter_parented(tree: ast.AST):
    """Yield ``(node, parents)`` for every node, where ``parents`` is the
    tuple of enclosing AST nodes outermost-first."""
    stack: list[tuple[ast.AST, tuple[ast.AST, ...]]] = [(tree, ())]
    while stack:
        node, parents = stack.pop()
        yield node, parents
        child_parents = parents + (node,)
        for child in ast.iter_child_nodes(node):
            stack.append((child, child_parents))
