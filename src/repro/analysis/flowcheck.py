"""flowcheck driver: run every rule family, diff against the committed
baseline, and fail on *new* findings.

    PYTHONPATH=src python -m repro.analysis.flowcheck
    PYTHONPATH=src python -m repro.analysis.flowcheck --json out.json
    PYTHONPATH=src python -m repro.analysis.flowcheck --write-baseline

Baseline contract (``flowcheck_baseline.json`` at the repo root): every
entry suppresses findings matching its fingerprint and MUST carry a
non-empty ``justification`` — a suppression nobody can defend is a bug
with a paper trail.  ``--write-baseline`` seeds entries with a TODO
justification; the check mode refuses to accept them until the TODO is
replaced, so "baseline it" is never a silent escape hatch.  Baseline
entries that no longer match anything are reported as STALE (advisory,
mirroring the bench guard's ORPHANED rows) so the file shrinks as debt
is paid down.

Exit codes: 0 = clean against baseline; 1 = new findings; 2 = broken
baseline (unjustified entries) or usage error.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from .common import Context, Finding
from .rules import ALL_RULE_IDS, FAMILIES

BASELINE_NAME = "flowcheck_baseline.json"
TODO_JUSTIFICATION = ("TODO: explain why this pre-existing finding is "
                      "acceptable")


def default_root() -> Path:
    """The repo root this package sits in (…/src/repro/analysis ->
    three levels up)."""
    return Path(__file__).resolve().parents[3]


def collect_findings(ctx: Context) -> list[Finding]:
    """All findings from every rule family, pragma-suppressed lines
    removed, in (file, line, rule) order."""
    findings: list[Finding] = []
    for _family, mod in FAMILIES:
        findings.extend(mod.run(ctx))
    kept = []
    for f in findings:
        sf = ctx.source(f.file)
        if sf is not None and sf.disabled(f.line, f.rule):
            continue
        kept.append(f)
    kept.sort(key=lambda f: (f.file, f.line, f.rule, f.message))
    return kept


def load_baseline(path: Path) -> tuple[list[dict], list[str]]:
    """(entries, errors).  Errors are fatal (exit 2): a baseline that
    cannot be trusted must not silently suppress anything."""
    if not path.is_file():
        return [], []
    try:
        payload = json.loads(path.read_text())
    except ValueError as exc:
        return [], [f"{path}: not valid JSON ({exc})"]
    entries = payload.get("entries", [])
    errors = []
    for i, entry in enumerate(entries):
        just = str(entry.get("justification", "")).strip()
        if not just or just.startswith("TODO"):
            errors.append(
                f"{path}: entry {i} ({entry.get('fingerprint', '?')!r}) "
                f"has no real justification — every suppression must "
                f"say why it is acceptable")
        if not entry.get("fingerprint"):
            errors.append(f"{path}: entry {i} has no fingerprint")
    return entries, errors


def write_baseline(path: Path, findings: list[Finding]) -> None:
    entries = [{
        "rule": f.rule,
        "file": f.file,
        "message": f.message,
        "fingerprint": f.fingerprint,
        "justification": TODO_JUSTIFICATION,
    } for f in findings]
    # one entry per fingerprint (identical constructs on several lines
    # of one function share a message by design)
    seen: set[str] = set()
    unique = []
    for e in entries:
        if e["fingerprint"] not in seen:
            seen.add(e["fingerprint"])
            unique.append(e)
    payload = {
        "schema": 1,
        "tool": "repro.analysis.flowcheck",
        "entries": unique,
    }
    path.write_text(json.dumps(payload, indent=2) + "\n")


def split_findings(
    findings: list[Finding], entries: list[dict],
) -> tuple[list[Finding], list[Finding], list[dict]]:
    """(new, suppressed, stale baseline entries)."""
    suppressed_fps = {e["fingerprint"] for e in entries if "fingerprint" in e}
    new = [f for f in findings if f.fingerprint not in suppressed_fps]
    suppressed = [f for f in findings if f.fingerprint in suppressed_fps]
    live = {f.fingerprint for f in findings}
    stale = [e for e in entries if e.get("fingerprint") not in live]
    return new, suppressed, stale


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.flowcheck", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    parser.add_argument("--root", type=Path, default=None,
                        help="repo root (default: auto-detected)")
    parser.add_argument("--baseline", type=Path, default=None,
                        help=f"baseline path (default: <root>/"
                             f"{BASELINE_NAME})")
    parser.add_argument("--no-baseline", action="store_true",
                        help="ignore the baseline: report every finding "
                             "as new")
    parser.add_argument("--write-baseline", action="store_true",
                        help="write the current findings as the baseline "
                             "(justifications seeded as TODO — fill them "
                             "in before committing) and exit 0")
    parser.add_argument("--json", type=Path, default=None, metavar="PATH",
                        help="also write a machine-readable findings "
                             "payload")
    parser.add_argument("--list-rules", action="store_true",
                        help="print every rule id and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        for family, mod in FAMILIES:
            for rid in mod.RULE_IDS:
                print(f"{rid}  [{family}]")
        return 0

    root = (args.root or default_root()).resolve()
    if not (root / "src" / "repro").is_dir():
        print(f"flowcheck: {root} does not look like the repo root "
              f"(no src/repro) — pass --root", file=sys.stderr)
        return 2
    baseline_path = args.baseline or root / BASELINE_NAME

    ctx = Context(root=root)
    findings = collect_findings(ctx)

    if args.write_baseline:
        write_baseline(baseline_path, findings)
        print(f"flowcheck: wrote {len(findings)} finding(s) to "
              f"{baseline_path}")
        print("flowcheck: fill in every TODO justification before "
              "committing — the check mode rejects TODOs")
        return 0

    entries: list[dict] = []
    if not args.no_baseline:
        entries, errors = load_baseline(baseline_path)
        if errors:
            for e in errors:
                print(f"flowcheck: BROKEN BASELINE: {e}")
            return 2

    new, suppressed, stale = split_findings(findings, entries)

    if args.json:
        args.json.write_text(json.dumps({
            "schema": 1,
            "rules": list(ALL_RULE_IDS),
            "findings": [f.to_json() for f in findings],
            "new": [f.to_json() for f in new],
            "suppressed": len(suppressed),
            "stale_baseline": [e.get("fingerprint") for e in stale],
        }, indent=2) + "\n")

    for entry in stale:
        print(f"flowcheck: STALE baseline entry (no longer matches "
              f"anything — delete it): {entry.get('fingerprint')}")
    if new:
        print(f"flowcheck: {len(new)} new finding(s) "
              f"({len(suppressed)} suppressed by baseline):")
        for f in new:
            print(f.format())
        return 1
    print(f"flowcheck: OK — 0 new findings "
          f"({len(findings)} total, {len(suppressed)} suppressed by "
          f"baseline, {len(stale)} stale baseline entr"
          f"{'y' if len(stale) == 1 else 'ies'})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
