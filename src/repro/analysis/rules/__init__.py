"""Rule registry: one module per rule family, each exposing
``run(ctx) -> list[Finding]`` plus the ``FT-*`` rule ids it can emit."""

from . import (
    api_surface, bench_coverage, dtype_drift, jit_retrace, registry_hygiene,
)

#: (family name, module) in report order.  Every module contributes its
#: rule ids via a module-level ``RULE_IDS`` tuple.
FAMILIES = (
    ("jit-retrace", jit_retrace),
    ("dtype-drift", dtype_drift),
    ("registry-hygiene", registry_hygiene),
    ("api-surface", api_surface),
    ("bench-coverage", bench_coverage),
)

ALL_RULE_IDS = tuple(
    rid for _, mod in FAMILIES for rid in mod.RULE_IDS)
