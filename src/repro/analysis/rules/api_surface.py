"""FT-API: SimSpec <-> front-end <-> fused-delegation consistency.

``SimSpec`` is the one validated description of how a simulation runs,
and four front ends accept it (``simulate_paths``, ``monte_carlo_fim``,
``monte_carlo_throughput``, ``simulate_timeline``) alongside a
legacy-kwarg surface.  A new SimSpec field can silently rot in three
places, and each is a rule here:

* **FT-API-KWARGS** — a front end declares an ``_UNSET`` legacy kwarg
  that is not a SimSpec field (it would be rejected by the SimSpec
  constructor only at call time), or declares one and then fails to
  forward it into the dict handed to ``resolve_spec`` (the kwarg parses
  but does nothing);
* **FT-API-MISSING** — a SimSpec field that a front end neither exposes
  as a legacy kwarg nor appears in the per-front-end exclusion table
  below.  Exclusions are *declared with a reason*, so "this front end
  deliberately has no ``transport=``" is auditable rather than
  accidental.  A stale exclusion (the kwarg exists after all) is also
  flagged;
* **FT-API-FUSED** — a front end delegates to a ``fused_*`` device
  pipeline but does not forward a SimSpec-named parameter the fused
  function accepts.  This is exactly how ``spec.max_hops`` was silently
  dropped by the jax fast paths before this analyzer existed: the spec
  resolved it, the numpy path honored it, and the fused call rebuilt
  the default.
"""

from __future__ import annotations

import ast

from ..common import Context, Finding, call_name, keyword_names

RULE_KWARGS = "FT-API-KWARGS"
RULE_MISSING = "FT-API-MISSING"
RULE_FUSED = "FT-API-FUSED"
RULE_IDS = (RULE_KWARGS, RULE_MISSING, RULE_FUSED)

SPEC_MODULE = "src/repro/core/vector_sim.py"
SPEC_CLASS = "SimSpec"
UNSET_NAME = "_UNSET"
RESOLVE_FN = "resolve_spec"
FUSED_MODULE = "src/repro/core/jax_engine.py"

#: front-end function -> (module, {excluded spec field: reason}).
#: An exclusion documents a *deliberate* hole in the legacy-kwarg
#: surface; spec= still carries the field everywhere.
FRONTENDS: dict[str, tuple[str, dict[str, str]]] = {
    "simulate_paths": ("src/repro/core/vector_sim.py", {
        "transport": "paths-only front end: no throughput stage ever "
                     "reads the transport profile",
        "timing": "snapshot front end: the time axis only exists in "
                  "simulate_timeline",
    }),
    "monte_carlo_fim": ("src/repro/core/vector_sim.py", {
        "transport": "FIM has no goodput stage, so a transport profile "
                     "cannot change the result",
        "timing": "snapshot front end: the time axis only exists in "
                  "simulate_timeline",
    }),
    "monte_carlo_throughput": ("src/repro/core/vector_throughput.py", {
        "timing": "snapshot front end: the time axis only exists in "
                  "simulate_timeline",
    }),
    "simulate_timeline": ("src/repro/core/timeline.py", {}),
}


def _find_function(tree: ast.Module, name: str) -> ast.FunctionDef | None:
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef) and node.name == name:
            return node
    return None


def spec_fields(ctx: Context) -> tuple[list[str], str] | None:
    """(SimSpec field names, module path) or None when unparseable."""
    sf = ctx.source(SPEC_MODULE)
    if sf is None:
        return None
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.ClassDef) and node.name == SPEC_CLASS:
            fields = [
                stmt.target.id for stmt in node.body
                if isinstance(stmt, ast.AnnAssign)
                and isinstance(stmt.target, ast.Name)]
            return fields, sf.rel
    return None


def _unset_params(fn: ast.FunctionDef) -> dict[str, int]:
    """Parameter name -> line for every param defaulted to ``_UNSET``."""
    out: dict[str, int] = {}
    a = fn.args
    pos = [*a.posonlyargs, *a.args]
    for param, default in zip(pos[len(pos) - len(a.defaults):], a.defaults):
        if isinstance(default, ast.Name) and default.id == UNSET_NAME:
            out[param.arg] = param.lineno
    for param, default in zip(a.kwonlyargs, a.kw_defaults):
        if isinstance(default, ast.Name) and default.id == UNSET_NAME:
            out[param.arg] = param.lineno
    return out


def _resolve_spec_keys(fn: ast.FunctionDef) -> set[str]:
    """Keys of the dict literal handed to ``resolve_spec`` in the body."""
    keys: set[str] = set()
    for node in ast.walk(fn):
        if not (isinstance(node, ast.Call)
                and call_name(node).split(".")[-1] == RESOLVE_FN):
            continue
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            if isinstance(arg, ast.Call) \
                    and call_name(arg) == "dict":
                keys |= {kw.arg for kw in arg.keywords if kw.arg}
            elif isinstance(arg, ast.Dict):
                keys |= {k.value for k in arg.keys
                         if isinstance(k, ast.Constant)
                         and isinstance(k.value, str)}
    return keys


def _fused_signatures(ctx: Context) -> dict[str, set[str]]:
    """fused function name -> parameter names (from the fused module)."""
    sf = ctx.source(FUSED_MODULE)
    if sf is None:
        return {}
    out: dict[str, set[str]] = {}
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.FunctionDef) \
                and node.name.startswith("fused_"):
            a = node.args
            out[node.name] = {p.arg for p in
                              (*a.posonlyargs, *a.args, *a.kwonlyargs)}
    return out


def run(ctx: Context) -> list[Finding]:
    spec = spec_fields(ctx)
    if spec is None:
        return []
    fields, spec_rel = spec
    field_set = set(fields)
    fused_sigs = _fused_signatures(ctx)
    findings: list[Finding] = []

    for fn_name, (rel, exclusions) in FRONTENDS.items():
        sf = ctx.source(rel)
        if sf is None:
            continue
        fn = _find_function(sf.tree, fn_name)
        if fn is None:
            continue
        unset = _unset_params(fn)
        dict_keys = _resolve_spec_keys(fn)

        for param, line in sorted(unset.items()):
            if param not in field_set:
                findings.append(Finding(
                    rule=RULE_KWARGS, file=sf.rel, line=line,
                    message=(f"`{fn_name}` declares legacy kwarg "
                             f"`{param}` which is not a {SPEC_CLASS} "
                             f"field"),
                    hint=f"add the field to {SPEC_CLASS} (with "
                         f"resolve() validation) or drop the kwarg"))
            elif param not in dict_keys:
                findings.append(Finding(
                    rule=RULE_KWARGS, file=sf.rel, line=line,
                    message=(f"`{fn_name}` declares legacy kwarg "
                             f"`{param}` but never forwards it to "
                             f"{RESOLVE_FN}"),
                    hint="add it to the dict handed to resolve_spec — "
                         "as written the kwarg parses and does nothing"))

        for field in fields:
            if field in unset:
                if field in exclusions:
                    findings.append(Finding(
                        rule=RULE_MISSING, file=sf.rel, line=fn.lineno,
                        message=(f"stale exclusion: `{fn_name}` now "
                                 f"accepts `{field}` but the rule table "
                                 f"still excludes it"),
                        hint="delete the entry from "
                             "rules/api_surface.FRONTENDS"))
                continue
            if field not in exclusions:
                findings.append(Finding(
                    rule=RULE_MISSING, file=sf.rel, line=fn.lineno,
                    message=(f"{SPEC_CLASS} field `{field}` is not "
                             f"accepted as a legacy kwarg by "
                             f"`{fn_name}`"),
                    hint="add the kwarg (defaulted to _UNSET and "
                         "forwarded to resolve_spec), or declare the "
                         "exclusion with a reason in "
                         "rules/api_surface.FRONTENDS"))

        # fused delegations must forward every spec-named parameter
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            callee = call_name(node).split(".")[-1]
            if callee not in fused_sigs:
                continue
            passed = keyword_names(node)
            for param in sorted(fused_sigs[callee] & field_set):
                if param not in passed:
                    findings.append(Finding(
                        rule=RULE_FUSED, file=sf.rel, line=node.lineno,
                        message=(f"`{fn_name}` delegates to `{callee}` "
                                 f"without forwarding spec field "
                                 f"`{param}` (the fused path silently "
                                 f"uses its own default)"),
                        hint=f"pass {param}=s.{param} in the delegation "
                             f"call"))
    return findings
