"""FT-BENCH: benchmark rows must stay in lockstep with the smoke baseline.

``benchmarks/check_regression.py`` already guards one direction:
baseline rows with no counterpart in fresh results surface as ORPHANED.
This rule is the inverse, and it runs *statically* — before any bench
executes: every row name a smoke-covered bench module can emit must
exist in ``benchmarks/BENCH_baseline_smoke.json``, or be explicitly
declared new with a ``# flowcheck: new-bench-row`` pragma on the
``emit(...)`` line.  Without it, a freshly added row ships unguarded
(no baseline row -> the regression guard never compares it) and the
PR-4/PR-5 baseline-drift dance repeats.

A module is *smoke-covered* when at least one of its emitted names
matches a baseline row — modules outside the CI smoke set
(``fig4``, ``placement``, ...) have no baseline rows at all and are
skipped wholesale, so adding a brand-new bench module stays friction
free until it joins the smoke matrix.

f-string row names (``f"hetero_{scen}_{tag}_fim_pct"``) become match
patterns (each interpolation matches any non-empty run), checked
against the baseline with fullmatch: the pattern must cover at least
one committed row.
"""

from __future__ import annotations

import ast
import json
import re

from ..common import Context, Finding, call_name

RULE_ROW = "FT-BENCH-ROW"
RULE_IDS = (RULE_ROW,)

BENCH_DIR = "benchmarks"
BASELINE_REL = "benchmarks/BENCH_baseline_smoke.json"
EMIT_NAME = "emit"
NEW_ROW_PRAGMA = "new-bench-row"

#: Harness/guard modules that never emit rows of their own.
SKIP_FILES = {"run.py", "common.py", "check_regression.py",
              "render_roofline_md.py"}


def _emit_patterns(tree: ast.Module) -> list[tuple[str, int, bool]]:
    """(regex-or-literal, line, is_pattern) for every emit() call whose
    first argument is a string literal or f-string.  Dynamically
    computed names (a variable) cannot be checked and are skipped."""
    out: list[tuple[str, int, bool]] = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and call_name(node).split(".")[-1] == EMIT_NAME
                and node.args):
            continue
        arg = node.args[0]
        if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
            out.append((arg.value, node.lineno, False))
        elif isinstance(arg, ast.JoinedStr):
            parts = []
            for v in arg.values:
                if isinstance(v, ast.Constant):
                    parts.append(re.escape(str(v.value)))
                else:
                    parts.append(r".+")
            out.append(("".join(parts), node.lineno, True))
    return out


def baseline_row_names(ctx: Context) -> set[str] | None:
    path = ctx.root / BASELINE_REL
    if not path.is_file():
        return None
    try:
        payload = json.loads(path.read_text())
    except ValueError:
        return None
    return {row.get("name") for row in payload.get("rows", [])
            if row.get("name")}


def run(ctx: Context) -> list[Finding]:
    rows = baseline_row_names(ctx)
    if rows is None:
        return []
    findings: list[Finding] = []
    for sf in ctx.sources(BENCH_DIR):
        if sf.path.name in SKIP_FILES:
            continue
        emits = _emit_patterns(sf.tree)
        if not emits:
            continue

        def covered(spec: str, is_pattern: bool) -> bool:
            if is_pattern:
                rx = re.compile(spec)
                return any(rx.fullmatch(r) for r in rows)
            return spec in rows

        # modules with zero baseline presence are not in the CI smoke
        # set; their rows are unguarded by design
        if not any(covered(spec, isp) for spec, _, isp in emits):
            continue
        for spec, line, is_pattern in emits:
            if covered(spec, is_pattern):
                continue
            if NEW_ROW_PRAGMA in sf.pragmas(line):
                continue
            kind = "pattern" if is_pattern else "row"
            findings.append(Finding(
                rule=RULE_ROW, file=sf.rel, line=line,
                message=(f"bench {kind} `{spec}` has no matching row in "
                         f"{BASELINE_REL} — the regression guard will "
                         f"never compare it"),
                hint="refresh the smoke baseline (recipe in ROADMAP.md "
                     "housekeeping), or mark the emit line with "
                     "`# flowcheck: new-bench-row` until the next "
                     "refresh"))
    return findings
