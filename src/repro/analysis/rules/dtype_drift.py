"""FT-DT: dtype drift hazards in the hot-path core modules.

The numpy and jax engines are differential-tested to be bit-identical;
that contract survives only while every array's dtype is pinned where
it is created.  Three construction idioms leave the dtype to the
environment instead:

* ``np.arange(...)`` without ``dtype=`` — numpy's default integer is
  the platform C ``long``: int64 on Linux, int32 on Windows.  An index
  tensor that silently changes width changes overflow behaviour and the
  bit pattern fed to the hash mix.
* ``np.array([...])`` / ``np.asarray([...])`` on a *literal*
  list/tuple/comprehension without ``dtype=`` — the element-derived
  default is platform-int for integer content (same C-long trap) and
  invisible-to-reviewers float64 otherwise.  Arrays built from existing
  arrays preserve their dtype and are not flagged.
* ``jnp.zeros/ones/empty/full/arange/linspace`` without ``dtype=``
  inside the jax engine — jax's default dtype *changes with the x64
  mode* (float32/int32 bare, float64/int64 under
  ``jax.experimental.enable_x64``).  Code that relies on running inside
  the engine's scoped x64 context works, but the dependence is
  invisible at the call site; either pin the dtype or baseline the
  finding with that justification.

Positional dtypes count (``np.zeros(n, bool)``; ``np.full(shape, v,
np.int32)``), so the codebase's existing pinned calls stay clean.
"""

from __future__ import annotations

import ast

from ..common import Context, Finding, SourceFile, call_name

RULE_ARANGE = "FT-DT-ARANGE"
RULE_LITERAL = "FT-DT-LITERAL"
RULE_JNP = "FT-DT-JNP"
RULE_IDS = (RULE_ARANGE, RULE_LITERAL, RULE_JNP)

#: Hot-path modules under the numpy<->jax bit-identity contract.
HOT_MODULES = (
    "src/repro/core/vector_sim.py",
    "src/repro/core/vector_throughput.py",
    "src/repro/core/strategies.py",
    "src/repro/core/reordering.py",
    "src/repro/core/timeline.py",
    "src/repro/core/jax_engine.py",
    "src/repro/core/compile_fabric.py",
)

#: Modules where jnp constructors are additionally policed (x64-scope
#: dependent defaults).
JNP_MODULES = ("src/repro/core/jax_engine.py",)

NUMPY_ALIASES = ("np", "numpy")
JNP_ALIASES = ("jnp",)

#: func name -> index of the positional dtype slot (None = keyword-only
#: in practice for this rule).
_POSITIONAL_DTYPE_SLOT = {
    "zeros": 1, "ones": 1, "empty": 1, "array": 1, "asarray": 1,
    "full": 2, "linspace": 2,
}

_LITERAL_NODES = (ast.List, ast.Tuple, ast.ListComp, ast.GeneratorExp,
                  ast.Set, ast.SetComp)


def _has_dtype(node: ast.Call, fname: str) -> bool:
    if any(kw.arg == "dtype" for kw in node.keywords):
        return True
    slot = _POSITIONAL_DTYPE_SLOT.get(fname)
    return slot is not None and len(node.args) > slot


def _enclosing(parents: tuple[ast.AST, ...]) -> str:
    for p in reversed(parents):
        if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return p.name
    return "<module>"


def _check_module(sf: SourceFile, police_jnp: bool) -> list[Finding]:
    from ..common import iter_parented

    findings: list[Finding] = []
    for node, parents in iter_parented(sf.tree):
        if not isinstance(node, ast.Call):
            continue
        callee = call_name(node)
        alias, _, fname = callee.partition(".")
        if not fname:
            continue
        where = _enclosing(parents)
        if alias in NUMPY_ALIASES:
            if fname == "arange" and not _has_dtype(node, fname):
                findings.append(Finding(
                    rule=RULE_ARANGE, file=sf.rel, line=node.lineno,
                    message=(f"np.arange without explicit dtype in "
                             f"`{where}` (`{_snippet(node)}`)"),
                    hint="numpy's default integer is the platform C long "
                         "(int32 on Windows); pin dtype=np.int64 (or the "
                         "width the consumer needs)"))
            elif fname in ("array", "asarray") \
                    and not _has_dtype(node, fname) and node.args \
                    and isinstance(node.args[0], _LITERAL_NODES):
                findings.append(Finding(
                    rule=RULE_LITERAL, file=sf.rel, line=node.lineno,
                    message=(f"np.{fname} on a literal without explicit "
                             f"dtype in `{where}` (`{_snippet(node)}`)"),
                    hint="element-derived dtype is platform-dependent for "
                         "int content; pin dtype= at the call"))
        elif police_jnp and alias in JNP_ALIASES:
            if fname in ("zeros", "ones", "empty", "full", "arange",
                         "linspace") and not _has_dtype(node, fname):
                findings.append(Finding(
                    rule=RULE_JNP, file=sf.rel, line=node.lineno,
                    message=(f"jnp.{fname} without explicit dtype in "
                             f"`{where}` (`{_snippet(node)}`)"),
                    hint="jax's default dtype flips with the x64 mode; "
                         "pin dtype=, or baseline with the justification "
                         "that the call always runs inside the engine's "
                         "scoped enable_x64 context"))
    return findings


def _snippet(node: ast.AST) -> str:
    try:
        text = ast.unparse(node)
    except Exception:
        return "<call>"
    return text if len(text) <= 60 else text[:57] + "..."


def run(ctx: Context) -> list[Finding]:
    findings: list[Finding] = []
    for rel in HOT_MODULES:
        sf = ctx.source(rel)
        if sf is not None:
            findings.extend(_check_module(sf, police_jnp=rel in JNP_MODULES))
    return findings
