"""FT-JIT: retrace / host-sync hazards inside jitted code.

``core/jax_engine.py`` builds its kernels as closures decorated with
``functools.partial(jax.jit, static_argnames=(...))``.  Inside such a
function (and anything it calls), four Python idioms silently destroy
the performance contract:

* ``for``/``while`` over a traced value — unrolls per element or fails;
* ``if`` on a traced value — a ``TracerBoolConversionError`` at best, a
  retrace-per-value loop at worst (the ``departure_fill`` trap PR 9
  documented: shape-shrinking Python loops retrace every iteration);
* ``float()`` / ``bool()`` / ``int()`` / ``.item()`` / ``.tolist()`` on
  a traced array — a device->host sync in the middle of the kernel;
* ``np.*`` calls on traced arrays — a silent host round-trip (numpy
  forces concretization) that turns the fused pipeline into ping-pong.

The rule runs a small interprocedural taint analysis: parameters of a
jit entry that are NOT in ``static_argnames`` are traced; taint
propagates through assignments and through calls to same-module
helpers (per-call-site, so ``_hash_grid_j(fields, dev_seed,
hash_backend)`` taints the arrays but not the static backend string).
Known-static accesses never carry taint: ``x.shape`` / ``x.ndim`` /
``x.dtype`` / ``x.size`` / ``len(x)`` are trace-time constants, and
``x is None`` / ``x is not None`` is Python-level structure dispatch,
not a value branch — so the codebase's ``for f in
range(fields.shape[1])`` and ``if cell_salt is not None`` idioms stay
clean by construction.

Functions *defined inside* a jit entry (``cond``/``body`` closures
handed to ``lax.while_loop``) are analyzed with all their parameters
traced plus the enclosing taint, since their arguments are loop-carried
tracers by construction.
"""

from __future__ import annotations

import ast

from ..common import Context, Finding, SourceFile, call_name

RULE_LOOP = "FT-JIT-LOOP"
RULE_BRANCH = "FT-JIT-BRANCH"
RULE_HOSTSYNC = "FT-JIT-HOSTSYNC"
RULE_NUMPY = "FT-JIT-NUMPY"
RULE_IDS = (RULE_LOOP, RULE_BRANCH, RULE_HOSTSYNC, RULE_NUMPY)

#: Modules that contain (or build) jitted kernels.
JIT_MODULES = (
    "src/repro/core/jax_engine.py",
    "src/repro/core/strategies.py",
)

#: Attribute accesses on a traced array that are static at trace time.
STATIC_ATTRS = {"shape", "ndim", "dtype", "size"}

#: Builtins whose call on a traced value forces a host sync.
HOST_CASTS = {"float", "bool", "int", "complex"}

#: Method calls on a traced value that force a host sync.
HOST_METHODS = {"item", "tolist", "numpy"}

NUMPY_ALIASES = {"np", "numpy"}


def _is_jax_jit_expr(node: ast.expr) -> bool:
    """True for ``jax.jit`` / ``jit`` expressions."""
    if isinstance(node, ast.Attribute):
        return (node.attr == "jit" and isinstance(node.value, ast.Name)
                and node.value.id == "jax")
    return isinstance(node, ast.Name) and node.id == "jit"


def jit_static_argnames(fn: ast.FunctionDef) -> tuple[bool, set[str]]:
    """(is jit entry, static_argnames) from the decorator list.

    Recognized shapes: ``@jax.jit``, ``@jit``,
    ``@jax.jit(static_argnames=...)``, and
    ``@[functools.]partial(jax.jit, static_argnames=...)``.
    """
    for dec in fn.decorator_list:
        if _is_jax_jit_expr(dec):
            return True, set()
        if not isinstance(dec, ast.Call):
            continue
        callee = call_name(dec)
        if _is_jax_jit_expr(dec.func):
            return True, _static_names(dec)
        if callee in ("functools.partial", "partial") and dec.args \
                and _is_jax_jit_expr(dec.args[0]):
            return True, _static_names(dec)
    return False, set()


def _static_names(call: ast.Call) -> set[str]:
    for kw in call.keywords:
        if kw.arg in ("static_argnames", "static_argnums"):
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                return {v.value}
            if isinstance(v, (ast.Tuple, ast.List)):
                return {e.value for e in v.elts
                        if isinstance(e, ast.Constant)
                        and isinstance(e.value, str)}
    return set()


def _param_names(fn: ast.FunctionDef) -> list[str]:
    a = fn.args
    return [p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs)]


class _TaintChecker(ast.NodeVisitor):
    """Taint-aware hazard scan of one function body under a given set of
    traced names.  Collects findings and the call sites into same-module
    helpers (with per-argument taint) for the interprocedural worklist."""

    def __init__(self, sf: SourceFile, fn: ast.FunctionDef,
                 tainted: set[str], local_funcs: dict[str, ast.FunctionDef],
                 qualname: str):
        self.sf = sf
        self.fn = fn
        self.tainted = set(tainted)
        self.local_funcs = local_funcs
        self.qualname = qualname
        self.findings: list[Finding] = []
        self.helper_calls: list[tuple[str, frozenset[str]]] = []
        self.nested: list[ast.FunctionDef] = []

    # -- taint query ------------------------------------------------------

    def expr_taint(self, node: ast.expr | None) -> bool:
        """Does evaluating ``node`` observe a traced *value*?  Accesses
        that are static at trace time (shape/ndim/dtype/size, len(),
        ``is [not] None``) do not count."""
        if node is None:
            return False
        for sub, parents in _walk_with_parents(node):
            if not isinstance(sub, ast.Name) or sub.id not in self.tainted:
                continue
            if not self._static_context(sub, parents):
                return True
        return False

    def _static_context(self, name: ast.Name,
                        parents: tuple[ast.AST, ...]) -> bool:
        """Is this tainted-name use wrapped in a static accessor?"""
        for p in reversed(parents):
            if isinstance(p, ast.Attribute) and p.attr in STATIC_ATTRS:
                return True
            if isinstance(p, ast.Call) and isinstance(p.func, ast.Name) \
                    and p.func.id == "len":
                return True
            if isinstance(p, ast.Compare) and all(
                    isinstance(op, (ast.Is, ast.IsNot)) for op in p.ops):
                # `x is None` / `x is not None`: structure, not value
                return True
        return False

    # -- statements -------------------------------------------------------

    def visit_FunctionDef(self, node: ast.FunctionDef):
        if node is not self.fn:
            self.nested.append(node)   # analyzed with full-taint params
            return
        self.generic_visit(node)

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Assign(self, node: ast.Assign):
        self._assign(node.targets, node.value)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign):
        self._assign([node.target], node.value)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign):
        if node.value is not None:
            self._assign([node.target], node.value)
        self.generic_visit(node)

    def _assign(self, targets: list[ast.expr], value: ast.expr):
        if self.expr_taint(value):
            for t in targets:
                for sub in ast.walk(t):
                    if isinstance(sub, ast.Name):
                        self.tainted.add(sub.id)

    def visit_For(self, node: ast.For):
        if self.expr_taint(node.iter):
            self._emit(RULE_LOOP, node,
                       f"Python `for` over traced value in jitted "
                       f"`{self.qualname}` (iterating "
                       f"`{_snippet(node.iter)}` unrolls per element "
                       f"or retraces)",
                       "hoist to lax.fori_loop/scan, or iterate a static "
                       "shape: `for i in range(x.shape[k])`")
        else:
            # loop targets over a static iterable stay untainted
            pass
        if self.expr_taint(node.iter):
            for sub in ast.walk(node.target):
                if isinstance(sub, ast.Name):
                    self.tainted.add(sub.id)
        self.generic_visit(node)

    def visit_While(self, node: ast.While):
        if self.expr_taint(node.test):
            self._emit(RULE_LOOP, node,
                       f"Python `while` on traced value in jitted "
                       f"`{self.qualname}` (test `{_snippet(node.test)}`)",
                       "use lax.while_loop with the condition inside the "
                       "traced cond function")
        self.generic_visit(node)

    def visit_If(self, node: ast.If):
        if self.expr_taint(node.test):
            self._emit(RULE_BRANCH, node,
                       f"Python `if` on traced value in jitted "
                       f"`{self.qualname}` (test `{_snippet(node.test)}`)",
                       "branch with jnp.where/lax.cond, or make the "
                       "operand a static_argname")
        self.generic_visit(node)

    def visit_IfExp(self, node: ast.IfExp):
        if self.expr_taint(node.test):
            self._emit(RULE_BRANCH, node,
                       f"conditional expression on traced value in jitted "
                       f"`{self.qualname}` (test `{_snippet(node.test)}`)",
                       "use jnp.where instead of `a if t else b`")
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call):
        callee = call_name(node)
        args_taint = any(self.expr_taint(a) for a in node.args) or any(
            self.expr_taint(kw.value) for kw in node.keywords)
        if callee in HOST_CASTS and args_taint:
            self._emit(RULE_HOSTSYNC, node,
                       f"`{callee}()` on traced value in jitted "
                       f"`{self.qualname}` forces a device->host sync",
                       "keep the value traced (jnp ops) or mark the "
                       "argument static")
        elif isinstance(node.func, ast.Attribute) \
                and node.func.attr in HOST_METHODS \
                and self.expr_taint(node.func.value):
            self._emit(RULE_HOSTSYNC, node,
                       f"`.{node.func.attr}()` on traced value in jitted "
                       f"`{self.qualname}` forces a device->host sync",
                       "return the traced array and materialize outside "
                       "the jit boundary")
        elif callee.partition(".")[0] in NUMPY_ALIASES and args_taint:
            rule = (RULE_HOSTSYNC
                    if callee.split(".")[-1] in ("asarray", "array")
                    else RULE_NUMPY)
            self._emit(rule, node,
                       f"`{callee}` called on traced value in jitted "
                       f"`{self.qualname}` (numpy concretizes the tracer)",
                       "use the jnp twin of the operation inside jit")
        elif callee in self.local_funcs and callee != self.qualname:
            taint = frozenset(self._callsite_taint(node, callee))
            self.helper_calls.append((callee, taint))
        self.generic_visit(node)

    def _callsite_taint(self, node: ast.Call, callee: str) -> set[str]:
        params = _param_names(self.local_funcs[callee])
        out: set[str] = set()
        for i, arg in enumerate(node.args):
            if i < len(params) and self.expr_taint(arg):
                out.add(params[i])
        for kw in node.keywords:
            if kw.arg in params and self.expr_taint(kw.value):
                out.add(kw.arg)
        return out

    def _emit(self, rule: str, node: ast.AST, message: str, hint: str):
        self.findings.append(Finding(
            rule=rule, file=self.sf.rel,
            line=getattr(node, "lineno", 1), message=message, hint=hint))


def _walk_with_parents(node: ast.AST):
    stack: list[tuple[ast.AST, tuple[ast.AST, ...]]] = [(node, ())]
    while stack:
        cur, parents = stack.pop()
        yield cur, parents
        for child in ast.iter_child_nodes(cur):
            stack.append((child, parents + (cur,)))


def _collect_functions(tree: ast.Module) -> dict[str, ast.FunctionDef]:
    """name -> FunctionDef for every function in the module (nested
    included; inner names shadow outer on collision, which matches the
    call-by-bare-name resolution the checker does)."""
    out: dict[str, ast.FunctionDef] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out.setdefault(node.name, node)
    return out


def _analyze_module(sf: SourceFile) -> list[Finding]:
    funcs = _collect_functions(sf.tree)
    findings: list[Finding] = []
    # worklist of (function name, tainted params); analyzing a function
    # under a superset of any earlier taint set supersedes that run, so
    # track the union seen per function and re-run only on growth
    seen: dict[str, set[str]] = {}
    work: list[tuple[ast.FunctionDef, set[str], str]] = []

    for name, fn in funcs.items():
        is_jit, static = jit_static_argnames(fn)
        if is_jit:
            tainted = {p for p in _param_names(fn) if p not in static}
            work.append((fn, tainted, name))
            seen[name] = set(tainted)

    emitted: set[tuple[str, str, int]] = set()
    budget = 200   # hard cap: the worklist is tiny in practice
    while work and budget:
        budget -= 1
        fn, tainted, qualname = work.pop()
        checker = _TaintChecker(sf, fn, tainted, funcs, qualname)
        checker.visit(fn)
        for f in checker.findings:
            key = (f.rule, f.message, f.line)
            if key not in emitted:
                emitted.add(key)
                findings.append(f)
        # closures defined inside jitted code: arguments are tracers by
        # construction (lax.while_loop carries), so all params taint,
        # plus whatever of the enclosing scope they close over
        for nested in checker.nested:
            n_taint = set(_param_names(nested)) | checker.tainted
            prev = seen.get(f"{qualname}.{nested.name}", set())
            if not n_taint <= prev:
                seen[f"{qualname}.{nested.name}"] = prev | n_taint
                work.append((nested, n_taint,
                             f"{qualname}.{nested.name}"))
        # same-module helpers: taint flows per call site
        for callee, taint in checker.helper_calls:
            prev = seen.get(callee, set())
            if not set(taint) <= prev:
                seen[callee] = prev | set(taint)
                work.append((funcs[callee], prev | set(taint), callee))
    return findings


def _snippet(node: ast.expr) -> str:
    try:
        text = ast.unparse(node)
    except Exception:
        return "<expr>"
    return text if len(text) <= 48 else text[:45] + "..."


def run(ctx: Context) -> list[Finding]:
    findings: list[Finding] = []
    for rel in JIT_MODULES:
        sf = ctx.source(rel)
        if sf is not None:
            findings.extend(_analyze_module(sf))
    return findings
