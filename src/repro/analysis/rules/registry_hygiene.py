"""FT-REG: registry hygiene for strategies, transports, and channels.

``simulate_paths(strategy="wave-congestion-aware")``,
``transport="roce-nack"``, and schedule validation against the channel
vocabulary all assume the registries are fully populated the moment the
module is imported.  Three ways that assumption rots:

* a ``register_*`` call tucked inside a function runs only if someone
  happens to call it — every other entry point sees a hole in the
  registry (module-level loops/``if`` blocks are fine: they execute at
  import);
* two modules registering the same name — whichever imports last wins,
  silently re-anchoring every consumer (the runtime guards raise today,
  but only on the import order that actually collides);
* a registered name no tier-1 test ever references — the registration
  is dead weight at best and silently broken at worst.

Name extraction is static: literal first arguments, plus a one-hop
resolution through module-level assignments for the
``for _p in (IDEAL, ROCE_NACK, STRACK): register_transport(_p)`` idiom
(the profile name is read out of the ``calibrate_transport("name", ...)``
/ ``TransportProfile(name="...")`` constructor).  A registration whose
name cannot be resolved statically is itself a finding: the other two
checks are blind to it.
"""

from __future__ import annotations

import ast

from ..common import Context, Finding, SourceFile, call_name, iter_parented

RULE_TOPLEVEL = "FT-REG-TOPLEVEL"
RULE_DUP = "FT-REG-DUP"
RULE_UNTESTED = "FT-REG-UNTESTED"
RULE_OPAQUE = "FT-REG-OPAQUE"
RULE_IDS = (RULE_TOPLEVEL, RULE_DUP, RULE_UNTESTED, RULE_OPAQUE)

SRC_DIR = "src"
TESTS_DIR = "tests"

#: register function -> which argument carries the public name.
#: ``register_channel(value, "CH_NAME")`` names via arg 1; the others
#: via arg 0 (a literal string or a resolvable profile object).
REGISTER_FUNCS = {
    "register_strategy": 0,
    "register_transport": 0,
    "register_channel": 1,
}

#: Constructor calls whose name= (or first string arg) defines the
#: registered name when a profile object is passed by variable.
_NAME_BEARING_CTORS = ("TransportProfile", "calibrate_transport")


def _module_assignments(tree: ast.Module) -> dict[str, ast.expr]:
    """Module-level simple assignments: name -> value expression."""
    out: dict[str, ast.expr] = {}
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    out[t.id] = node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None \
                and isinstance(node.target, ast.Name):
            out[node.target.id] = node.value
    return out


def _name_from_ctor(call: ast.Call) -> str | None:
    if call_name(call).split(".")[-1] not in _NAME_BEARING_CTORS:
        return None
    for kw in call.keywords:
        if kw.arg == "name" and isinstance(kw.value, ast.Constant) \
                and isinstance(kw.value.value, str):
            return kw.value.value
    if call.args and isinstance(call.args[0], ast.Constant) \
            and isinstance(call.args[0].value, str):
        return call.args[0].value
    return None


def _resolve_name(arg: ast.expr, assigns: dict[str, ast.expr],
                  loop_bindings: dict[str, list[ast.expr]]) -> list[str] | None:
    """Registered name(s) for one register-call argument, or None when
    it cannot be resolved statically."""
    if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
        return [arg.value]
    candidates: list[ast.expr] = []
    if isinstance(arg, ast.Name):
        if arg.id in loop_bindings:
            candidates = loop_bindings[arg.id]
        elif arg.id in assigns:
            candidates = [assigns[arg.id]]
    out: list[str] = []
    for c in candidates:
        if isinstance(c, ast.Name) and c.id in assigns:
            c = assigns[c.id]
        if isinstance(c, ast.Call):
            name = _name_from_ctor(c)
            if name is None:
                return None
            out.append(name)
        else:
            return None
    return out or None


def _loop_bindings(parents: tuple[ast.AST, ...]) -> dict[str, list[ast.expr]]:
    """Bindings from enclosing module-level ``for x in (a, b, c):``."""
    out: dict[str, list[ast.expr]] = {}
    for p in parents:
        if isinstance(p, ast.For) and isinstance(p.target, ast.Name) \
                and isinstance(p.iter, (ast.Tuple, ast.List)):
            out[p.target.id] = list(p.iter.elts)
    return out


def _scan_module(sf: SourceFile) -> tuple[list[Finding],
                                          list[tuple[str, str, int, str]]]:
    """(findings, registrations) where each registration is
    (register func, resolved name, line, file)."""
    findings: list[Finding] = []
    regs: list[tuple[str, str, int, str]] = []
    assigns = _module_assignments(sf.tree)
    for node, parents in iter_parented(sf.tree):
        if not isinstance(node, ast.Call):
            continue
        fname = call_name(node).split(".")[-1]
        if fname not in REGISTER_FUNCS:
            continue
        in_function = any(isinstance(
            p, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda,
                ast.ClassDef)) for p in parents)
        if in_function:
            findings.append(Finding(
                rule=RULE_TOPLEVEL, file=sf.rel, line=node.lineno,
                message=(f"`{fname}` call inside a function/class body "
                         f"(`{_snippet(node)}`) — the registry is only "
                         f"populated if that code happens to run"),
                hint="move the registration to module top level so it "
                     "executes at import time"))
            continue
        arg_idx = REGISTER_FUNCS[fname]
        if len(node.args) <= arg_idx:
            continue
        replace = any(kw.arg == "replace" for kw in node.keywords)
        names = _resolve_name(node.args[arg_idx], assigns,
                              _loop_bindings(parents))
        if names is None:
            findings.append(Finding(
                rule=RULE_OPAQUE, file=sf.rel, line=node.lineno,
                message=(f"`{fname}` with a statically unresolvable name "
                         f"(`{_snippet(node)}`)"),
                hint="register with a literal name (or a module-level "
                     "constructor with a literal name=) so uniqueness "
                     "and test coverage stay checkable"))
            continue
        if not replace:
            for name in names:
                regs.append((fname, name, node.lineno, sf.rel))
    return findings, regs


def _snippet(node: ast.AST) -> str:
    try:
        text = ast.unparse(node)
    except Exception:
        return "<call>"
    return text if len(text) <= 60 else text[:57] + "..."


def run(ctx: Context) -> list[Finding]:
    findings: list[Finding] = []
    regs: list[tuple[str, str, int, str]] = []
    for sf in ctx.sources(SRC_DIR):
        f, r = _scan_module(sf)
        findings.extend(f)
        regs.extend(r)

    # repo-wide uniqueness per registry kind
    seen: dict[tuple[str, str], tuple[int, str]] = {}
    for fname, name, line, rel in regs:
        key = (fname, name)
        if key in seen:
            first_line, first_rel = seen[key]
            findings.append(Finding(
                rule=RULE_DUP, file=rel, line=line,
                message=(f"`{fname}({name!r})` registered more than once "
                         f"(first at {first_rel})"),
                hint="pick a unique name, or pass replace=True at the "
                     "site that deliberately overrides"))
        else:
            seen[key] = (line, rel)

    # every registered name must be referenced by at least one test
    test_blobs = [sf.text for sf in ctx.sources(TESTS_DIR)]
    for (fname, name), (line, rel) in sorted(seen.items(),
                                             key=lambda kv: kv[1]):
        if not any(name in blob for blob in test_blobs):
            findings.append(Finding(
                rule=RULE_UNTESTED, file=rel, line=line,
                message=(f"registered name {name!r} ({fname}) is not "
                         f"referenced by any test"),
                hint="add a test that resolves the name through the "
                     "registry (a strategy matrix row or a direct "
                     "resolve_* assertion both count)"))
    return findings
