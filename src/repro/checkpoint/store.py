"""Checkpointing: atomic, step-indexed, resumable.

Layout:
    <dir>/step_<N>/arrays.msgpack     flattened param/opt pytree
    <dir>/step_<N>/meta.json          step, tree structure, shapes
    <dir>/LATEST                      text file with the newest step

Writes go to ``step_<N>.tmp`` then ``os.replace`` (atomic on POSIX), so a
host failure mid-write can never corrupt the restore point — the
fault-tolerance contract the restart tests exercise.  Arrays are stored
host-side (numpy) so restore can re-shard onto any mesh (elastic
restart with a different device count reuses the same checkpoint).
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(directory: str, step: int, tree: Any, *, keep_last: int = 3) -> str:
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat = _flatten(tree)
    packed = {
        k: {"dtype": str(v.dtype), "shape": list(v.shape),
            "data": v.tobytes()}
        for k, v in flat.items()
    }
    with open(os.path.join(tmp, "arrays.msgpack"), "wb") as f:
        f.write(msgpack.packb(packed, use_bin_type=True))
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "keys": sorted(flat)}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                      # atomic publish
    with open(os.path.join(directory, "LATEST.tmp"), "w") as f:
        f.write(str(step))
    os.replace(os.path.join(directory, "LATEST.tmp"),
               os.path.join(directory, "LATEST"))

    _gc(directory, keep_last)
    return final


def _gc(directory: str, keep_last: int) -> None:
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for s in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"), ignore_errors=True)


def latest_step(directory: str) -> int | None:
    p = os.path.join(directory, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def restore(directory: str, template: Any, *, step: int | None = None,
            shardings: Any | None = None) -> tuple[Any, int]:
    """Restore into the structure of ``template`` (pytree of arrays or
    ShapeDtypeStructs).  Returns (tree, step)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}", "arrays.msgpack")
    with open(path, "rb") as f:
        packed = msgpack.unpackb(f.read(), raw=False)

    flat_template = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(flat_template[0]))
    for (tpath, tleaf), shard in zip(flat_template[0], shard_leaves):
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in tpath)
        rec = packed[key]
        arr = np.frombuffer(rec["data"], dtype=rec["dtype"]).reshape(rec["shape"])
        want = np.dtype(tleaf.dtype)
        if arr.dtype != want:
            arr = arr.astype(want)
        leaves.append(jax.device_put(arr, shard) if shard is not None
                      else jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(flat_template[1], leaves), step
