"""Architecture registry: --arch <id> resolution for launchers and tests."""

from __future__ import annotations

from .base import (
    ArchConfig, ShapeConfig, MoEConfig, MLAConfig, SSMConfig, HybridConfig,
    EncDecConfig, SHAPES, TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K,
    applicable_shapes,
)
from .granite_3_2b import CONFIG as GRANITE_3_2B
from .glm4_9b import CONFIG as GLM4_9B
from .codeqwen15_7b import CONFIG as CODEQWEN15_7B
from .qwen2_72b import CONFIG as QWEN2_72B
from .deepseek_v2_lite_16b import CONFIG as DEEPSEEK_V2_LITE_16B
from .qwen2_moe_a27b import CONFIG as QWEN2_MOE_A27B
from .jamba_15_large_398b import CONFIG as JAMBA_15_LARGE_398B
from .whisper_large_v3 import CONFIG as WHISPER_LARGE_V3
from .qwen2_vl_72b import CONFIG as QWEN2_VL_72B
from .mamba2_13b import CONFIG as MAMBA2_13B

ARCHS: dict[str, ArchConfig] = {
    c.name: c
    for c in (
        GRANITE_3_2B, GLM4_9B, CODEQWEN15_7B, QWEN2_72B, DEEPSEEK_V2_LITE_16B,
        QWEN2_MOE_A27B, JAMBA_15_LARGE_398B, WHISPER_LARGE_V3, QWEN2_VL_72B,
        MAMBA2_13B,
    )
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


def all_cells() -> list[tuple[ArchConfig, ShapeConfig]]:
    """Every assigned (arch x shape) cell that compiles (32 cells; the 8
    long_500k full-attention cells are documented skips, DESIGN.md §4)."""
    return [(a, s) for a in ARCHS.values() for s in applicable_shapes(a)]


__all__ = [
    "ArchConfig", "ShapeConfig", "MoEConfig", "MLAConfig", "SSMConfig",
    "HybridConfig", "EncDecConfig", "SHAPES", "TRAIN_4K", "PREFILL_32K",
    "DECODE_32K", "LONG_500K", "applicable_shapes", "ARCHS", "get_arch",
    "get_shape", "all_cells",
]
