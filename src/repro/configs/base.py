"""Architecture + shape configuration.

One ``ArchConfig`` per assigned architecture (``src/repro/configs/<id>.py``),
a ``reduced()`` variant for CPU smoke tests, and the four assigned input
shapes.  Configs are plain dataclasses — no framework magic — and every
field mirrors the public source cited in the per-arch file.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    # "shared experts" are modeled as one always-on expert of width
    # num_shared * d_ff_expert (how Qwen-MoE/DeepSeek fuse them).
    num_shared: int = 0
    every_k_layers: int = 1          # MoE on layers where l % k == k-1 (jamba: 2)
    first_dense_layers: int = 0      # deepseek: layer 0 is a dense FFN
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    # expert placement: "tp" shards every expert's FFN width over 'model'
    # (no dispatch comms; baseline); "ep" shards the expert DIM over
    # 'model' (full-width experts, XLA emits the all-to-all exchange —
    # the §Perf hillclimb variant and the paper's A2A traffic source).
    impl: str = "tp"


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    q_lora_rank: int = 0             # 0 = direct q projection (V2-Lite)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64               # SSD head dim (mamba2); ignored by mamba1
    chunk: int = 256                 # SSD chunk length
    variant: str = "ssd"             # "ssd" (mamba2) | "mamba1" (jamba)


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    period: int = 8                  # jamba: 1 attention per 8 layers
    attn_index: int = 7              # position of the attention layer in period


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    num_encoder_layers: int = 32
    encoder_seq: int = 1500          # whisper: 30 s of audio after conv stub
    # the conv frontend is a stub: input_specs provides (B, encoder_seq, d_model)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    mrope_sections: Optional[tuple[int, int, int]] = None   # qwen2-vl
    sliding_window: int = 0          # >0: windowed attention (long-ctx hybrid)
    dtype: str = "bfloat16"
    source: str = ""                 # provenance: [hf:... / arXiv:...]

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """May run the long_500k cell (SSM / hybrid with windowed attn)."""
        return self.family in ("ssm", "hybrid")

    def param_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        changes: dict = dict(
            num_layers=min(self.num_layers, 4),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads < self.num_heads else 4,
            head_dim=32,
            d_ff=256,
            vocab=512,
        )
        if self.moe:
            changes["moe"] = dataclasses.replace(
                self.moe, num_experts=8, top_k=2, d_ff_expert=64,
                num_shared=min(self.moe.num_shared, 1),
            )
        if self.mla:
            changes["mla"] = dataclasses.replace(
                self.mla, kv_lora_rank=32, qk_nope_dim=32, qk_rope_dim=16,
                v_head_dim=32, q_lora_rank=0,
            )
            changes["head_dim"] = 0
        if self.ssm:
            changes["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, head_dim=16, chunk=32)
        if self.hybrid:
            changes["num_layers"] = self.hybrid.period  # one full period
        if self.encdec:
            changes["encdec"] = dataclasses.replace(
                self.encdec, num_encoder_layers=2, encoder_seq=16)
        if self.mrope_sections:
            # rescale sections to the reduced head_dim, preserving ratios
            hd = changes.get("head_dim") or changes["d_model"] // changes["num_heads"]
            total = hd // 2
            old = self.mrope_sections
            s0 = max(1, total * old[0] // sum(old))
            s1 = max(1, total * old[1] // sum(old))
            changes["mrope_sections"] = (s0, s1, total - s0 - s1)
        return dataclasses.replace(self, **changes)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES: dict[str, ShapeConfig] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


def applicable_shapes(arch: ArchConfig) -> list[ShapeConfig]:
    """The assigned cells for this arch (DESIGN.md §Arch-applicability):
    long_500k only for sub-quadratic families."""
    shapes = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if arch.sub_quadratic:
        shapes.append(LONG_500K)
    return shapes
