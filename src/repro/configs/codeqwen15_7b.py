"""codeqwen1.5-7b — dense transformer, full MHA (kv=32), QKV bias.
[hf:Qwen/CodeQwen1.5-7B; hf]
32L d_model=4096 32H (GQA kv=32) d_ff=13440 vocab=92416 — qwen1.5-arch."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="codeqwen1.5-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=13440,
    vocab=92416,
    qkv_bias=True,
    rope_theta=1000000.0,  # CodeQwen long-context rope base
    source="hf:Qwen/CodeQwen1.5-7B",
)
