"""deepseek-v2-lite-16b — MoE with Multi-head Latent Attention (MLA).
[arXiv:2405.04434; hf]
27L d_model=2048 16H d_ff(expert)=1408 vocab=102400, MLA kv_lora=512.

NOTE on the assignment line: the header says "MoE 64e top-6" while the
inline note says "2 shared+160 routed top-6".  160 routed is full
DeepSeek-V2 (236B); V2-*Lite* has 64 routed + 2 shared experts, top-6
(HF config: n_routed_experts=64, n_shared_experts=2, num_experts_per_tok=6,
moe_intermediate_size=1408, first_k_dense_replace=1, kv_lora_rank=512,
qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128).  We follow the
header + HF config (64 routed); recorded in DESIGN.md §Arch-applicability.
"""

from .base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,   # MLA: per-head KV reconstructed from the shared latent
    d_ff=10944,        # the single dense layer's FFN width (HF: intermediate_size)
    vocab=102400,
    rope_theta=10000.0,
    moe=MoEConfig(
        num_experts=64,
        top_k=6,
        d_ff_expert=1408,
        num_shared=2,
        first_dense_layers=1,
    ),
    mla=MLAConfig(
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
        q_lora_rank=0,
    ),
    source="arXiv:2405.04434 / hf:deepseek-ai/DeepSeek-V2-Lite",
)
