"""glm4-9b — dense GQA transformer with aggressive KV compression (kv=2).
[hf:THUDM/glm-4-9b; hf]
40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552 — RoPE, GQA."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="glm4-9b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab=151552,
    rope_theta=10000.0,
    qkv_bias=True,  # GLM-4 uses add_qkv_bias=True
    source="hf:THUDM/glm-4-9b",
)
