"""jamba-1.5-large-398b — hybrid Mamba+attention (1:7) with MoE (16e top-2).
[arXiv:2403.19887; hf]
72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536.

Structure (Jamba paper): blocks of 8 layers with 1 attention layer per
block (ratio 1:7); MoE replaces the dense MLP every other layer (e=16,
top-2).  Jamba uses Mamba-1 selective-scan layers (d_state=16, conv=4,
expand=2) — we keep that variant; mamba2-1.3b exercises SSD.

long_500k: runs (hybrid is sub-quadratic: mamba layers are O(1)/token and
the 9 attention layers use a sliding window at long context).
"""

from .base import ArchConfig, HybridConfig, MoEConfig, SSMConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab=65536,
    moe=MoEConfig(
        num_experts=16,
        top_k=2,
        d_ff_expert=24576,
        every_k_layers=2,   # MoE on odd layers, dense MLP on even
    ),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, variant="mamba1"),
    hybrid=HybridConfig(period=8, attn_index=7),
    sliding_window=4096,    # used by attention layers in the long_500k cell
    source="arXiv:2403.19887 / hf:ai21labs/AI21-Jamba-1.5-Large",
)
