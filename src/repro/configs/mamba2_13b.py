"""mamba2-1.3b — attention-free SSM with SSD (state-space duality).
[arXiv:2405.21060; unverified]
48L d_model=2048 d_ff=0 vocab=50280, ssm_state=128.

Pure Mamba-2: d_inner = 2*d_model = 4096, SSD head_dim=64 -> 64 heads,
d_state=128, chunked SSD with chunk=256.  No attention, no FFN (the Mamba
block IS the layer).  All four shapes run, including long_500k (O(1)
state per decoded token).
"""

from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=64,        # SSD heads = d_inner / head_dim
    num_kv_heads=64,
    d_ff=0,
    vocab=50280,
    norm_eps=1e-5,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256,
                  variant="ssd"),
    source="arXiv:2405.21060",
)
