"""qwen2-moe-a2.7b — MoE: 60 routed top-4 + shared expert (4x1408 fused).
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
24L d_model=2048 16H (kv=16) d_ff(expert)=1408 vocab=151936."""

from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=5632,         # shared-expert width (= 4 x 1408, per HF config)
    vocab=151936,
    qkv_bias=True,
    rope_theta=1000000.0,
    moe=MoEConfig(
        num_experts=60,
        top_k=4,
        d_ff_expert=1408,
        num_shared=4,   # fused shared expert of width 4*1408=5632
    ),
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)
