"""qwen2-vl-72b — VLM backbone (qwen2-72b body + M-RoPE).
[arXiv:2409.12191; hf]
80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.

The vision frontend (dynamic-resolution ViT) is a STUB per the
assignment: input_specs() provides precomputed patch/token embeddings
(B, S, d_model) plus M-RoPE position ids (3, B, S) = (temporal, height,
width) streams; mrope_section=[16, 24, 24] half-dims as in the HF config.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1000000.0,
    mrope_sections=(16, 24, 24),
    source="arXiv:2409.12191 / hf:Qwen/Qwen2-VL-72B-Instruct",
)
