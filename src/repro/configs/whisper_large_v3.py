"""whisper-large-v3 — encoder-decoder audio transformer (backbone only).
[arXiv:2212.04356; unverified]
32L d_model=1280 20H (kv=20) d_ff=5120 vocab=51866 — enc-dec.

The conv frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings (B, 1500, d_model) for the encoder.  The
decoder is a standard pre-LN causal transformer with cross-attention.
Whisper uses learned positions + LayerNorm; we keep LN but use RoPE-free
absolute positions for the backbone (positions are part of the stub).
long_500k is SKIPPED (full attention).  decode_* runs (enc-dec has a
decoder; only encoder-only archs skip decode).
"""

from .base import ArchConfig, EncDecConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="encdec",
    num_layers=32,          # decoder layers
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab=51866,
    norm_eps=1e-5,
    encdec=EncDecConfig(num_encoder_layers=32, encoder_seq=1500),
    source="arXiv:2212.04356",
)
