"""FlowTracer core: the paper's primary contribution.

Fabric model + ECMP/static routing + Flow Imbalance Metric + the parallel
hop-by-hop path-discovery algorithm + compiled-HLO flow extraction +
topology-aware placement.  Importing the package stays jax-free so tracer
worker processes remain lightweight: the device engine
(``core.jax_engine``, selected via ``engine="jax"`` on the Monte-Carlo
front ends) imports jax lazily, only when actually asked to run.
"""

from .fabric import (
    Fabric, Link, Device, build_paper_testbed, build_multipod_fabric,
    nic_ip, server_name,
    HOST_TO_LEAF, LEAF_TO_SPINE, SPINE_TO_LEAF, LEAF_TO_HOST,
)
from .flows import (
    Flow, FiveTuple, PairSpec, WorkloadDescription, synthesize_flows,
    bipartite_pairs, workload_from_flows,
)
from .ecmp import (
    EcmpRouting, StaticRouting, RoutingPolicy, Forwarder, ecmp_hash,
    device_seed, flow_hash_fields, flow_fields_matrix,
    FIELDS_5TUPLE, FIELDS_VXLAN, FIELDS_IP_PAIR,
)
from .compile_fabric import CompiledFabric, compile_fabric
from .contracts import CONTRACTS_ENV, ContractViolation, contracts_enabled
from .vector_sim import (
    VectorTraceResult, MonteCarloFim, SimSpec, simulate_paths,
    fim_from_counts, fim_vector, monte_carlo_fim, resolve_flows,
    DEMAND_UNIFORM, DEMAND_BYTES, flow_demand_weights,
    ENGINE_NUMPY, ENGINE_JAX, resolve_hash_backend,
    TIMING_STATIC, TIMING_EVENT,
)
from .vector_throughput import (
    MonteCarloThroughput, batched_max_min, max_min_rates,
    flow_rates_from_flowlets, pair_rate_matrix, throughput_from_result,
    monte_carlo_throughput, DepartureFill, departure_fill,
)
from .strategies import (
    RoutingStrategy, EcmpStrategy, PrimeSpraying, AdaptiveSpraying,
    CongestionAware, WaveCongestionAware,
    register_strategy, resolve_strategy, available_strategies,
    ELEPHANT_MIN_BYTES,
)
from .reordering import (
    TransportProfile, IDEAL, ROCE_NACK, STRACK,
    ROCE_NACK_ANCHORS, STRACK_ANCHORS, calibrate_transport,
    register_transport, resolve_transport, available_transports,
    flowlet_exposure, reordering_efficiency,
    DEFAULT_RTT_SECONDS, rtt_round_budget,
)
from .timeline import (
    TimelineStep, TimelineResult, StepResult, simulate_timeline,
    merged_step, partition_flows, flow_channel,
    register_channel, known_channels, channel_name, step_byte_totals,
)
from .fim import (
    fim, per_layer_fim, link_flow_counts, max_min_throughput,
    per_pair_throughput, layer_load_stats, LayerLoadStats,
)
from .tracer import (
    FlowTracer, TraceResult, LatencyModel, ConnectionManager, DeviceChannel,
    ADHOC, PERSISTENT, auto_processes,
)
from .hlo_flows import (
    CollectiveOp, extract_collectives, summarize, collectives_to_flows,
    shape_bytes, CollectiveSummary, EdgeClassCounts, wire_and_operand,
)
from .llm_workload import (
    LlmJobSpec, llm_collective_ops, llm_flows, llm_workload,
    paper_testbed_llm_workload, multipod_llm_workload,
    llm_collective_phases, llm_schedule,
    paper_testbed_llm_schedule, multipod_llm_schedule,
    SCHEDULE_SEQUENTIAL, SCHEDULE_DP_OVERLAP,
    CH_GRAD_AR, CH_FSDP_AG, CH_FSDP_RS, CH_MOE_A2A, CH_BARRIER,
)
from .placement import (
    static_route_assignment, topology_aware_ring, ring_edge_stats,
    balanced_port_spread,
)
from .report import analyze_paths, PathReport

__all__ = [
    "Fabric", "Link", "Device", "build_paper_testbed", "build_multipod_fabric",
    "nic_ip", "server_name",
    "HOST_TO_LEAF", "LEAF_TO_SPINE", "SPINE_TO_LEAF", "LEAF_TO_HOST",
    "Flow", "FiveTuple", "PairSpec", "WorkloadDescription", "synthesize_flows",
    "bipartite_pairs", "workload_from_flows",
    "EcmpRouting", "StaticRouting", "RoutingPolicy", "Forwarder", "ecmp_hash",
    "device_seed", "flow_hash_fields", "flow_fields_matrix",
    "FIELDS_5TUPLE", "FIELDS_VXLAN", "FIELDS_IP_PAIR",
    "CompiledFabric", "compile_fabric",
    "CONTRACTS_ENV", "ContractViolation", "contracts_enabled",
    "VectorTraceResult", "MonteCarloFim", "SimSpec", "simulate_paths",
    "fim_from_counts", "fim_vector", "monte_carlo_fim", "resolve_flows",
    "DEMAND_UNIFORM", "DEMAND_BYTES", "flow_demand_weights",
    "ENGINE_NUMPY", "ENGINE_JAX", "resolve_hash_backend",
    "TIMING_STATIC", "TIMING_EVENT",
    "MonteCarloThroughput", "batched_max_min", "max_min_rates",
    "flow_rates_from_flowlets", "pair_rate_matrix", "throughput_from_result",
    "monte_carlo_throughput", "DepartureFill", "departure_fill",
    "RoutingStrategy", "EcmpStrategy", "PrimeSpraying", "AdaptiveSpraying",
    "CongestionAware", "WaveCongestionAware",
    "register_strategy", "resolve_strategy", "available_strategies",
    "ELEPHANT_MIN_BYTES",
    "TransportProfile", "IDEAL", "ROCE_NACK", "STRACK",
    "ROCE_NACK_ANCHORS", "STRACK_ANCHORS", "calibrate_transport",
    "register_transport", "resolve_transport", "available_transports",
    "flowlet_exposure", "reordering_efficiency",
    "DEFAULT_RTT_SECONDS", "rtt_round_budget",
    "TimelineStep", "TimelineResult", "StepResult", "simulate_timeline",
    "merged_step", "partition_flows", "flow_channel",
    "register_channel", "known_channels", "channel_name", "step_byte_totals",
    "fim", "per_layer_fim", "link_flow_counts", "max_min_throughput",
    "per_pair_throughput", "layer_load_stats", "LayerLoadStats",
    "FlowTracer", "TraceResult", "LatencyModel", "ConnectionManager",
    "DeviceChannel", "ADHOC", "PERSISTENT", "auto_processes",
    "CollectiveOp", "extract_collectives", "summarize", "collectives_to_flows",
    "shape_bytes", "CollectiveSummary", "EdgeClassCounts", "wire_and_operand",
    "LlmJobSpec", "llm_collective_ops", "llm_flows", "llm_workload",
    "paper_testbed_llm_workload", "multipod_llm_workload",
    "llm_collective_phases", "llm_schedule",
    "paper_testbed_llm_schedule", "multipod_llm_schedule",
    "SCHEDULE_SEQUENTIAL", "SCHEDULE_DP_OVERLAP",
    "CH_GRAD_AR", "CH_FSDP_AG", "CH_FSDP_RS", "CH_MOE_A2A", "CH_BARRIER",
    "static_route_assignment", "topology_aware_ring", "ring_edge_stats",
    "balanced_port_spread",
    "analyze_paths", "PathReport",
]
