"""Compile a ``Fabric`` + forwarding logic into dense arrays.

The hop-by-hop tracer asks ``Forwarder.candidates(device, flow)`` at every
hop — a Python dict walk per flow per hop.  For Monte-Carlo sweeps over
thousands of hash seeds that is the bottleneck, so we compile the fabric
once into integer tables the vectorized engine (``vector_sim``) can index
with whole arrays:

* every device gets an id, a ``crc32(name)`` (the per-switch hash-seed
  component of ``EcmpRouting``), and a server/switch flag;
* every link gets an id plus dst-device / layer / capacity columns;
* the equal-cost candidate set at ``(device, flow)`` depends only on the
  device and one *NIC key* — the flow's **src** (server, nic) while the
  packet is on the source host, its **dst** (server, nic) everywhere else
  (Clos forwarding is destination-routed).  So all candidate sets live in
  one padded ``(V, K, C_max)`` table of link ids, built by calling the
  real ``Forwarder`` per (device, key) so candidate *order* — which the
  hash indexes into — is identical to the Python path by construction.

Compilation is O(V*K) and done once per fabric; every simulated flow and
seed afterwards is pure array indexing.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .ecmp import Forwarder, _crc
from .fabric import Fabric, Link, SERVER, nic_ip
from .flows import FiveTuple, Flow


@dataclasses.dataclass(frozen=True)
class CompiledFabric:
    """Dense-array view of a fabric, consumed by ``vector_sim``."""

    fabric: Fabric
    # devices
    device_names: list[str]         # device id -> name
    device_id: dict[str, int]       # name -> device id
    dev_crc: np.ndarray             # (V,) uint64  crc32(name)
    is_server: np.ndarray           # (V,) bool
    # links
    links: list[Link]               # link id -> Link
    link_src: np.ndarray            # (L,) int32  src device id
    link_dst: np.ndarray            # (L,) int32  dst device id
    link_layer: np.ndarray          # (L,) int32  layer id
    layer_names: list[str]          # layer id -> name (fabric.layers order)
    link_gbps: np.ndarray           # (L,) float64
    # NIC keys: one per (server, nic index), i.e. one per NIC IP
    key_of_ip: dict[str, int]       # nic ip -> key id
    key_server: np.ndarray          # (K,) int32  device id owning the key
    #: distinct NIC indices present on the fabric's servers, sorted — the
    #: authoritative record of the NIC plan (``resolve_flows`` synthesizes
    #: against it; sparse numbering like (0, 4) survives, where re-parsing
    #: IP strings for a max would invent NICs that do not exist)
    nic_indices: tuple[int, ...]
    # candidate tables
    cand: np.ndarray                # (V, K, C_max) int32 link ids, -1 padded
    cand_n: np.ndarray              # (V, K) int32  candidate count

    @property
    def num_devices(self) -> int:
        return len(self.device_names)

    @property
    def num_links(self) -> int:
        return len(self.links)

    def flow_endpoint_ids(
        self, flows,
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Per-flow (src device id, dst device id, src key, dst key)."""
        src_dev = np.array([self.device_id[f.src] for f in flows], np.int32)
        dst_dev = np.array([self.device_id[f.dst] for f in flows], np.int32)
        src_key = np.array(
            [self.key_of_ip[f.tuple5.src_ip] for f in flows], np.int32)
        dst_key = np.array(
            [self.key_of_ip[f.tuple5.dst_ip] for f in flows], np.int32)
        return src_dev, dst_dev, src_key, dst_key


def compile_fabric(fabric: Fabric) -> CompiledFabric:
    fwd = Forwarder(fabric)
    device_names = list(fabric.devices)
    device_id = {name: i for i, name in enumerate(device_names)}
    dev_crc = np.array([_crc(n) for n in device_names], np.uint64)
    is_server = np.array(
        [fabric.kind(n) == SERVER for n in device_names], bool)

    links = list(fabric.links)
    link_id = {ln.name: i for i, ln in enumerate(links)}
    layer_names = fabric.layers
    layer_id = {name: i for i, name in enumerate(layer_names)}
    link_src = np.array([device_id[ln.src] for ln in links], np.int32)
    link_dst = np.array([device_id[ln.dst] for ln in links], np.int32)
    link_layer = np.array([layer_id[ln.layer] for ln in links], np.int32)
    link_gbps = np.array([ln.gbps for ln in links], np.float64)

    # NIC keys, in deterministic (server name, nic index) order.
    nic_keys = sorted(fwd._server_nic_links)
    key_of_ip = {nic_ip(srv, nic): k for k, (srv, nic) in enumerate(nic_keys)}
    key_server = np.array(
        [device_id[srv] for srv, _ in nic_keys], np.int32)

    # Candidate table: ask the real Forwarder per (device, key) so both the
    # membership and the order of every equal-cost set match the tracer.
    V, K = len(device_names), len(nic_keys)
    per_cell: list[list[list[int]]] = [[[] for _ in range(K)] for _ in range(V)]
    c_max = 1
    for k, (srv, nic) in enumerate(nic_keys):
        ip = nic_ip(srv, nic)
        probe = Flow(flow_id=-1, src=srv, dst=srv,
                     tuple5=FiveTuple(ip, ip, 0, 0))
        for v, dev in enumerate(device_names):
            if is_server[v]:
                # Only the flow's own source host ever forwards on src key.
                if dev != srv:
                    continue
                cands = fwd.candidates(dev, probe)
            else:
                cands = fwd.candidates(dev, probe)  # dst-keyed at switches
            ids = [link_id[c.name] for c in cands]
            per_cell[v][k] = ids
            c_max = max(c_max, len(ids))

    cand = np.full((V, K, c_max), -1, np.int32)
    cand_n = np.zeros((V, K), np.int32)
    for v in range(V):
        for k in range(K):
            ids = per_cell[v][k]
            cand_n[v, k] = len(ids)
            if ids:
                cand[v, k, : len(ids)] = ids

    return CompiledFabric(
        fabric=fabric,
        device_names=device_names,
        device_id=device_id,
        dev_crc=dev_crc,
        is_server=is_server,
        links=links,
        link_src=link_src,
        link_dst=link_dst,
        link_layer=link_layer,
        layer_names=layer_names,
        link_gbps=link_gbps,
        key_of_ip=key_of_ip,
        key_server=key_server,
        nic_indices=tuple(sorted({nic for _, nic in nic_keys})),
        cand=cand,
        cand_n=cand_n,
    )
