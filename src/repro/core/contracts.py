"""Opt-in runtime contract checks for the simulation seams.

``FLOWTRACER_CONTRACTS=1`` arms cheap shape/dtype/finiteness assertions
at the three places every simulation flows through — ``resolve_spec``
(the front-end glue), ``simulate_paths`` (the routed tensor), and
``throughput_from_result`` (the rate aggregation).  They are the
*runtime* half of the flowcheck story (``repro.analysis``): the static
analyzer proves the call sites stay consistent; contract mode proves
the arrays that actually crossed the seam look like the docstrings say.

Off by default and read from the environment on every call, so a test
can flip it with ``monkeypatch.setenv`` — no import-order trap.  The
checks are linear scans of already-materialized arrays (no copies, no
device syncs beyond what a consumer would force anyway), sized to run a
full tier-1 shard without noticeable cost.

Violations raise ``ContractViolation`` (an ``AssertionError`` subclass,
so ``pytest.raises(AssertionError)`` also matches) naming the seam and
the invariant.
"""

from __future__ import annotations

import os

import numpy as np

CONTRACTS_ENV = "FLOWTRACER_CONTRACTS"

_OFF = ("", "0", "false", "off", "no")


class ContractViolation(AssertionError):
    """A runtime contract at a simulation seam did not hold."""


def contracts_enabled() -> bool:
    """True when ``FLOWTRACER_CONTRACTS`` is set to anything truthy."""
    return os.environ.get(CONTRACTS_ENV, "").strip().lower() not in _OFF


def _fail(seam: str, invariant: str) -> None:
    raise ContractViolation(f"[{CONTRACTS_ENV}] {seam}: {invariant}")


def check_spec(s) -> None:
    """Post-conditions of ``resolve_spec``: the spec is *resolved* —
    every engine-coupled default concretized, scalars validated."""
    seam = "resolve_spec"
    if not (isinstance(s.max_hops, int) and s.max_hops >= 1):
        _fail(seam, f"resolved max_hops must be an int >= 1, "
                    f"got {s.max_hops!r}")
    if s.hash_backend is None:
        _fail(seam, "resolved spec left hash_backend unset (resolve() "
                    "must concretize the engine-coupled default)")
    if s.fields is None:
        _fail(seam, "resolved spec left fields unset")
    if isinstance(s.strategy, str):
        _fail(seam, f"resolved spec left strategy as the name string "
                    f"{s.strategy!r} (resolve() must look it up)")
    if isinstance(s.transport, str) and s.transport != "ideal":
        _fail(seam, f"resolved spec left transport as the name string "
                    f"{s.transport!r}")


def check_trace_result(res) -> None:
    """Post-conditions of ``simulate_paths``: the routed tensor is a
    well-formed ``VectorTraceResult`` (shapes agree, link ids in range,
    flowlet demands positive and summing to 1 per parent flow)."""
    seam = "simulate_paths"
    ids = res.link_ids
    if ids.ndim != 3:
        _fail(seam, f"link_ids must be (H, Nf, S), got shape {ids.shape}")
    if not np.issubdtype(ids.dtype, np.integer):
        _fail(seam, f"link_ids must be an integer tensor, got {ids.dtype}")
    num_links = res.compiled.num_links
    lo, hi = int(ids.min()), int(ids.max())
    if lo < -1 or hi >= num_links:
        _fail(seam, f"link ids must lie in [-1, {num_links}), "
                    f"got range [{lo}, {hi}]")
    _h, nf, s_dim = ids.shape
    if res.seeds.shape != (s_dim,):
        _fail(seam, f"seeds shape {res.seeds.shape} does not match the "
                    f"link_ids seed axis ({s_dim})")
    n = res.num_flows
    fi = res.flow_index
    if fi.shape != (nf,):
        _fail(seam, f"flow_index shape {fi.shape} does not match the "
                    f"flowlet axis ({nf})")
    if nf and (fi.min() < 0 or fi.max() >= n):
        _fail(seam, f"flow_index must name parent rows in [0, {n}), "
                    f"got range [{fi.min()}, {fi.max()}]")
    dem = res.demand
    if dem.shape != (nf,):
        _fail(seam, f"demand shape {dem.shape} does not match the "
                    f"flowlet axis ({nf})")
    if not (np.isfinite(dem).all() and (dem > 0).all()):
        _fail(seam, "flowlet demand fractions must be finite and > 0")
    per_flow = np.zeros(n)
    np.add.at(per_flow, fi, dem)
    if not np.allclose(per_flow, 1.0):
        _fail(seam, "flowlet demand fractions must sum to 1 per parent "
                    f"flow (worst deviation {abs(per_flow - 1).max():.3g})")
    fd = res.flow_demand
    if fd.shape != (n,):
        _fail(seam, f"flow_demand shape {fd.shape} must be ({n},)")
    if not (np.isfinite(fd).all() and (fd >= 0).all()):
        _fail(seam, "flow_demand weights must be finite and >= 0")
    if res.extra_exposure is not None:
        ex = res.extra_exposure
        if ex.shape != (n, s_dim):
            _fail(seam, f"extra_exposure shape {ex.shape} must be "
                        f"({n}, {s_dim})")
        if not (np.isfinite(ex).all() and (ex >= 0).all()):
            _fail(seam, "extra_exposure must be finite and >= 0")


def check_throughput(tp) -> None:
    """Post-conditions of ``throughput_from_result``: finite non-negative
    rates, efficiency in (0, 1], and goodput = rates x efficiency."""
    seam = "throughput_from_result"
    n, s_dim = len(tp.flows), len(tp.seeds)
    if tp.rates.shape != (n, s_dim):
        _fail(seam, f"rates shape {tp.rates.shape} must be "
                    f"({n}, {s_dim})")
    if not (np.isfinite(tp.rates).all() and (tp.rates >= 0).all()):
        _fail(seam, "rates must be finite and >= 0")
    if len(tp.pairs) != tp.per_pair.shape[0] \
            or tp.per_pair.shape[1] != s_dim:
        _fail(seam, f"per_pair shape {tp.per_pair.shape} must be "
                    f"({len(tp.pairs)}, {s_dim})")
    if not np.isfinite(tp.per_pair).all():
        _fail(seam, "per-pair rates must be finite")
    eff = tp.efficiency
    if not ((eff > 0) & (eff <= 1.0)).all():
        _fail(seam, "efficiency must lie in (0, 1]")
    if not (np.isfinite(tp.exposure).all() and (tp.exposure >= 0).all()):
        _fail(seam, "exposure must be finite and >= 0")
    if not np.allclose(tp.goodput, tp.rates * eff):
        _fail(seam, "goodput must equal rates x efficiency")
