"""Routing policies: ECMP hashing (with VXLAN entropy reduction) and
preprogrammed static routing.

Every forwarding decision in the fabric is a choice among a set of
equal-cost egress links.  ``EcmpRouting`` picks by hashing flow headers —
per switch, with a per-switch seed, exactly how real fabrics behave (and
why collisions differ hop to hop).  ``StaticRouting`` consults a
preprogrammed table (the paper's second configuration).

The hash is a deterministic integer mix (splitmix64 finalizer) over CRC32s
of the header fields — stable across runs and processes, unlike Python's
salted ``hash``.
"""

from __future__ import annotations

import dataclasses
import zlib
from collections.abc import Sequence

from .fabric import Fabric, Link, LEAF, SERVER, SPINE
from .flows import Flow

_MASK = (1 << 64) - 1

# Hash-field presets.  VXLAN encapsulation hides the inner 5-tuple from
# transit switches; entropy survives only via the outer UDP source port
# (derived from an inner-header hash) — fewer effective fields, more
# collisions (paper Section II).
FIELDS_5TUPLE = "5tuple"
FIELDS_VXLAN = "vxlan"
FIELDS_IP_PAIR = "ip-pair"


def _mix64(x: int) -> int:
    x &= _MASK
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _MASK
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _MASK
    return (x ^ (x >> 31)) & _MASK


def _crc(s: str) -> int:
    return zlib.crc32(s.encode())


HASH_INIT = 0x9E3779B97F4A7C15


def ecmp_hash(fields: Sequence[int], seed: int) -> int:
    h = _mix64(seed ^ HASH_INIT)
    for f in fields:
        h = _mix64(h ^ (f & _MASK))
    return h


def device_seed(device: str, seed: int) -> int:
    """The effective per-switch hash seed: every device salts the shared
    run seed with a stable digest of its own name (real switches differ in
    per-ASIC seeds the same way — that is why collisions differ hop to
    hop)."""
    return _crc(device) ^ seed


def flow_hash_fields(flow: Flow, mode: str) -> list[int]:
    t = flow.tuple5
    if mode == FIELDS_5TUPLE:
        return [_crc(t.src_ip), _crc(t.dst_ip), t.src_port, t.dst_port, t.protocol]
    if mode == FIELDS_VXLAN:
        # Outer header: (outer src ip, outer dst ip, outer UDP sport).  The
        # sport is the VTEP's hash of the inner 5-tuple folded to 14 bits.
        inner = ecmp_hash(
            [_crc(t.src_ip), _crc(t.dst_ip), t.src_port, t.dst_port, t.protocol],
            seed=0x564C414E,  # "VLAN"
        )
        return [_crc(t.src_ip), _crc(t.dst_ip), inner % 16384]
    if mode == FIELDS_IP_PAIR:
        return [_crc(t.src_ip), _crc(t.dst_ip)]
    raise ValueError(f"unknown hash-field mode: {mode}")


def flow_fields_matrix(flows: Sequence[Flow], mode: str):
    """Integer hash fields for many flows as a dense ``(N, F)`` uint64
    array — the batched twin of ``flow_hash_fields`` (identical values),
    consumed by ``vector_sim``.  Imported lazily so the tracer stays
    numpy-free."""
    import numpy as np

    return np.array(
        [flow_hash_fields(f, mode) for f in flows], np.uint64
    ).reshape(len(flows), -1)


# ---------------------------------------------------------------------------
# Candidate-set computation (the "equal cost" part of ECMP)
# ---------------------------------------------------------------------------


class Forwarder:
    """Computes the equal-cost candidate egress set at each device.

    This encodes the L3 Clos forwarding logic shared by both policies:
      * server:  LAG over the ports of the NIC owning the flow's src ip;
      * leaf:    if the dst NIC is locally attached -> LAG down to it,
                 otherwise ECMP over all uplinks (any spine reaches any leaf);
      * spine:   ECMP over the links to the leaf behind the dst NIC.
    """

    def __init__(self, fabric: Fabric):
        self.fabric = fabric
        # dst_ip -> (server, nic index) -> attachment leaf + ports.
        self._ip_attach: dict[str, tuple[str, str, list[Link]]] = {}
        self._server_nic_links: dict[tuple[str, int], list[Link]] = {}
        for ln in fabric.links:
            if fabric.kind(ln.src) == SERVER and ln.src_port.startswith("nic"):
                nic = int(ln.src_port[3 : ln.src_port.index("p")])
                self._server_nic_links.setdefault((ln.src, nic), []).append(ln)

    def _nic_of_ip(self, ip: str) -> tuple[str, int]:
        # 10.<nic>.<hi>.<lo> (fabric.nic_ip) — server index from last octets.
        parts = ip.split(".")
        nic = int(parts[1])
        idx = int(parts[2]) * 256 + int(parts[3])
        for prefix in ("srv-", "host-"):
            name = f"{prefix}{idx}"
            if name in self.fabric.devices:
                return name, nic
        raise KeyError(f"no server for ip {ip}")

    def attachment_leaf(self, ip: str) -> str:
        server, nic = self._nic_of_ip(ip)
        links = self._server_nic_links[(server, nic)]
        return links[0].dst  # both LAG ports land on the same leaf

    def candidates(self, device: str, flow: Flow) -> list[Link]:
        fab = self.fabric
        kind = fab.kind(device)
        if kind == SERVER:
            server, nic = self._nic_of_ip(flow.tuple5.src_ip)
            assert server == device, (server, device, "flow must start at src")
            return sorted(self._server_nic_links[(device, nic)],
                          key=lambda l: l.src_port)
        dst_server, dst_nic = self._nic_of_ip(flow.tuple5.dst_ip)
        dst_leaf = self.attachment_leaf(flow.tuple5.dst_ip)
        if kind == LEAF:
            if device == dst_leaf:  # LAG down to the dst NIC's ports
                down = [
                    l for l in fab.links_between(device, dst_server)
                    if l.dst_port.startswith(f"nic{dst_nic}p")
                ]
                return sorted(down, key=lambda l: l.src_port)
            ups = [l for l in fab.egress_links(device) if fab.kind(l.dst) == SPINE]
            return sorted(ups, key=lambda l: (l.dst, l.src_port))
        if kind == SPINE:
            downs = fab.links_between(device, dst_leaf)
            return sorted(downs, key=lambda l: l.src_port)
        raise ValueError(f"unknown device kind {kind}")


# ---------------------------------------------------------------------------
# Policies
# ---------------------------------------------------------------------------


class RoutingPolicy:
    """Interface: the forwarding decision a device would reveal via its
    hash-visibility CLI (switches) or driver/route table (servers)."""

    def egress(self, device: str, flow: Flow, ingress_port: str | None) -> Link:
        raise NotImplementedError


@dataclasses.dataclass
class EcmpRouting(RoutingPolicy):
    fabric: Fabric
    seed: int = 0
    fields: str = FIELDS_5TUPLE

    def __post_init__(self):
        self.forwarder = Forwarder(self.fabric)

    def egress(self, device: str, flow: Flow, ingress_port: str | None) -> Link:
        cands = self.forwarder.candidates(device, flow)
        if len(cands) == 1:
            return cands[0]
        h = ecmp_hash(flow_hash_fields(flow, self.fields),
                      device_seed(device, self.seed))
        return cands[h % len(cands)]


class StaticRouting(RoutingPolicy):
    """Preprogrammed routing: an explicit (device, flow) -> egress-port map,
    as produced by placement.static_route_assignment.  Falls back to the
    single candidate when no choice exists."""

    def __init__(self, fabric: Fabric, table: dict[tuple[str, int], str]):
        self.fabric = fabric
        self.forwarder = Forwarder(fabric)
        self.table = table  # (device, flow_id) -> src_port

    def egress(self, device: str, flow: Flow, ingress_port: str | None) -> Link:
        port = self.table.get((device, flow.flow_id))
        if port is not None:
            return self.fabric.link_from_port(device, port)
        cands = self.forwarder.candidates(device, flow)
        if len(cands) != 1:
            raise KeyError(
                f"static table has no entry for ({device}, flow {flow.flow_id}) "
                f"and {len(cands)} candidates exist"
            )
        return cands[0]
