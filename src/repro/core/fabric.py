"""Fabric model: devices, links, and topology builders.

This is the "network topology file" of the paper (Section III-A): it lists
every device, every interface, and how interfaces connect.  The tracer uses
it to map the egress interface reported by one device to the ingress
interface of the next.

Two families of fabrics are modeled:

* ``build_paper_testbed`` — the paper's 2-rack RoCEv2 cluster: 16 servers
  (2 dual-port 100G NICs each, one NIC per ToR), 4 leaf switches
  (3.2 Tb/s), 4 spine switches (1.6 Tb/s), 4x100G links per leaf-spine
  pair.  256 bipartite flows -> ideal 4 flows per link on every layer.
* ``build_multipod_fabric`` — the TPU adaptation: pods of hosts whose
  inter-pod (DCN) traffic crosses an Ethernet leaf-spine Clos with ECMP,
  which is exactly the regime the paper studies.  Intra-pod ICI links are
  modeled separately with deterministic routing (no hash decisions).
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from collections.abc import Sequence

SERVER = "server"
LEAF = "leaf"
SPINE = "spine"

# Link layers used for FIM grouping (paper Fig. 3(b,c) subplots).
HOST_TO_LEAF = "host-to-leaf"
LEAF_TO_SPINE = "leaf-to-spine"
SPINE_TO_LEAF = "spine-to-leaf"
LEAF_TO_HOST = "leaf-to-host"


@dataclasses.dataclass(frozen=True, slots=True)
class Link:
    """A unidirectional link between two device ports."""

    src: str
    src_port: str
    dst: str
    dst_port: str
    gbps: float
    layer: str

    @property
    def name(self) -> str:
        return f"{self.src}:{self.src_port}->{self.dst}:{self.dst_port}"


@dataclasses.dataclass(frozen=True, slots=True)
class Device:
    name: str
    kind: str  # server | leaf | spine
    rack: int | None = None
    pod: int | None = None


class Fabric:
    """Topology file + adjacency helpers (paper Section III-A)."""

    def __init__(self, devices: Sequence[Device], links: Sequence[Link]):
        self.devices: dict[str, Device] = {d.name: d for d in devices}
        self.links: list[Link] = list(links)
        self._egress: dict[str, list[Link]] = defaultdict(list)
        self._by_pair: dict[tuple[str, str], list[Link]] = defaultdict(list)
        self._by_src_port: dict[tuple[str, str], Link] = {}
        for ln in self.links:
            self._egress[ln.src].append(ln)
            self._by_pair[(ln.src, ln.dst)].append(ln)
            self._by_src_port[(ln.src, ln.src_port)] = ln

    # -- queries used by the tracer ---------------------------------------
    def egress_links(self, device: str) -> list[Link]:
        return self._egress[device]

    def links_between(self, src: str, dst: str) -> list[Link]:
        return self._by_pair.get((src, dst), [])

    def link_from_port(self, device: str, port: str) -> Link:
        """Topology-file lookup: egress interface -> the link it drives ->
        the next device's ingress interface (paper Section III-B.2)."""
        return self._by_src_port[(device, port)]

    def kind(self, device: str) -> str:
        return self.devices[device].kind

    def links_by_layer(self, layer: str) -> list[Link]:
        return [ln for ln in self.links if ln.layer == layer]

    @property
    def layers(self) -> list[str]:
        seen: list[str] = []
        for ln in self.links:
            if ln.layer not in seen:
                seen.append(ln.layer)
        return seen

    # -- (de)serialization: the literal "topology file" -------------------
    def to_json(self) -> dict:
        return {
            "devices": [dataclasses.asdict(d) for d in self.devices.values()],
            "links": [dataclasses.asdict(l) for l in self.links],
        }

    @classmethod
    def from_json(cls, obj: dict) -> "Fabric":
        return cls(
            [Device(**d) for d in obj["devices"]],
            [Link(**l) for l in obj["links"]],
        )


# ---------------------------------------------------------------------------
# NIC addressing helpers
# ---------------------------------------------------------------------------

def nic_ip(server: str, nic: int) -> str:
    """Deterministic per-NIC IP.  Each dual-port NIC owns one IP; the two
    ports of a NIC form a LAG into a single leaf (so the leaf's downlink
    choice is a 2-way hash — the paper's 4th cross-rack ECMP decision)."""
    idx = int(server.split("-")[-1])
    return f"10.{nic}.{idx // 256}.{idx % 256}"


def server_name(i: int) -> str:
    return f"srv-{i}"


# ---------------------------------------------------------------------------
# Paper testbed (Fig. 2a)
# ---------------------------------------------------------------------------

def build_paper_testbed(
    *,
    num_racks: int = 2,
    servers_per_rack: int = 8,
    leaves_per_rack: int = 2,
    num_spines: int = 4,
    links_per_leaf_spine: int = 4,
    link_gbps: float = 100.0,
    ports_per_nic: int = 2,
) -> Fabric:
    """The paper's 2-rack testbed.

    Derivation from the paper's numbers: 4 leaves x 4 spines x 4 links
    = 64 leaf->spine links; 256 bipartite flows / 64 links = the paper's
    "4 flows per link for a perfectly balanced distribution".  Every server
    has two dual-port 100G NICs (400 Gb/s total); NIC k LAGs its two ports
    into leaf k of the rack.
    """
    devices: list[Device] = []
    links: list[Link] = []

    spines = [f"spine-{s}" for s in range(num_spines)]
    devices += [Device(s, SPINE) for s in spines]

    for r in range(num_racks):
        leaves = [f"leaf-{r * leaves_per_rack + l}" for l in range(leaves_per_rack)]
        devices += [Device(l, LEAF, rack=r) for l in leaves]

        for s in range(servers_per_rack):
            i = r * servers_per_rack + s
            srv = server_name(i)
            devices.append(Device(srv, SERVER, rack=r))
            for nic in range(leaves_per_rack):  # NIC k -> leaf k (LAG of 2 ports)
                leaf = leaves[nic]
                for p in range(ports_per_nic):
                    links.append(
                        Link(srv, f"nic{nic}p{p}", leaf, f"host-{srv}-{nic}-{p}",
                             link_gbps, HOST_TO_LEAF)
                    )
                    links.append(
                        Link(leaf, f"down-{srv}-{nic}-{p}", srv, f"nic{nic}p{p}",
                             link_gbps, LEAF_TO_HOST)
                    )
        for leaf in leaves:
            for spine in spines:
                for k in range(links_per_leaf_spine):
                    links.append(
                        Link(leaf, f"up-{spine}-{k}", spine, f"in-{leaf}-{k}",
                             link_gbps, LEAF_TO_SPINE)
                    )
                    links.append(
                        Link(spine, f"down-{leaf}-{k}", leaf, f"spinein-{spine}-{k}",
                             link_gbps, SPINE_TO_LEAF)
                    )
    return Fabric(devices, links)


# ---------------------------------------------------------------------------
# Multi-pod TPU DCN fabric (hardware adaptation — DESIGN.md section 2)
# ---------------------------------------------------------------------------

def build_multipod_fabric(
    *,
    num_pods: int = 2,
    hosts_per_pod: int = 64,
    leaves_per_pod: int = 4,
    num_spines: int = 8,
    links_per_leaf_spine: int = 4,
    host_link_gbps: float = 100.0,
    fabric_link_gbps: float = 400.0,
    nics_per_host: int = 1,
    ports_per_nic: int = 2,
) -> Fabric:
    """DCN fabric connecting TPU pods.

    Each pod is a "rack" of hosts (a host fronts 4 TPU chips on v5e).
    Inter-pod collective traffic — the flows on the ``pod`` mesh axis —
    crosses leaf -> spine -> leaf with an ECMP decision at each stage,
    i.e. the exact hash-collision regime of the paper.  Intra-pod ICI is
    NOT part of this fabric (deterministic torus; see hlo_flows.py).
    """
    devices: list[Device] = []
    links: list[Link] = []
    spines = [f"spine-{s}" for s in range(num_spines)]
    devices += [Device(s, SPINE) for s in spines]

    for pod in range(num_pods):
        leaves = [f"leaf-{pod * leaves_per_pod + l}" for l in range(leaves_per_pod)]
        devices += [Device(l, LEAF, rack=pod, pod=pod) for l in leaves]
        for h in range(hosts_per_pod):
            i = pod * hosts_per_pod + h
            srv = f"host-{i}"
            devices.append(Device(srv, SERVER, rack=pod, pod=pod))
            for nic in range(nics_per_host):
                leaf = leaves[h % leaves_per_pod] if nics_per_host == 1 else leaves[nic % leaves_per_pod]
                for p in range(ports_per_nic):
                    links.append(Link(srv, f"nic{nic}p{p}", leaf,
                                      f"host-{srv}-{nic}-{p}", host_link_gbps,
                                      HOST_TO_LEAF))
                    links.append(Link(leaf, f"down-{srv}-{nic}-{p}", srv,
                                      f"nic{nic}p{p}", host_link_gbps,
                                      LEAF_TO_HOST))
        for leaf in leaves:
            for spine in spines:
                for k in range(links_per_leaf_spine):
                    links.append(Link(leaf, f"up-{spine}-{k}", spine,
                                      f"in-{leaf}-{k}", fabric_link_gbps,
                                      LEAF_TO_SPINE))
                    links.append(Link(spine, f"down-{leaf}-{k}", leaf,
                                      f"spinein-{spine}-{k}", fabric_link_gbps,
                                      SPINE_TO_LEAF))
    return Fabric(devices, links)


def host_of_nic_ip(ip: str) -> tuple[int, int]:
    """Inverse of nic_ip: ip -> (server index, nic index)."""
    parts = ip.split(".")
    return int(parts[2]) * 256 + int(parts[3]), int(parts[1])
