"""Flow Imbalance Metric (paper eq. 1) and the throughput model used to
reproduce Fig. 3(a).

FIM = (100/n) * sum_i |actual_i - ideal_i| / ideal_i       (MAPE)

where i ranges over the network links of the fabric (optionally restricted
to one layer, as in the paper's per-layer subplots) and ideal_i is the
perfectly balanced per-link count.  Lower is better; 0 means every link
carries exactly the balanced share.

The throughput model is progressive-filling max-min fairness over link
capacities: each flow's rate is limited by its most contended link, which
is precisely how colliding 100G RoCE flows halve each other (paper
Section I).  Per-pair throughput is the sum over the pair's flows.
"""

from __future__ import annotations

import dataclasses
import heapq
from collections import defaultdict
from collections.abc import Mapping, Sequence

from .fabric import Fabric, Link
from .flows import Flow

# A traced path is the ordered list of links a flow traverses.
Path = list[Link]


def link_flow_counts(paths: Mapping[int, Path]) -> dict[str, int]:
    """actual_flows_i for every link that appears in any path."""
    counts: dict[str, int] = defaultdict(int)
    for path in paths.values():
        for link in path:
            counts[link.name] += 1
    return dict(counts)


def fim(
    paths: Mapping[int, Path],
    fabric: Fabric,
    *,
    layers: Sequence[str] | None = None,
    only_used_leaves: bool = False,
) -> float:
    """Flow Imbalance Metric over the links of ``layers`` (default: every
    layer that carries at least one flow somewhere in the fabric).

    ``ideal_flows_i`` is total flows on the layer / number of links in the
    layer — the paper's "each link carries an equal number of flows".
    Links in layers that carry zero total flows are excluded (ideal would
    be 0 and MAPE undefined); that matches the paper's use, where only the
    layers exercised by the workload are plotted.
    """
    values = per_layer_fim(paths, fabric, layers=layers,
                           only_used_leaves=only_used_leaves)
    if not values:
        return 0.0
    # Aggregate FIM = mean over all participating links, i.e. weight each
    # layer by its link count.
    total_links = sum(n for _, n in values.values())
    if total_links == 0:
        return 0.0
    return sum(v * n for v, n in values.values()) / total_links


@dataclasses.dataclass(frozen=True)
class LayerLoadStats:
    """One layer's link-load aggregate — the single source both the FIM
    computations and the path report (core/report.py) read, so per-link
    counts, totals, ideals, and MAPE can never drift apart."""

    link_counts: dict[str, int]   # every participating link, incl. idle
    total: int                    # sum of counts over the layer
    n_links: int
    ideal: float                  # total / n_links
    fim_pct: float                # MAPE over the layer's links


def layer_load_stats(
    paths: Mapping[int, Path],
    fabric: Fabric,
    *,
    layers: Sequence[str] | None = None,
    only_used_leaves: bool = False,
) -> dict[str, LayerLoadStats]:
    """Per-layer load stats.  Layers with zero traffic are dropped, and
    so are *empty* layers (no links — after the ``only_used_leaves``
    filter an exercised layer can end up linkless): their ideal load is
    undefined, so they are skipped rather than divided by zero."""
    counts = link_flow_counts(paths)
    used_devs: set[str] = set()
    if only_used_leaves:
        for p in paths.values():
            for l in p:
                used_devs.add(l.src)
                used_devs.add(l.dst)
    out: dict[str, LayerLoadStats] = {}
    for layer in (layers or fabric.layers):
        links = fabric.links_by_layer(layer)
        if only_used_leaves:
            links = [l for l in links if l.src in used_devs and l.dst in used_devs]
        if not links:
            continue
        per_link = {l.name: counts.get(l.name, 0) for l in links}
        total = sum(per_link.values())
        if total == 0:
            continue
        ideal = total / len(links)
        mape = 100.0 / len(links) * sum(
            abs(c - ideal) / ideal for c in per_link.values()
        )
        out[layer] = LayerLoadStats(link_counts=per_link, total=total,
                                    n_links=len(links), ideal=ideal,
                                    fim_pct=mape)
    return out


def per_layer_fim(
    paths: Mapping[int, Path],
    fabric: Fabric,
    *,
    layers: Sequence[str] | None = None,
    only_used_leaves: bool = False,
) -> dict[str, tuple[float, int]]:
    """Per-layer (FIM, n_links).  Layers with zero traffic are dropped."""
    stats = layer_load_stats(paths, fabric, layers=layers,
                             only_used_leaves=only_used_leaves)
    return {layer: (s.fim_pct, s.n_links) for layer, s in stats.items()}


def max_min_throughput(paths: Mapping[int, Path]) -> dict[int, float]:
    """Progressive-filling max-min fair rates (Gb/s) per flow id.

    Iteratively saturate the tightest link: rate = residual capacity /
    unfrozen flows crossing it; freeze those flows; repeat.  Exact for the
    single-path, equal-demand case the paper evaluates.

    This is the readable scalar reference the vectorized engine
    (``core/vector_throughput.py``) is differentially tested against.
    The bottleneck is found with a lazy-invalidation heap: stale entries
    (their share no longer matches the link's current residual/count) are
    skipped on pop, and a link is re-pushed whenever a freeze drains it.
    """
    link_cap: dict[str, float] = {}
    link_flows: dict[str, set[int]] = defaultdict(set)
    for fid, path in paths.items():
        for link in path:
            link_cap[link.name] = link.gbps
            link_flows[link.name].add(fid)

    rate: dict[int, float] = {}
    active: set[int] = set(paths.keys())
    residual = dict(link_cap)
    live_flows = {k: set(v) for k, v in link_flows.items()}
    heap = [(residual[name] / len(fl), name)
            for name, fl in live_flows.items() if fl]
    heapq.heapify(heap)
    while active:
        # bottleneck link = min residual/active_flows among links w/ active flows
        best_link = None
        while heap:
            share, name = heapq.heappop(heap)
            fl = live_flows[name]
            if fl and share == residual[name] / len(fl):
                best_link, best_share = name, share
                break
        if best_link is None:
            for fid in active:
                rate[fid] = float("inf")
            break
        drained: set[str] = set()
        for fid in list(live_flows[best_link]):
            rate[fid] = best_share
            active.discard(fid)
            for path_link in paths[fid]:
                if fid in live_flows[path_link.name]:
                    live_flows[path_link.name].discard(fid)
                    residual[path_link.name] -= best_share
                    drained.add(path_link.name)
        live_flows[best_link].clear()
        for name in drained:
            fl = live_flows[name]
            if fl:
                heapq.heappush(heap, (residual[name] / len(fl), name))
    return rate


def per_pair_throughput(
    flows_list: Sequence[Flow], paths: Mapping[int, Path]
) -> dict[tuple[str, str], float]:
    rates = max_min_throughput(paths)
    out: dict[tuple[str, str], float] = defaultdict(float)
    for f in flows_list:
        out[(f.src, f.dst)] += rates.get(f.flow_id, 0.0)
    return dict(out)
