"""Flow primitives: 5-tuples, flows, and workload descriptions.

Mirrors the paper's Step (1): the *workload description* names the exact
server pairs involved in the communication and the number of flows ``f``
between each pair.  Flows are identified by the RoCEv2/TCP 5-tuple
(src_ip, dst_ip, src_port, dst_port, protocol).
"""

from __future__ import annotations

import dataclasses
import itertools
from collections.abc import Iterable, Sequence

PROTO_TCP = 6
PROTO_UDP = 17
# RoCEv2 rides UDP/4791; we keep the inner QP pair in the port fields the
# way the NIC driver exposes it (paper Section III-B.1b).
ROCE_UDP_DPORT = 4791


@dataclasses.dataclass(frozen=True, slots=True)
class FiveTuple:
    """The classic flow identity used for every ECMP hash decision."""

    src_ip: str
    dst_ip: str
    src_port: int
    dst_port: int
    protocol: int = PROTO_UDP

    def as_key(self) -> tuple[str, str, int, int, int]:
        return (self.src_ip, self.dst_ip, self.src_port, self.dst_port, self.protocol)


@dataclasses.dataclass(frozen=True, slots=True)
class Flow:
    """A unidirectional flow between two endpoints.

    ``src``/``dst`` are *server* names (fabric node ids); the 5-tuple binds
    the flow to concrete NIC IPs so hash decisions are reproducible.
    ``bytes`` carries the volume for throughput / roofline analysis (0 for
    pure path-discovery runs, where only counts matter).
    """

    flow_id: int
    src: str
    dst: str
    tuple5: FiveTuple
    bytes: int = 0
    label: str = ""  # e.g. the HLO collective op this flow came from


@dataclasses.dataclass(frozen=True, slots=True)
class PairSpec:
    """One (s, d) communication pair with ``f`` flows (paper Alg. 1 input).

    ``bytes_per_flow`` optionally pins the volume of this pair's flows:
    the paper's workload description names flow *volumes* as well as
    pairs, and real LLM collectives are heavily non-uniform (a DP
    gradient all-reduce is ~9 orders of magnitude heavier than a
    barrier).  ``None`` defers to the ``synthesize_flows`` default.
    """

    src: str
    dst: str
    num_flows: int
    bytes_per_flow: int | None = None


@dataclasses.dataclass(slots=True)
class WorkloadDescription:
    """Paper Step (1): server pairs + flows per pair (+ filter info)."""

    pairs: list[PairSpec]
    filter_protocols: tuple[int, ...] = (PROTO_TCP, PROTO_UDP)

    @property
    def total_flows(self) -> int:
        return sum(p.num_flows for p in self.pairs)

    @property
    def total_bytes(self) -> int:
        """Declared volume over all pairs.  Pairs without an explicit
        ``bytes_per_flow`` spec count as 0 — the description only knows
        what it declares (a synthesize-time default is not visible here)."""
        return sum(p.num_flows * (p.bytes_per_flow or 0) for p in self.pairs)

    def filter(self, flows: Iterable[Flow]) -> list[Flow]:
        """Keep only flows relevant to this workload (paper Alg. 1 line 7)."""
        wanted = {(p.src, p.dst) for p in self.pairs}
        return [
            f
            for f in flows
            if (f.src, f.dst) in wanted and f.tuple5.protocol in self.filter_protocols
        ]


def synthesize_flows(
    workload: WorkloadDescription,
    *,
    nic_ip: "callable[[str, int], str]",
    nics_per_server: int = 2,
    bytes_per_flow: int = 0,
    base_port: int = 49152,
    protocol: int = PROTO_UDP,
) -> list[Flow]:
    """Materialize concrete flows for a workload.

    This is what the NIC driver / ``ss`` query returns in the real tool: one
    5-tuple per flow.  Flows for a pair are spread round-robin over the
    (src NIC x dst NIC) combinations — each NIC has its own IP — and get
    distinct source ports, which is exactly the entropy ECMP hashes over.

    ``bytes_per_flow`` is the global default volume; a pair carrying its
    own ``PairSpec.bytes_per_flow`` overrides it, so heterogeneous-volume
    workloads are expressible from the description alone.
    """
    flows: list[Flow] = []
    fid = itertools.count()
    for pair in workload.pairs:
        pair_bytes = (pair.bytes_per_flow if pair.bytes_per_flow is not None
                      else bytes_per_flow)
        nic_combos = [
            (s_nic, d_nic)
            for s_nic in range(nics_per_server)
            for d_nic in range(nics_per_server)
        ]
        for k in range(pair.num_flows):
            s_nic, d_nic = nic_combos[k % len(nic_combos)]
            t5 = FiveTuple(
                src_ip=nic_ip(pair.src, s_nic),
                dst_ip=nic_ip(pair.dst, d_nic),
                src_port=base_port + k,
                dst_port=ROCE_UDP_DPORT if protocol == PROTO_UDP else 5001,
                protocol=protocol,
            )
            flows.append(
                Flow(
                    flow_id=next(fid),
                    src=pair.src,
                    dst=pair.dst,
                    tuple5=t5,
                    bytes=pair_bytes,
                )
            )
    return flows


def bipartite_pairs(
    rack_a: Sequence[str],
    rack_b: Sequence[str],
    flows_per_pair: int,
    *,
    bytes_per_flow: int | Sequence[int] | None = None,
) -> WorkloadDescription:
    """The paper's Fig. 2(b) bipartite pattern: server i in rack A exchanges
    traffic with server i in rack B, both directions, saturating the
    cross-rack links.  16 directed pairs x 16 flows = 256 flows on the
    paper testbed.

    ``bytes_per_flow`` optionally sets flow volumes: a scalar applies to
    every pair, a sequence gives server-pair ``i`` (both directions) its
    own volume — the bipartite + heterogeneous-volume scenario.
    """
    assert len(rack_a) == len(rack_b)
    if isinstance(bytes_per_flow, (str, bytes)):
        raise TypeError(
            f"bytes_per_flow must be an int or a sequence of ints, "
            f"got {bytes_per_flow!r}")
    if bytes_per_flow is None:
        per_pair: list[int | None] = [None] * len(rack_a)
    else:
        try:
            items = iter(bytes_per_flow)
        except TypeError:   # scalar, including numpy integer scalars
            per_pair = [int(bytes_per_flow)] * len(rack_a)
        else:               # element errors propagate with their own message
            per_pair = [int(v) for v in items]
        if len(per_pair) != len(rack_a):
            raise ValueError(
                f"bytes_per_flow has {len(per_pair)} entries for "
                f"{len(rack_a)} server pairs")
    pairs = []
    for (a, b), volume in zip(zip(rack_a, rack_b), per_pair):
        pairs.append(PairSpec(a, b, flows_per_pair, bytes_per_flow=volume))
        pairs.append(PairSpec(b, a, flows_per_pair, bytes_per_flow=volume))
    return WorkloadDescription(pairs=pairs)


def workload_from_flows(flows: Iterable[Flow]) -> WorkloadDescription:
    """Recover the paper-Step-(1) description from a concrete flow list
    (e.g. the HLO-derived flows of ``core/llm_workload.py``): pairs in
    first-seen order, per-pair flow counts, and per-pair byte specs.

    A pair whose flows carry different volumes (one pair serving both an
    all-reduce ring edge and an all-to-all edge) is summarized by its
    *mean* bytes per flow — the description is per-pair granular; keep
    the explicit flow list when exact per-flow volumes matter.
    """
    counts: dict[tuple[str, str], int] = {}
    volumes: dict[tuple[str, str], int] = {}
    for f in flows:
        key = (f.src, f.dst)
        counts[key] = counts.get(key, 0) + 1
        volumes[key] = volumes.get(key, 0) + f.bytes
    # always pin the spec (0 stays 0): leaving an all-zero pair at None
    # would let a synthesize-time default silently inflate it
    pairs = [
        PairSpec(src, dst, n, bytes_per_flow=round(volumes[(src, dst)] / n))
        for (src, dst), n in counts.items()
    ]
    return WorkloadDescription(pairs=pairs)
