"""Compiled-HLO -> flow extraction.

The paper's first-hop discovery asks the NIC driver which flows exist.
For an XLA-compiled training step we can do strictly better: the SPMD
partitioner has already decided every collective the program will run, so
the *compiled HLO text* is a complete, passive description of the job's
network traffic.  This module:

  1. parses every collective op (all-reduce / all-gather / reduce-scatter /
     all-to-all / collective-permute, sync or async-start form) with its
     shape and replica groups (explicit or iota-v2 format);
  2. models per-device wire bytes for each (ring algorithms for AR/AG/RS,
     pairwise for A2A, explicit pairs for permute) — this feeds the
     roofline collective term;
  3. decomposes inter-host traffic into point-to-point ``Flow`` records
     with RoCEv2 5-tuples so FlowTracer can trace them across the DCN
     fabric model.  Intra-host (chip-to-chip) and intra-pod ICI edges are
     tallied separately — ICI routing is deterministic (no ECMP), so only
     pod-crossing flows enter the Clos analysis (DESIGN.md section 2).
"""

from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from collections.abc import Mapping, Sequence

from .flows import Flow, FiveTuple, ROCE_UDP_DPORT, PROTO_UDP

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u2": 1, "u4": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1,
    "f8e5m2fnuz": 1, "f8e3m4": 1, "f8e4m3": 1,
}

_SHAPE_RE = re.compile(r"([a-z]\w*)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all", "collective-broadcast",
)
# op line:  %name = SHAPE opname(...), attrs
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(" + "|".join(_COLLECTIVES) + r")(-start)?\("
)
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{(\{[\d,{}\s]*\})\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?"
)
_PAIRS_RE = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)*)\}")
_CHANNEL_RE = re.compile(r"channel_id=(\d+)")

# computation header: `%name (args) -> type {`  or  `ENTRY %name ...{`
# (args may contain nested parens for tuple types -> greedy match)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_WHILE_RE = re.compile(r"while\(.*?body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"\bcall\(.*?to_apply=%?([\w.\-]+)")


def computation_multipliers(hlo_text: str) -> dict[str, int]:
    """Execution count of each HLO computation, from while-loop
    known_trip_count backend configs (XLA counts loop bodies ONCE in
    cost_analysis; collectives inside scan bodies run trip_count times).

    Returns {computation_name: multiplier}; ENTRY has multiplier 1.
    """
    comps: dict[str, list[str]] = {}
    entry = None
    cur: list[str] | None = None
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line)
        if m:
            cur = comps.setdefault(m.group(1), [])
            if line.lstrip().startswith("ENTRY"):
                entry = m.group(1)
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            cur.append(line)

    # edges: computation -> [(child, weight)]
    edges: dict[str, list[tuple[str, int]]] = {name: [] for name in comps}
    for name, lines in comps.items():
        for line in lines:
            wm = _WHILE_RE.search(line)
            if wm:
                tm = _TRIP_RE.search(line)
                trip = int(tm.group(1)) if tm else 1
                edges[name].append((wm.group(1), trip))
                continue
            cm = _CALL_RE.search(line)
            if cm:
                edges[name].append((cm.group(1), 1))

    if entry is None:
        return {name: 1 for name in comps}
    # fixed-point over the (acyclic) computation-call DAG: each
    # computation's count is the sum over parents of parent_count * weight.
    mult: dict[str, int] = {name: (1 if name == entry else 0) for name in comps}
    for _ in range(len(comps) + 2):
        new = {name: (1 if name == entry else 0) for name in comps}
        for parent, out in edges.items():
            for child, w in out:
                if child in new:
                    new[child] += mult.get(parent, 0) * w
        new[entry] = 1
        if new == mult:
            break
        mult = new
    return {name: max(1, v) for name, v in mult.items()}


def op_computations(hlo_text: str) -> dict[int, str]:
    """line number -> enclosing computation name."""
    out: dict[int, str] = {}
    cur = "<none>"
    for i, line in enumerate(hlo_text.splitlines()):
        m = _COMP_RE.match(line)
        if m:
            cur = m.group(1)
        out[i] = cur
    return out


def shape_bytes(shape_str: str) -> int:
    """Bytes of one HLO shape token like ``bf16[256,4096]{1,0}``.
    Tuple shapes sum their elements."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype == "token" or dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _parse_groups(line: str) -> list[list[int]]:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        num_groups, group_size = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        total = math.prod(dims)
        ids = list(range(total))
        # reshape -> transpose -> flatten, pure python (dims are small)
        if m.group(4):
            perm = [int(x) for x in m.group(4).split(",")]
            # index math: element at flat position p has multi-index over dims
            strides = [0] * len(dims)
            acc = 1
            for i in range(len(dims) - 1, -1, -1):
                strides[i] = acc
                acc *= dims[i]
            tdims = [dims[p] for p in perm]
            tstrides = [strides[p] for p in perm]
            out = []
            idx = [0] * len(tdims)
            for _ in range(total):
                out.append(sum(i * s for i, s in zip(idx, tstrides)))
                for ax in range(len(tdims) - 1, -1, -1):
                    idx[ax] += 1
                    if idx[ax] < tdims[ax]:
                        break
                    idx[ax] = 0
            ids = out
        return [ids[g * group_size : (g + 1) * group_size]
                for g in range(num_groups)]
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        inner = m.group(1)
        groups = re.findall(r"\{([\d,\s]*)\}", inner)
        return [[int(x) for x in g.split(",") if x.strip()] for g in groups if g.strip()]
    return []


def _parse_pairs(line: str) -> list[tuple[int, int]]:
    m = _PAIRS_RE.search(line)
    if not m:
        return []
    return [tuple(int(v) for v in p.split(","))
            for p in re.findall(r"\{(\d+,\d+)\}", m.group(1))]


@dataclasses.dataclass(frozen=True, slots=True)
class CollectiveOp:
    kind: str                      # all-reduce / all-gather / ...
    result_bytes: int              # per-device result buffer
    operand_bytes: int             # per-device operand buffer
    wire_bytes: int                # modeled per-device bytes on the wire, ONE execution
    groups: tuple[tuple[int, ...], ...]
    pairs: tuple[tuple[int, int], ...]  # collective-permute only
    channel_id: int
    line_no: int
    multiplier: int = 1            # executions per step (while trip counts)

    @property
    def total_wire_bytes(self) -> int:
        return self.wire_bytes * self.multiplier


def wire_and_operand(kind: str, result_bytes: int, n: int) -> tuple[int, int]:
    """Per-device (wire_bytes, operand_bytes) under ring algorithms.

    Public byte model shared with synthetic collective generators
    (``core/llm_workload.py``)."""
    if kind not in _COLLECTIVES:
        raise ValueError(kind)
    if n <= 1:
        # nothing on the wire; still report operand bytes for bookkeeping
        return 0, result_bytes
    if kind == "all-reduce":
        return int(2 * (n - 1) / n * result_bytes), result_bytes
    if kind in ("all-gather", "collective-broadcast"):
        return int((n - 1) / n * result_bytes), result_bytes // n
    if kind == "reduce-scatter":
        operand = result_bytes * n
        return (n - 1) * result_bytes, operand
    if kind in ("all-to-all", "ragged-all-to-all"):
        return int((n - 1) / n * result_bytes), result_bytes
    # collective-permute: every pair moves the full buffer
    return result_bytes, result_bytes


def extract_collectives(hlo_text: str) -> list[CollectiveOp]:
    """Parse collectives with per-op execution multipliers (loop trip
    counts), since ops inside scan bodies appear once in the text."""
    mults = computation_multipliers(hlo_text)
    comp_of = op_computations(hlo_text)
    ops: list[CollectiveOp] = []
    for ln_no, line in enumerate(hlo_text.splitlines()):
        m = _OP_RE.match(line)
        if not m:
            continue
        shape_str, kind, is_start = m.group(1), m.group(2), bool(m.group(3))
        # async -start ops of gather/permute return (operand, result, ...):
        # use the LAST array element as the result buffer.
        if is_start and shape_str.startswith("("):
            # async -start tuple: last array element is the output buffer
            shapes = _SHAPE_RE.findall(shape_str)
            if shapes:
                dtype, dims = shapes[-1]
                dims_s = f"{dtype}[{dims}]"
                result_bytes = shape_bytes(dims_s)
            else:
                result_bytes = 0
        else:
            result_bytes = shape_bytes(shape_str)

        pairs = tuple(_parse_pairs(line))
        groups = tuple(tuple(g) for g in _parse_groups(line))
        if kind == "collective-permute":
            n = 2 if pairs else 1
            wire, operand = (result_bytes, result_bytes) if pairs else (0, result_bytes)
        else:
            n = max((len(g) for g in groups), default=1)
            wire, operand = wire_and_operand(kind, result_bytes, n)
        chan = _CHANNEL_RE.search(line)
        ops.append(
            CollectiveOp(
                kind=kind,
                result_bytes=result_bytes,
                operand_bytes=operand,
                wire_bytes=wire,
                groups=groups,
                pairs=pairs,
                channel_id=int(chan.group(1)) if chan else 0,
                line_no=ln_no,
                multiplier=mults.get(comp_of.get(ln_no, ""), 1),
            )
        )
    return ops


@dataclasses.dataclass
class CollectiveSummary:
    per_kind_wire: dict[str, int]
    per_kind_count: dict[str, int]
    total_wire_bytes: int          # per device
    total_operand_bytes: int       # per device (prompt-faithful roofline input)

    @property
    def total_count(self) -> int:
        return sum(self.per_kind_count.values())


def summarize(ops: Sequence[CollectiveOp]) -> CollectiveSummary:
    """Totals with loop multipliers applied (true per-step wire traffic)."""
    wire: dict[str, int] = defaultdict(int)
    count: dict[str, int] = defaultdict(int)
    for op in ops:
        wire[op.kind] += op.total_wire_bytes
        count[op.kind] += op.multiplier
    return CollectiveSummary(
        per_kind_wire=dict(wire),
        per_kind_count=dict(count),
        total_wire_bytes=sum(op.total_wire_bytes for op in ops),
        total_operand_bytes=sum(op.operand_bytes * op.multiplier for op in ops),
    )


# ---------------------------------------------------------------------------
# Decomposition into point-to-point flows (FlowTracer input)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EdgeClassCounts:
    """Where a collective's ring edges land in the machine."""

    intra_host: int = 0
    intra_pod_ici: int = 0
    inter_pod_dcn: int = 0
    dcn_bytes: int = 0
    ici_bytes: int = 0


def _ring_edges(group: Sequence[int]) -> list[tuple[int, int]]:
    n = len(group)
    return [(group[i], group[(i + 1) % n]) for i in range(n)] if n > 1 else []


def collectives_to_flows(
    ops: Sequence[CollectiveOp],
    coords: Mapping[int, tuple[int, int, int]],
    *,
    host_name: "callable[[int], str] | None" = None,
    nic_ip: "callable[[str, int], str] | None" = None,
    base_port: int = 49152,
) -> tuple[list[Flow], EdgeClassCounts]:
    """Decompose collectives into inter-host DCN flows.

    ``coords[device] = (pod, global_host, chip)``.  Ring edges between
    chips on the same host never touch a network; edges within a pod ride
    the ICI torus (deterministic); only pod-crossing edges become DCN
    flows with RoCE 5-tuples for the Clos fabric.
    """
    if host_name is None:
        host_name = lambda h: f"host-{h}"
    if nic_ip is None:
        from .fabric import nic_ip as _nip
        nic_ip = _nip

    flows: list[Flow] = []
    stats = EdgeClassCounts()
    fid = 0
    for op in ops:
        if op.kind == "collective-permute":
            edges = list(op.pairs)
            per_edge_bytes = op.result_bytes
            edge_sets = [edges]
        elif op.kind in ("all-to-all", "ragged-all-to-all"):
            edge_sets = []
            for g in op.groups:
                n = len(g)
                if n > 1:
                    edge_sets.append(
                        [(a, b) for a in g for b in g if a != b]
                    )
            per_edge_bytes = None  # computed per group below
        else:
            edge_sets = [_ring_edges(g) for g in op.groups]
            per_edge_bytes = None

        for g_idx, edges in enumerate(edge_sets):
            if not edges:
                continue
            if per_edge_bytes is None:
                n = len(op.groups[g_idx]) if op.groups else 2
                if op.kind == "all-reduce":
                    eb = int(2 * (n - 1) / n * op.result_bytes)
                elif op.kind in ("all-gather", "collective-broadcast"):
                    eb = int((n - 1) / n * op.result_bytes)
                elif op.kind == "reduce-scatter":
                    eb = (n - 1) * op.result_bytes
                elif op.kind in ("all-to-all", "ragged-all-to-all"):
                    eb = op.result_bytes // max(1, n)
                else:
                    eb = op.result_bytes
            else:
                eb = per_edge_bytes
            eb *= op.multiplier   # repeated executions = one elephant flow
            for e_idx, (a, b) in enumerate(edges):
                pa, ha, _ = coords[a]
                pb, hb, _ = coords[b]
                if ha == hb:
                    stats.intra_host += 1
                    continue
                if pa == pb:
                    stats.intra_pod_ici += 1
                    stats.ici_bytes += eb
                    continue
                stats.inter_pod_dcn += 1
                stats.dcn_bytes += eb
                src, dst = host_name(ha), host_name(hb)
                t5 = FiveTuple(
                    src_ip=nic_ip(src, 0),
                    dst_ip=nic_ip(dst, 0),
                    src_port=base_port + ((op.channel_id * 131 + e_idx * 7919) % 16384),
                    dst_port=ROCE_UDP_DPORT,
                    protocol=PROTO_UDP,
                )
                flows.append(
                    Flow(flow_id=fid, src=src, dst=dst, tuple5=t5, bytes=eb,
                         label=f"{op.kind}#ch{op.channel_id}")
                )
                fid += 1
    return flows, stats
