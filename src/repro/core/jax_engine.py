"""Device-resident JAX engine: walk -> counts/FIM -> max-min fill -> goodput.

The numpy engine (``vector_sim`` / ``vector_throughput`` / ``reordering``)
is the differential reference; this module re-expresses the same hot path
as jitted jax so a pod-scale sweep (100k flows x 10k seeds) runs on the
accelerator with no host round-trips between stages:

* the per-hop ECMP/flowlet walk is a ``lax.while_loop`` over the (N, S)
  current-device grid — bit-identical to ``vector_sim.ecmp_walk`` under
  the exact splitmix64 backend (uint64 wraparound is exact under x64);
* link counts ride one ``segment_sum`` over the link-id tensor, and the
  per-layer FIM (MAPE vs per-layer ideal) is a handful of masked
  reductions per layer;
* the weighted progressive max-min fill keeps the numpy engine's
  parallel local-bottleneck formulation, as a ``lax.while_loop`` whose
  body is segment ops over (seed, link) cells — frozen columns park
  their cells on the sentinel slot instead of compacting, which keeps
  every shape static under jit;
* flowlet exposure -> transport efficiency -> goodput fuse on top as
  per-parent segment reductions.

Hash backends: ``"exact"`` is the splitmix64 chain (bit-identical to the
Python tracer, and to the numpy engine — the differential contract).
``"murmur"`` is the murmur3 avalanche shared with ``kernels/flowhash``
(the Pallas ``bulk_hash`` kernel on TPU, the same fold/fmix formulas as
jnp elsewhere); it is the default for real accelerator backends, where
64-bit multiplies are slow or unsupported.  ``default_hash_backend``
encodes that policy.

Everything here enters through ``jax.experimental.enable_x64`` as a
*scoped* context (never the global flag): the exact backend needs uint64
and the fill needs float64, but flipping x64 globally would change
default dtypes for every other jax user in the process.
"""

from __future__ import annotations

import dataclasses
import functools
from collections.abc import Sequence

import numpy as np

from .compile_fabric import CompiledFabric, compile_fabric
from .ecmp import FIELDS_5TUPLE, HASH_INIT, flow_fields_matrix
from .fabric import Fabric
from .flows import Flow, WorkloadDescription
from .vector_sim import (
    DEMAND_UNIFORM, EXACT, MURMUR, MonteCarloFim, VectorTraceResult,
    flow_demand_weights, normalize_seeds, resolve_flows,
)

__all__ = [
    "ENGINE_NUMPY", "ENGINE_JAX", "default_hash_backend",
    "jax_ecmp_walk", "jax_wave_walk", "jax_link_flow_counts",
    "jax_fim_from_counts",
    "jax_batched_max_min", "jax_flowlet_exposure",
    "fused_monte_carlo_fim", "fused_monte_carlo_throughput",
]

ENGINE_NUMPY = "numpy"
ENGINE_JAX = "jax"

# Seeds per device pass in the fused front ends: caps the transient
# (max_hops, N, Sc) int32 walk tensor at ~0.5 GB for 100k-flow sweeps
# (16 * 100k * 8192 * 4B).  Chunking re-enters the same jitted functions
# (shapes repeat), so it costs one dispatch per chunk, not a recompile.
_FUSED_SEED_CHUNK_CELLS = 100_000 * 8192


def _jx():
    """Lazy jax import bundle — core stays importable (and the numpy
    engine usable) on hosts without jax."""
    import jax
    import jax.numpy as jnp
    from jax import lax
    return jax, jnp, lax


def _x64():
    from jax.experimental import enable_x64
    return enable_x64()


def default_hash_backend(engine: str = ENGINE_JAX) -> str:
    """Backend policy when the caller doesn't pin one: the numpy engine
    (and jax-on-CPU, where CI differential tests run) keep the exact
    tracer-identical splitmix64; real accelerator backends default to the
    TPU-native murmur kernel path."""
    if engine != ENGINE_JAX:
        return EXACT
    import jax
    return MURMUR if jax.default_backend() in ("tpu", "gpu") else EXACT


def resolve_engine(engine: str) -> str:
    if engine not in (ENGINE_NUMPY, ENGINE_JAX):
        raise ValueError(
            f"unknown engine {engine!r}; "
            f"expected {ENGINE_NUMPY!r} or {ENGINE_JAX!r}")
    return engine


# ---------------------------------------------------------------------------
# Compiled-fabric tables on device (cached per CompiledFabric instance)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _DeviceTables:
    cand: object
    cand_n: object
    dev_crc: object
    is_server: object
    link_dst: object
    link_gbps: object


_TABLE_CACHE: dict[int, tuple[object, _DeviceTables]] = {}


def device_tables(comp: CompiledFabric) -> _DeviceTables:
    """Device copies of the forwarding tables, uploaded once per compiled
    fabric (keyed by identity — CompiledFabric is frozen, and the weakref
    anchor in the cache value keeps ids from being recycled under us)."""
    hit = _TABLE_CACHE.get(id(comp))
    if hit is not None and hit[0] is comp:
        return hit[1]
    _, jnp, _ = _jx()
    tabs = _DeviceTables(
        cand=jnp.asarray(comp.cand),
        cand_n=jnp.asarray(comp.cand_n),
        dev_crc=jnp.asarray(comp.dev_crc),
        is_server=jnp.asarray(comp.is_server),
        link_dst=jnp.asarray(comp.link_dst),
        link_gbps=jnp.asarray(np.asarray(comp.link_gbps, np.float64)),
    )
    if len(_TABLE_CACHE) > 16:
        _TABLE_CACHE.clear()
    _TABLE_CACHE[id(comp)] = (comp, tabs)
    return tabs


# ---------------------------------------------------------------------------
# Hash grids (device twins of vector_sim.hash_grid)
# ---------------------------------------------------------------------------


def _mix64_j(x):
    _, jnp, _ = _jx()
    x = (x ^ (x >> jnp.uint64(30))) * jnp.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> jnp.uint64(27))) * jnp.uint64(0x94D049BB133111EB)
    return x ^ (x >> jnp.uint64(31))


def _exact_grid_j(fields, dev_seed):
    """splitmix64 over (N, F) fields x (N, S) device seeds -> (N, S)
    uint64 — the exact ``ecmp_hash_vec`` chain, bit-identical under x64."""
    _, jnp, _ = _jx()
    h = _mix64_j(dev_seed ^ jnp.uint64(HASH_INIT))
    for f in range(fields.shape[1]):
        h = _mix64_j(h ^ fields[:, f][:, None])
    return h


def _murmur_grid_j(fields, dev_seed):
    """murmur3 grid with the per-(flow, seed) device seed as the hash
    init — the seed-as-init convention shared with ``bulk_hash`` (whose
    scalar seed is the same init broadcast) and the numpy murmur grid."""
    from ..kernels.flowhash.kernel import murmur_fmix, murmur_fold
    _, jnp, _ = _jx()
    h = (dev_seed & jnp.uint64(0xFFFFFFFF)).astype(jnp.uint32)
    f32 = fields.astype(jnp.uint32)
    for f in range(fields.shape[1]):
        h = murmur_fold(h, f32[:, f][:, None])
    return murmur_fmix(h).astype(jnp.uint64)


def _hash_grid_j(fields, dev_seed, hash_backend: str):
    if hash_backend == EXACT:
        return _exact_grid_j(fields, dev_seed)
    if hash_backend == MURMUR:
        return _murmur_grid_j(fields, dev_seed)
    raise ValueError(f"unknown hash backend: {hash_backend}")


# ---------------------------------------------------------------------------
# Stage 1: the walk (lax.while_loop over the (N, S) device grid)
# ---------------------------------------------------------------------------


def _walk_jit():
    jax, jnp, lax = _jx()

    @functools.partial(
        jax.jit, static_argnames=("max_hops", "hash_backend", "n_fields"))
    def walk(cand, cand_n, dev_crc, is_server, link_dst,
             src_dev, src_key, dst_key, fields, seeds, cell_salt,
             *, max_hops: int, hash_backend: str, n_fields: int):
        N, S = src_dev.shape[0], seeds.shape[0]
        state0 = jnp.broadcast_to(
            src_dev[:, None].astype(jnp.int32), (N, S))
        done0 = jnp.zeros((N, S), bool)
        ids0 = jnp.full((max_hops, N, S), -1, jnp.int32)

        def cond(c):
            t, state, done, ids = c
            return (t < max_hops) & ~done.all()

        def body(c):
            t, state, done, ids = c
            # src-keyed on the source host (hop 0), dst-keyed at switches
            key = jnp.where(is_server[state], src_key[:, None],
                            dst_key[:, None])
            n = cand_n[state, key]
            dev_seed = dev_crc[state] ^ seeds[None, :]
            if cell_salt is not None:
                dev_seed = dev_seed ^ cell_salt
            h = _hash_grid_j(fields, dev_seed, hash_backend)
            safe_n = jnp.maximum(n, 1).astype(jnp.uint64)
            choice = jnp.where(n > 1, (h % safe_n).astype(jnp.int32), 0)
            link = cand[state, key, choice]
            link = jnp.where(done | (n == 0), -1, link)
            ids = lax.dynamic_update_index_in_dim(ids, link, t, 0)
            nxt = jnp.where(link >= 0, link_dst[jnp.maximum(link, 0)], state)
            done = done | (link < 0) | is_server[nxt]
            return t + 1, nxt, done, ids

        t, state, done, ids = lax.while_loop(
            cond, body, (jnp.int32(0), state0, done0, ids0))
        return ids, state, done, t

    return walk


@functools.lru_cache(maxsize=1)
def _walk_fn():
    return _walk_jit()


def _jax_walk_device(comp, src_dev, src_key, dst_key, field_mat, seeds_u64,
                     *, hash_backend, max_hops, cell_salt=None):
    """Run the walk on device; returns device (max_hops, N, S) link ids,
    final state, done mask, and the hop-count scalar (all device-side)."""
    _, jnp, _ = _jx()
    tabs = device_tables(comp)
    salt = None if cell_salt is None else jnp.asarray(cell_salt)
    return _walk_fn()(
        tabs.cand, tabs.cand_n, tabs.dev_crc, tabs.is_server, tabs.link_dst,
        jnp.asarray(src_dev), jnp.asarray(src_key), jnp.asarray(dst_key),
        jnp.asarray(field_mat), jnp.asarray(seeds_u64), salt,
        max_hops=max_hops, hash_backend=hash_backend,
        n_fields=int(field_mat.shape[1]))


def _check_walk(comp, state, dst_dev, describe):
    """The numpy engine's arrival contract (termination is checked on
    the ``done`` scalar before this runs); state is (N, S)-small, so the
    host pull costs nothing next to the link-id tensor it replaces."""
    state = np.asarray(state)
    arrived = state == np.broadcast_to(
        np.asarray(dst_dev)[:, None], state.shape)
    if not arrived.all():
        bad = np.argwhere(~arrived)[0]
        raise RuntimeError(
            f"{describe(bad[0])} (seed index {bad[1]}) terminated "
            f"at {comp.device_names[state[bad[0], bad[1]]]}")


def jax_ecmp_walk(
    comp: CompiledFabric,
    src_dev: np.ndarray,
    dst_dev: np.ndarray,
    src_key: np.ndarray,
    dst_key: np.ndarray,
    field_mat: np.ndarray,
    seeds_u64: np.ndarray,
    *,
    hash_backend: str = EXACT,
    max_hops: int = 16,
    cell_salt: np.ndarray | None = None,
    describe=lambda n: f"column {n}",
) -> np.ndarray:
    """Drop-in twin of ``vector_sim.ecmp_walk`` on the jax engine:
    same signature, same (hops, N, S) numpy result, same termination
    errors — bit-identical under ``hash_backend="exact"``."""
    with _x64():
        ids, state, done, t = _jax_walk_device(
            comp, src_dev, src_key, dst_key, field_mat, seeds_u64,
            hash_backend=hash_backend, max_hops=max_hops,
            cell_salt=cell_salt)
        hops = int(t)
        if not bool(done.all()):
            raise RuntimeError(
                f"some flows did not terminate in {max_hops} hops")
        _check_walk(comp, state, dst_dev, describe)
        return np.asarray(ids[:hops])


def _wave_walk_jit():
    jax, jnp, lax = _jx()

    @functools.partial(
        jax.jit, static_argnames=("max_hops", "hash_backend", "n_fields",
                                  "cool", "near"))
    def wave_walk(cand, cand_n, dev_crc, is_server, link_dst,
                  src_dev, src_key, dst_key, fields, seeds, loads_q,
                  *, max_hops: int, hash_backend: str, n_fields: int,
                  cool: bool, near: bool):
        N, S = src_dev.shape[0], seeds.shape[0]
        C = cand.shape[-1]
        flat = loads_q.reshape(-1)
        row_off = jnp.arange(S, dtype=jnp.int64) * loads_q.shape[1]
        col_idx = jnp.arange(C)
        state0 = jnp.broadcast_to(
            src_dev[:, None].astype(jnp.int32), (N, S))
        done0 = jnp.zeros((N, S), bool)
        ids0 = jnp.full((max_hops, N, S), -1, jnp.int32)

        def cond(c):
            t, state, done, ids = c
            return (t < max_hops) & ~done.all()

        def body(c):
            t, state, done, ids = c
            key = jnp.where(is_server[state], src_key[:, None],
                            dst_key[:, None])
            n = cand_n[state, key]
            cands = cand[state, key]                       # (N, S, C)
            valid = (col_idx < n[..., None]) & (cands >= 0)
            cl = jnp.where(
                valid,
                flat[row_off[None, :, None] + jnp.maximum(cands, 0)],
                jnp.inf)
            dev_seed = dev_crc[state] ^ seeds[None, :]
            h = _hash_grid_j(fields, dev_seed, hash_backend)
            # the three _wave_choice eligibility modes, selected
            # statically (cool/near are jit-static):
            if cool and near:
                m = cl.min(axis=-1)
                tie = valid & (cl <= m[..., None] + 1.0)
            elif cool:
                n_valid = jnp.maximum(valid.sum(axis=-1), 1)
                mean = jnp.where(valid, cl, 0.0).sum(axis=-1) / n_valid
                tie = valid & (cl <= jnp.floor(mean)[..., None])
            else:
                tie = valid & (cl == cl.min(axis=-1)[..., None])
            n_tie = tie.sum(axis=-1)
            rank = jnp.where(
                n_tie > 1,
                (h % jnp.maximum(n_tie, 1).astype(jnp.uint64)
                 ).astype(jnp.int64),
                0)
            col = (tie.cumsum(axis=-1) <= rank[..., None]).sum(axis=-1)
            link = jnp.take_along_axis(
                cands, jnp.minimum(col, C - 1)[..., None], axis=-1)[..., 0]
            link = jnp.where(done | (n == 0), -1, link)
            ids = lax.dynamic_update_index_in_dim(ids, link, t, 0)
            nxt = jnp.where(link >= 0, link_dst[jnp.maximum(link, 0)], state)
            done = done | (link < 0) | is_server[nxt]
            return t + 1, nxt, done, ids

        t, state, done, ids = lax.while_loop(
            cond, body, (jnp.int32(0), state0, done0, ids0))
        return ids, state, done, t

    return wave_walk


@functools.lru_cache(maxsize=1)
def _wave_walk_fn():
    return _wave_walk_jit()


def jax_wave_walk(
    comp: CompiledFabric,
    src_dev: np.ndarray,
    dst_dev: np.ndarray,
    src_key: np.ndarray,
    dst_key: np.ndarray,
    field_mat: np.ndarray,
    seeds_u64: np.ndarray,
    loads: np.ndarray,
    *,
    hash_backend: str = EXACT,
    max_hops: int = 16,
    quantum: float = 1.0,
    cool: bool = False,
    near: bool = False,
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Device twin of ``strategies._wave_walk_numpy``: one speculative
    wave-routing pass of every (flow, seed) cell against a *frozen*
    ``(S, L)`` load snapshot, decisions quantized to ``quantum`` and
    tie-broken with the documented ``hash % n_tie`` rule — bit-identical
    to the numpy wave walk under ``hash_backend="exact"`` (the
    cross-engine differential contract).  ``cool``/``near`` select the
    repair-arrival eligibility modes of ``_wave_choice``; they are
    jit-static, so each mode compiles once.  Returns host-side
    ``(ids[:hops], state, done)`` for the caller's arrival checks."""
    with _x64():
        _, jnp, _ = _jx()
        tabs = device_tables(comp)
        loads_q = jnp.asarray(np.floor(np.asarray(loads) / quantum))
        ids, state, done, t = _wave_walk_fn()(
            tabs.cand, tabs.cand_n, tabs.dev_crc, tabs.is_server,
            tabs.link_dst, jnp.asarray(src_dev), jnp.asarray(src_key),
            jnp.asarray(dst_key), jnp.asarray(field_mat),
            jnp.asarray(seeds_u64), loads_q,
            max_hops=max_hops, hash_backend=hash_backend,
            n_fields=int(field_mat.shape[1]),
            cool=bool(cool), near=bool(near))
        hops = int(t)
        return np.asarray(ids[:hops]), np.asarray(state), np.asarray(done)


# ---------------------------------------------------------------------------
# Stage 2: link counts + FIM (segment_sum + per-layer MAPE)
# ---------------------------------------------------------------------------


def _counts_jit():
    jax, jnp, _ = _jx()

    @functools.partial(jax.jit, static_argnames=("L",))
    def counts_fn(ids, weights, *, L: int):
        # ids: (H, Nf, S) device link ids; weights: (Nf,) or None-ones
        H, Nf, S = ids.shape
        offs = jnp.arange(S, dtype=jnp.int32) * jnp.int32(L)
        flat = jnp.where(ids >= 0, ids + offs[None, None, :], S * L)
        w = jnp.broadcast_to(weights[None, :, None], ids.shape)
        w = jnp.where(ids >= 0, w, 0.0)
        c = jax.ops.segment_sum(w.ravel(), flat.ravel(),
                                num_segments=S * L + 1)
        return c[: S * L].reshape(S, L)

    return counts_fn


@functools.lru_cache(maxsize=1)
def _counts_fn():
    return _counts_jit()


def jax_link_flow_counts(ids, weights, L: int):
    """(S, L) demand-weighted link loads from a device (H, Nf, S) link-id
    tensor — twin of ``VectorTraceResult.link_flow_counts``."""
    _, jnp, _ = _jx()
    return _counts_fn()(ids, jnp.asarray(np.asarray(weights, np.float64)),
                        L=L)


def _fim_jit():
    jax, jnp, _ = _jx()

    @functools.partial(jax.jit,
                       static_argnames=("only_used_leaves", "num_devices"))
    def fim_fn(counts, layer_sel, link_src, link_dst,
               *, only_used_leaves: bool, num_devices: int):
        # counts: (S, L) float; layer_sel: (NL, L) bool one-hot per layer
        S, L = counts.shape
        if only_used_leaves:
            present = counts > 0
            used_src = jax.ops.segment_max(
                present.astype(jnp.int32).T, link_src,
                num_segments=num_devices)          # (V, S)
            used_dst = jax.ops.segment_max(
                present.astype(jnp.int32).T, link_dst,
                num_segments=num_devices)
            used = (jnp.maximum(used_src, used_dst) > 0)   # (V, S)
            leaf_mask = (used[link_src] & used[link_dst]).T  # (S, L)
        else:
            leaf_mask = jnp.ones((S, L), bool)

        num = jnp.zeros(S)
        den = jnp.zeros(S)
        mapes = []
        for li in range(layer_sel.shape[0]):
            lm = layer_sel[li][None, :]            # (1, L)
            mask = (lm & leaf_mask).astype(jnp.float64)
            n_links = mask.sum(axis=1)
            total = (counts * mask).sum(axis=1)
            live = (total > 0) & (n_links > 0)
            ideal = jnp.where(live, total / jnp.maximum(n_links, 1), 1.0)
            mape = (100.0 / jnp.maximum(n_links, 1)
                    * (jnp.abs(counts - ideal[:, None])
                       / ideal[:, None] * mask).sum(1))
            mape = jnp.where(live, mape, 0.0)
            mapes.append((mape, live))
            num = num + jnp.where(live, mape * n_links, 0.0)
            den = den + jnp.where(live, n_links, 0.0)
        agg = jnp.where(den > 0, num / jnp.maximum(den, 1.0), 0.0)
        return agg, [m for m, _ in mapes], [lv for _, lv in mapes]

    return fim_fn


@functools.lru_cache(maxsize=1)
def _fim_fn():
    return _fim_jit()


def jax_fim_from_counts(
    counts,
    comp: CompiledFabric,
    *,
    layers: Sequence[str] | None = None,
    only_used_leaves: bool = False,
) -> tuple[np.ndarray, dict[str, np.ndarray]]:
    """Twin of ``vector_sim.fim_from_counts`` on a device (S, L) count
    matrix; returns host arrays with the same layer-dropping semantics."""
    _, jnp, _ = _jx()
    layer_list = list(layers) if layers else comp.layer_names
    names, sels = [], []
    for layer in layer_list:
        if layer not in comp.layer_names:
            continue
        lid = comp.layer_names.index(layer)
        sel = comp.link_layer == lid
        if not sel.any():
            continue
        names.append(layer)
        sels.append(sel)
    if not names:
        S = int(counts.shape[0])
        return np.zeros(S), {}
    agg, mapes, lives = _fim_fn()(
        counts, jnp.asarray(np.stack(sels)),
        jnp.asarray(comp.link_src), jnp.asarray(comp.link_dst),
        only_used_leaves=only_used_leaves, num_devices=comp.num_devices)
    per_layer: dict[str, np.ndarray] = {}
    for name, mape, live in zip(names, mapes, lives):
        if bool(np.asarray(live).any()):   # all-dead layers are dropped
            per_layer[name] = np.asarray(mape)
    return np.asarray(agg), per_layer


# ---------------------------------------------------------------------------
# Stage 3: weighted progressive max-min fill (lax.while_loop + segment ops)
# ---------------------------------------------------------------------------


def _fill_jit():
    jax, jnp, lax = _jx()

    @functools.partial(jax.jit, static_argnames=("SL",))
    def fill(cells, w, cap, *, SL: int):
        """cells: (H, C) int32 cell ids in [0, SL] (SL = sentinel),
        w: (C,) float64 positive weights, cap: (SL,) float64 capacity.
        Returns (C,) max-min rates; all-sentinel columns get inf.

        Same parallel local-bottleneck formulation as the numpy
        ``_fill_block_weighted``: freeze every flow crossing a cell whose
        fair share equals the min share on every member's path, drain,
        repeat.  The loop body is deliberately scatter-free: XLA's CPU
        scatter (behind ``jax.ops.segment_*``) is orders of magnitude
        slower than a gather, so the cell ids are sorted ONCE up front
        and every per-round segment reduction becomes cumsum-at-static-
        boundaries; frozen-ness lives in per-column masks instead of
        rewriting ids, keeping every id-derived index static.  The
        bottleneck test ``segment_min(fm) == share`` is replaced by the
        equivalent ``count(fm < share) == 0`` (``fm <= share`` always
        holds, since the cell's own share enters the min), which is a
        sum — and therefore cumsum-able.
        """
        H, C = cells.shape
        flat = cells.ravel()                       # static per call
        order = jnp.argsort(flat)
        scol = order % C                           # column of sorted cell
        sflat = flat[order]
        bounds = jnp.searchsorted(sflat, jnp.arange(SL + 2))
        valid_s = sflat < SL                       # real-link cells
        wB_s = w[scol]

        def segsum(v_s):                           # (H*C,) sorted -> (SL+1,)
            c = jnp.concatenate([jnp.zeros(1), jnp.cumsum(v_s)])
            return c[bounds[1:]] - c[bounds[:-1]]

        residual0 = jnp.concatenate([cap, jnp.zeros(1)])
        haslink = (cells < SL).any(axis=0)
        rates0 = jnp.where(haslink, 0.0, jnp.inf)

        def cond(c):
            return c[0].any()

        def body(c):
            active, residual, rates = c
            act_s = active[scol] & valid_s
            wsum = segsum(jnp.where(act_s, wB_s, 0.0))
            share = jnp.where(wsum > 0,
                              residual / jnp.maximum(wsum, 1e-300), jnp.inf)
            share = share.at[SL].set(jnp.inf)
            fm = share[cells].min(axis=0)          # per-flow bottleneck
            less = segsum(jnp.where(
                act_s & (fm[scol] < share[sflat]), 1.0, 0.0))
            freezable = (less == 0) & (wsum > 0)
            freezable = freezable.at[SL].set(False)
            fz = freezable[cells].any(axis=0) & active
            rates = jnp.where(fz, w * fm, rates)
            drained = segsum(jnp.where(
                fz[scol] & valid_s, wB_s * fm[scol], 0.0))
            return active & ~fz, residual - drained, rates

        out = lax.while_loop(cond, body, (haslink, residual0, rates0))
        return out[2]

    return fill


@functools.lru_cache(maxsize=1)
def _fill_fn():
    return _fill_jit()


def _fill_device(ids, link_gbps, weights, *, L: int):
    """Run the fill on a device (H, N, S) link-id tensor; returns the
    device (N, S) rate grid."""
    _, jnp, _ = _jx()
    H, N, S = ids.shape
    SL = S * L
    offs = jnp.arange(S, dtype=jnp.int32) * jnp.int32(L)
    cells = jnp.where(ids >= 0, ids + offs[None, None, :], SL)
    cells = cells.transpose(0, 2, 1).reshape(H, S * N)   # seed-major cols
    w = jnp.tile(jnp.asarray(np.asarray(weights, np.float64)), S)
    cap = jnp.tile(jnp.asarray(np.asarray(link_gbps, np.float64)), S)
    rates = _fill_fn()(cells, w, cap, SL=SL)
    return rates.reshape(S, N).T                         # (N, S)


def jax_batched_max_min(
    link_ids: np.ndarray,
    link_gbps: np.ndarray,
    *,
    assume_unique: bool = False,
    weights: np.ndarray | None = None,
) -> np.ndarray:
    """Drop-in twin of ``vector_throughput.batched_max_min`` on the jax
    engine (the ``seed_block`` knob does not apply: the device fill runs
    all seeds in one static-shape pass)."""
    link_ids = np.asarray(link_ids)
    if link_ids.ndim != 3:
        raise ValueError(f"link_ids must be (H, N, S), got {link_ids.shape}")
    if not assume_unique:
        from .vector_throughput import dedup_link_ids
        link_ids = dedup_link_ids(link_ids)
    H, N, S = link_ids.shape
    if weights is not None:
        weights = np.asarray(weights, np.float64)
        if weights.shape != (N,):
            raise ValueError(
                f"weights must be ({N},) to match link_ids columns, "
                f"got {weights.shape}")
        if not (weights > 0).all():
            raise ValueError("weights must be strictly positive")
    if weights is None:
        weights = np.ones(N)
    if H == 0 or N == 0 or S == 0:
        out = np.empty((N, S))
        out[:] = np.inf if H == 0 else 0.0
        return out
    with _x64():
        _, jnp, _ = _jx()
        rates = _fill_device(jnp.asarray(link_ids),
                             np.asarray(link_gbps, np.float64),
                             weights, L=len(link_gbps))
        return np.asarray(rates)


# ---------------------------------------------------------------------------
# Stage 4: flowlet exposure -> transport efficiency -> goodput
# ---------------------------------------------------------------------------


def _exposure_jit():
    jax, jnp, _ = _jx()

    @functools.partial(jax.jit, static_argnames=("n",))
    def exposure_fn(hop_counts, unit_rates, fi, *, n: int):
        # hop_counts/unit_rates: (Nf, S); fi: (Nf,) parent rows
        hops = hop_counts.astype(jnp.float64)
        hmin = jax.ops.segment_min(hops, fi, num_segments=n)
        hmax = jax.ops.segment_max(hops, fi, num_segments=n)
        skew = (hmax - hmin) / jnp.maximum(hmin, 1.0)
        finite = jnp.isfinite(unit_rates)
        rmin = jax.ops.segment_min(
            jnp.where(finite, unit_rates, jnp.inf), fi, num_segments=n)
        rmax = jax.ops.segment_max(
            jnp.where(finite, unit_rates, -jnp.inf), fi, num_segments=n)
        live = jnp.isfinite(rmax) & (rmax > 0)
        dispersion = jnp.where(
            live, (rmax - jnp.where(live, rmin, 0.0))
            / jnp.where(live, rmax, 1.0), 0.0)
        exposure = skew + dispersion
        return jnp.where(jnp.isfinite(exposure), exposure, 0.0)

    return exposure_fn


@functools.lru_cache(maxsize=1)
def _exposure_fn():
    return _exposure_jit()


def jax_flowlet_exposure(
    result: VectorTraceResult,
    flowlet_rates: np.ndarray | None = None,
) -> np.ndarray:
    """Twin of ``reordering.flowlet_exposure`` on the jax engine."""
    n, s = result.num_flows, result.num_seeds
    extra = result.extra_exposure
    fi = np.asarray(result.flow_index)
    if not result.is_multipath and fi.size == n and (
            fi == np.arange(n, dtype=np.int64)).all():
        base = np.zeros((n, s))
        return base if extra is None else base + extra
    if flowlet_rates is None:
        flowlet_rates = jax_batched_max_min(
            result.link_ids, result.compiled.link_gbps,
            assume_unique=True, weights=_column_weights_or_none(result))
    with _x64():
        _, jnp, _ = _jx()
        unit = np.asarray(flowlet_rates) / result.column_weights()[:, None]
        exposure = np.asarray(_exposure_fn()(
            jnp.asarray(result.hop_counts()), jnp.asarray(unit),
            jnp.asarray(fi.astype(np.int32)), n=n))
    return exposure if extra is None else exposure + extra


def _column_weights_or_none(result: VectorTraceResult):
    w = result.column_weights()
    return None if (w == 1.0).all() else w


# ---------------------------------------------------------------------------
# Fused front ends (plain-ECMP fast path: everything stays on device)
# ---------------------------------------------------------------------------


def _seed_chunks(n_flows: int, max_hops: int, S: int):
    per = max(1, _FUSED_SEED_CHUNK_CELLS // max(1, n_flows))
    for s0 in range(0, S, per):
        yield s0, min(s0 + per, S)


def _fused_walk_counts(comp, flows, seeds_u64, *, fields, hash_backend,
                       max_hops, field_matrix, flow_demand):
    """One device pass per seed chunk: walk + demand-weighted counts.
    Returns the host (S, L) count matrix (small: seeds x links)."""
    _, jnp, _ = _jx()
    field_mat = (field_matrix if field_matrix is not None
                 else flow_fields_matrix(flows, fields))
    src_dev, dst_dev, src_key, dst_key = comp.flow_endpoint_ids(flows)
    L = comp.num_links
    out = np.empty((len(seeds_u64), L))
    for s0, s1 in _seed_chunks(len(flows), max_hops, len(seeds_u64)):
        ids, state, done, t = _jax_walk_device(
            comp, src_dev, src_key, dst_key, field_mat, seeds_u64[s0:s1],
            hash_backend=hash_backend, max_hops=max_hops)
        if not bool(done.all()):
            raise RuntimeError(
                f"some flows did not terminate in {max_hops} hops")
        _check_walk(comp, state, dst_dev,
                    lambda n: f"flow {flows[n].flow_id}")
        ids = ids[: int(t)]
        out[s0:s1] = np.asarray(
            jax_link_flow_counts(ids, flow_demand, L))
    return out


def fused_monte_carlo_fim(
    fabric: Fabric | CompiledFabric,
    workload: WorkloadDescription | Sequence[Flow],
    seeds: Sequence[int] | np.ndarray,
    *,
    fields: str = FIELDS_5TUPLE,
    hash_backend: str = EXACT,
    layers: Sequence[str] | None = None,
    only_used_leaves: bool = False,
    demand_mode: str = DEMAND_UNIFORM,
    max_hops: int = 16,
    field_matrix: np.ndarray | None = None,
) -> MonteCarloFim:
    """Plain-ECMP Monte-Carlo FIM with walk + counts + FIM on device."""
    comp = (fabric if isinstance(fabric, CompiledFabric)
            else compile_fabric(fabric))
    flows = resolve_flows(comp, workload)
    seeds_u64 = normalize_seeds(seeds)
    if len(flows) == 0:
        raise ValueError("simulate_paths needs at least one flow")
    flow_demand = flow_demand_weights(flows, demand_mode)
    with _x64():
        _, jnp, _ = _jx()
        counts = _fused_walk_counts(
            comp, flows, seeds_u64, fields=fields,
            hash_backend=hash_backend, max_hops=max_hops,
            field_matrix=field_matrix, flow_demand=flow_demand)
        agg, per_layer = jax_fim_from_counts(
            jnp.asarray(counts), comp, layers=layers,
            only_used_leaves=only_used_leaves)
    return MonteCarloFim(seeds=seeds_u64, aggregate=agg,
                         per_layer=per_layer)


def fused_monte_carlo_throughput(
    fabric: Fabric | CompiledFabric,
    workload: WorkloadDescription | Sequence[Flow],
    seeds: Sequence[int] | np.ndarray,
    *,
    fields: str = FIELDS_5TUPLE,
    hash_backend: str = EXACT,
    demand_mode: str = DEMAND_UNIFORM,
    transport=None,
    max_hops: int = 16,
    field_matrix: np.ndarray | None = None,
):
    """Plain-ECMP Monte-Carlo throughput with walk + fill on device.

    Single-path ECMP has zero flowlet exposure, so (exactly like the
    numpy fast path) goodput is the raw rate grid under every transport
    profile — the exposure/efficiency stages engage through
    ``throughput_from_result(engine="jax")`` for multi-path strategies.
    """
    from .reordering import resolve_transport
    from .vector_throughput import MonteCarloThroughput, pair_rate_matrix
    comp = (fabric if isinstance(fabric, CompiledFabric)
            else compile_fabric(fabric))
    flows = resolve_flows(comp, workload)
    seeds_u64 = normalize_seeds(seeds)
    if len(flows) == 0:
        raise ValueError("simulate_paths needs at least one flow")
    flow_demand = flow_demand_weights(flows, demand_mode)
    profile = resolve_transport(transport)
    field_mat = (field_matrix if field_matrix is not None
                 else flow_fields_matrix(flows, fields))
    src_dev, dst_dev, src_key, dst_key = comp.flow_endpoint_ids(flows)
    N, S, L = len(flows), len(seeds_u64), comp.num_links
    rates = np.empty((N, S))
    with _x64():
        for s0, s1 in _seed_chunks(N, max_hops, S):
            ids, state, done, t = _jax_walk_device(
                comp, src_dev, src_key, dst_key, field_mat,
                seeds_u64[s0:s1], hash_backend=hash_backend,
                max_hops=max_hops)
            if not bool(done.all()):
                raise RuntimeError(
                    f"some flows did not terminate in {max_hops} hops")
            _check_walk(comp, state, dst_dev,
                        lambda n: f"flow {flows[n].flow_id}")
            ids = ids[: int(t)]
            rates[:, s0:s1] = np.asarray(_fill_device(
                ids, np.asarray(comp.link_gbps, np.float64),
                flow_demand, L=L))
    pairs, per_pair = pair_rate_matrix(flows, rates)
    return MonteCarloThroughput(
        seeds=seeds_u64, flows=flows, rates=rates, pairs=pairs,
        per_pair=per_pair, transport=profile.name)
