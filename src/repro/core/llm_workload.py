"""Synthetic LLM-training collective mixes -> byte-weighted DCN flows.

The paper's workload description (Step 1) names server pairs *and* flow
volumes, and real LLM training traffic is heavily non-uniform across the
parallelism axes (LLMPrism): one data-parallel gradient all-reduce moves
gigabytes per ring edge while a barrier moves bytes, with FSDP
all-gather / reduce-scatter and MoE all-to-all in between.  This module
generates that mix *without* needing a compiled HLO dump: it constructs
the same ``CollectiveOp`` records ``hlo_flows.extract_collectives``
would parse — ring all-reduce / all-gather / reduce-scatter over
cross-host rings, expert-parallel all-to-all over EP groups, a tiny
control barrier — and reuses ``collectives_to_flows`` for the
byte-accurate decomposition into RoCE 5-tuple flows.

Two committed scenarios anchor benchmarks and tests:

* ``paper_testbed_llm_workload`` — the job mapped onto the paper's
  16-server 2-rack testbed (every host its own "pod", so every
  cross-host ring edge is a DCN flow, like the RoCE cluster it models);
* ``multipod_llm_workload`` — the TPU adaptation: hosts grouped into
  pods, intra-pod edges ride the deterministic ICI torus and only
  pod-crossing edges enter the Clos fabric.

Feed the flows to ``simulate_paths(..., demand_mode="bytes")`` (or the
Monte-Carlo front ends) to weight FIM and max-min throughput by volume.
"""

from __future__ import annotations

import dataclasses

from .fabric import server_name
from .flows import Flow, WorkloadDescription, workload_from_flows
from .hlo_flows import (
    CollectiveOp, EdgeClassCounts, collectives_to_flows, wire_and_operand,
)
from .timeline import TimelineStep, flow_channel, register_channel


@dataclasses.dataclass(frozen=True, slots=True)
class LlmJobSpec:
    """Shape of a data/expert-parallel LLM training step.

    ``num_hosts`` hosts of ``chips_per_host`` accelerators each.  The DP
    gradient sync runs one ring per chip index across all hosts (the
    standard multi-ring layout, so every host's NICs carry a share); the
    per-layer FSDP all-gather / reduce-scatter reuse those rings;
    expert-parallel all-to-all spans ``ep_group_hosts``-host groups; and
    one 4-byte barrier all-reduce models the control plane.

    Volumes derive from ``param_bytes`` (model size x dtype width) and
    the per-chip activation slab ``tokens_per_chip x hidden x
    dtype_bytes`` exactly like the HLO parser would report them.
    """

    num_hosts: int
    chips_per_host: int = 2
    hosts_per_pod: int | None = None   # None: every host its own pod
    param_bytes: int = 2_000_000_000   # ~1B params in bf16
    num_layers: int = 24
    moe_layers: int = 4
    ep_group_hosts: int = 8
    tokens_per_chip: int = 4096
    hidden: int = 4096
    dtype_bytes: int = 2


def _ring_op(kind: str, result_bytes: int, rings, channel_id: int,
             multiplier: int = 1) -> CollectiveOp:
    n = max((len(g) for g in rings), default=1)
    wire, operand = wire_and_operand(kind, result_bytes, n)
    return CollectiveOp(
        kind=kind, result_bytes=result_bytes, operand_bytes=operand,
        wire_bytes=wire, groups=tuple(tuple(g) for g in rings), pairs=(),
        channel_id=channel_id, line_no=0, multiplier=multiplier)


def llm_collective_ops(spec: LlmJobSpec) -> list[CollectiveOp]:
    """The per-step collective mix as ``CollectiveOp`` records.

    Byte model (per device, one step):

    * gradient all-reduce: each of the ``chips_per_host`` rings reduces
      its ``param_bytes / chips_per_host`` shard across all hosts;
    * FSDP all-gather + reduce-scatter: one layer's parameter shard per
      execution, ``num_layers`` executions (a while-loop trip count in
      real HLO);
    * MoE all-to-all: the ``tokens_per_chip x hidden`` activation slab
      shuffled across the EP group, once per MoE layer;
    * barrier: a 4-byte all-reduce across hosts (control plane).
    """
    h, cph = spec.num_hosts, spec.chips_per_host
    rings = [[host * cph + c for host in range(h)] for c in range(cph)]
    ep_span = max(1, min(spec.ep_group_hosts, h))
    ep_groups = [
        [host * cph + c for host in range(h0, min(h0 + ep_span, h))]
        for h0 in range(0, h, ep_span)
        for c in range(cph)
        if min(h0 + ep_span, h) - h0 > 1
    ]
    shard = spec.param_bytes // cph
    layer_shard = max(1, shard // spec.num_layers)
    a2a_bytes = spec.tokens_per_chip * spec.hidden * spec.dtype_bytes
    ops = [
        _ring_op("all-reduce", shard, rings, channel_id=1),
        _ring_op("all-gather", layer_shard, rings, channel_id=2,
                 multiplier=spec.num_layers),
        _ring_op("reduce-scatter", max(1, layer_shard // spec.num_hosts),
                 rings, channel_id=3, multiplier=spec.num_layers),
        _ring_op("all-reduce", 4, rings[:1], channel_id=5),   # barrier
    ]
    if spec.moe_layers > 0 and ep_groups:
        ops.insert(3, _ring_op("all-to-all", a2a_bytes, ep_groups,
                               channel_id=4, multiplier=spec.moe_layers))
    return ops


def llm_flows(
    spec: LlmJobSpec,
    *,
    host_name: "callable[[int], str] | None" = None,
) -> tuple[list[Flow], EdgeClassCounts]:
    """Decompose the job's collectives into DCN flows on a fabric.

    ``coords`` placement: device ``d`` lives on host ``d // chips_per
    host``; hosts are grouped ``hosts_per_pod`` to a pod, or — when
    ``hosts_per_pod`` is None — each host is its own pod, which makes
    every cross-host ring edge a DCN flow (the flat RoCE-cluster regime
    of the paper testbed).
    """
    cph = spec.chips_per_host
    coords = {}
    for d in range(spec.num_hosts * cph):
        host = d // cph
        pod = host if spec.hosts_per_pod is None else host // spec.hosts_per_pod
        coords[d] = (pod, host, d % cph)
    return collectives_to_flows(llm_collective_ops(spec), coords,
                                host_name=host_name)


def llm_workload(
    spec: LlmJobSpec,
    *,
    host_name: "callable[[int], str] | None" = None,
) -> tuple[WorkloadDescription, list[Flow], EdgeClassCounts]:
    """(byte-weighted workload description, concrete flows, edge stats)."""
    flows, stats = llm_flows(spec, host_name=host_name)
    return workload_from_flows(flows), flows, stats


def paper_testbed_llm_workload(
    **overrides,
) -> tuple[WorkloadDescription, list[Flow], EdgeClassCounts]:
    """The LLM job on the paper's 16-server testbed (``srv-i`` hosts).

    Every host is its own "pod" so all cross-host collective edges ride
    the 2-rack Clos — the heterogeneous sibling of the uniform 256-flow
    bipartite workload the paper saturates the fabric with.  Volumes
    span ~9 orders of magnitude (multi-GB all-reduce edges down to a
    7-byte barrier), which is exactly the regime where byte-weighted FIM
    diverges from unweighted FIM.
    """
    spec = LlmJobSpec(**{"num_hosts": 16, "hosts_per_pod": None,
                         **overrides})
    return llm_workload(spec, host_name=server_name)


def multipod_llm_workload(
    **overrides,
) -> tuple[WorkloadDescription, list[Flow], EdgeClassCounts]:
    """The LLM job across TPU pods (``host-i`` hosts of
    ``build_multipod_fabric``): intra-pod ring edges stay on ICI, only
    pod-crossing edges (DP ring seams + EP groups spanning pods) become
    DCN flows.  Defaults match the downscaled 2-pod x 8-host fabric the
    test suite uses."""
    spec = LlmJobSpec(**{"num_hosts": 16, "chips_per_host": 4,
                         "hosts_per_pod": 8, "ep_group_hosts": 16,
                         **overrides})
    return llm_workload(spec)


# ---------------------------------------------------------------------------
# Phase schedules (core/timeline.py)
# ---------------------------------------------------------------------------

#: channel map of ``llm_collective_ops``, the schedule vocabulary —
#: registered by name so schedule-validation errors print ``CH_*``
#: identifiers instead of bare ints (core/timeline.py registry)
CH_GRAD_AR = register_channel(1, "CH_GRAD_AR")
CH_FSDP_AG = register_channel(2, "CH_FSDP_AG")
CH_FSDP_RS = register_channel(3, "CH_FSDP_RS")
CH_MOE_A2A = register_channel(4, "CH_MOE_A2A")
CH_BARRIER = register_channel(5, "CH_BARRIER")

#: every collective runs alone, in training-step order — the synchronous
#: schedule of a vanilla FSDP/EP step (no comm/comm overlap)
SCHEDULE_SEQUENTIAL = "sequential"
#: gradient all-reduce overlapped into the backward phase (the standard
#: DP-overlap optimization), MoE shuffle overlapped with the forward
#: all-gather — two fat phases instead of four thin ones
SCHEDULE_DP_OVERLAP = "dp-overlap"


def llm_collective_phases(
    spec: LlmJobSpec, mode: str = SCHEDULE_SEQUENTIAL,
) -> tuple[list[CollectiveOp], list[TimelineStep]]:
    """Schedule-emitting variant of ``llm_collective_ops``: the same op
    list plus the ``TimelineStep`` schedule assigning each op's channel
    to a phase of the training step.

    ``"sequential"`` runs every collective in its own step — forward
    all-gather, MoE all-to-all, backward reduce-scatter, gradient
    all-reduce, barrier — which is what the merged snapshot mis-models
    hardest (it charges every phase the contention of all five).
    ``"dp-overlap"`` folds the gradient all-reduce into the backward
    phase and the MoE shuffle into the forward phase, the usual
    comm/comm overlap; the barrier stays its own (tiny) step.

    Steps carry equal default durations, read under ``timing="static"``
    (see core/timeline.py for why durations, not byte shares; under
    ``timing="event"`` durations are derived from the flows' byte
    volumes and the routed goodput instead).  Phases whose collective is
    absent from the spec (``moe_layers=0``) are dropped here — and
    ``llm_schedule`` additionally filters against the channels the
    *flows* actually carry, because ``simulate_timeline`` validates
    strictly and raises on a step no flow serves.
    """
    ops = llm_collective_ops(spec)
    if mode == SCHEDULE_SEQUENTIAL:
        schedule = [
            TimelineStep("fwd-all-gather", (CH_FSDP_AG,)),
            TimelineStep("moe-all-to-all", (CH_MOE_A2A,)),
            TimelineStep("bwd-reduce-scatter", (CH_FSDP_RS,)),
            TimelineStep("grad-all-reduce", (CH_GRAD_AR,)),
            TimelineStep("barrier", (CH_BARRIER,)),
        ]
    elif mode == SCHEDULE_DP_OVERLAP:
        schedule = [
            TimelineStep("forward", (CH_FSDP_AG, CH_MOE_A2A)),
            TimelineStep("backward", (CH_FSDP_RS, CH_GRAD_AR)),
            TimelineStep("barrier", (CH_BARRIER,)),
        ]
    else:
        raise ValueError(
            f"unknown schedule mode {mode!r}; expected "
            f"{SCHEDULE_SEQUENTIAL!r} or {SCHEDULE_DP_OVERLAP!r}")
    present = {op.channel_id for op in ops}
    schedule = [s for s in schedule
                if any(ch in present for ch in s.channels)]
    return ops, schedule


def llm_schedule(
    spec: LlmJobSpec,
    mode: str = SCHEDULE_SEQUENTIAL,
    *,
    host_name: "callable[[int], str] | None" = None,
) -> tuple[WorkloadDescription, list[Flow], EdgeClassCounts,
           list[TimelineStep]]:
    """Schedule-emitting variant of ``llm_workload``: the same
    (workload, flows, stats) triple plus the phase schedule, ready for
    ``simulate_timeline(fabric, flows, schedule, seeds)``.

    The schedule is filtered against the channels the emitted flows
    actually carry: a collective can be present in the op list yet
    produce zero DCN flows (e.g. a ring confined to one pod rides the
    ICI torus), and ``partition_flows`` rightly refuses a step no flow
    serves.  Each flow carries its byte volume (``Flow.bytes``), which
    is what gives ``timing="event"`` its per-step byte totals
    (``step_byte_totals``)."""
    _, schedule = llm_collective_phases(spec, mode)
    wl, flows, stats = llm_workload(spec, host_name=host_name)
    present = {flow_channel(f) for f in flows}
    schedule = [
        TimelineStep(s.name,
                     tuple(ch for ch in s.channels if ch in present),
                     s.duration)
        for s in schedule
        if any(ch in present for ch in s.channels)
    ]
    return wl, flows, stats, schedule


def paper_testbed_llm_schedule(
    mode: str = SCHEDULE_SEQUENTIAL, **overrides,
) -> tuple[WorkloadDescription, list[Flow], EdgeClassCounts,
           list[TimelineStep]]:
    """``paper_testbed_llm_workload`` plus its phase schedule."""
    spec = LlmJobSpec(**{"num_hosts": 16, "hosts_per_pod": None,
                         **overrides})
    return llm_schedule(spec, mode, host_name=server_name)


def multipod_llm_schedule(
    mode: str = SCHEDULE_SEQUENTIAL, **overrides,
) -> tuple[WorkloadDescription, list[Flow], EdgeClassCounts,
           list[TimelineStep]]:
    """``multipod_llm_workload`` plus its phase schedule."""
    spec = LlmJobSpec(**{"num_hosts": 16, "chips_per_host": 4,
                         "hosts_per_pod": 8, "ep_group_hosts": 16,
                         **overrides})
    return llm_schedule(spec, mode)
