"""Preprogrammed routing + topology-aware placement.

``static_route_assignment`` automates the paper's second configuration
("a preprogrammed static routing configuration, which promotes the
selection of distinct paths across the different communication pairs"):
instead of hand-programming switch tables, we walk every flow through the
fabric and at each multi-choice hop pick the least-loaded equal-cost
egress link (ties broken deterministically).  The result is a
(device, flow) -> egress-port table consumable by ``StaticRouting``.

Beyond the paper (§V future work: "dynamic routing adjustments"), this
module also optimizes the *traffic itself*:

* ``topology_aware_ring``   — reorder a collective ring so consecutive
  devices share a host, then a pod: inter-pod DCN edges drop from O(n) to
  the theoretical minimum (2 per pod boundary pair).
* ``balanced_port_spread``  — assign the per-edge flows of a collective to
  NIC ports/uplinks round-robin, the static analogue for DCN flows.
"""

from __future__ import annotations

from collections import defaultdict
from collections.abc import Mapping, Sequence

from .ecmp import Forwarder
from .fabric import Fabric, Link, SERVER
from .flows import Flow

Path = list[Link]


def _interleave_by_pair(flows: Sequence[Flow]) -> list[Flow]:
    by_pair: dict[tuple[str, str], list[Flow]] = defaultdict(list)
    for f in flows:
        by_pair[(f.src, f.dst)].append(f)
    ordered: list[Flow] = []
    queues = list(by_pair.values())
    i = 0
    while any(queues):
        q = queues[i % len(queues)]
        if q:
            ordered.append(q.pop(0))
        i += 1
    return ordered


def enumerate_paths(fabric: Fabric, fwd: Forwarder, flow: Flow,
                    max_paths: int = 4096) -> list[Path]:
    """All equal-cost end-to-end paths for a flow (DFS over the per-hop
    candidate sets)."""
    out: list[Path] = []
    stack: list[tuple[str, Path]] = [(flow.src, [])]
    while stack and len(out) < max_paths:
        device, prefix = stack.pop()
        for link in fwd.candidates(device, flow):
            path = prefix + [link]
            if fabric.kind(link.dst) == SERVER:
                out.append(path)
            else:
                stack.append((link.dst, path))
    return out


def static_route_assignment(
    fabric: Fabric,
    flows: Sequence[Flow],
    *,
    mode: str = "minmax",
) -> tuple[dict[tuple[str, int], str], dict[int, Path]]:
    """Compute the paper's "preprogrammed static routing" automatically.

    ``minmax`` (default): for each flow (pair-interleaved order), enumerate
    its equal-cost paths and pick the one minimizing (max link load along
    the path, then total load, then name) — destination-aware, so it
    balances *every* layer including spine->leaf downlinks, which a
    per-hop greedy cannot see.  ``hop_greedy`` is the cheaper per-hop
    variant for very large flow sets.

    Returns the static table {(device, flow_id): egress port} — exactly
    what an operator would preprogram into each device — plus the paths.
    """
    fwd = Forwarder(fabric)
    load: dict[str, int] = defaultdict(int)
    table: dict[tuple[str, int], str] = {}
    paths: dict[int, Path] = {}
    ordered = _interleave_by_pair(flows)

    for flow in ordered:
        if mode == "minmax":
            cands = enumerate_paths(fabric, fwd, flow)
            path = min(
                cands,
                key=lambda p: (
                    max(load[l.name] + 1 for l in p),
                    sum(load[l.name] for l in p),
                    tuple(l.name for l in p),
                ),
            )
        elif mode == "hop_greedy":
            path = []
            device = flow.src
            for _ in range(32):
                hop_cands = fwd.candidates(device, flow)
                link = min(hop_cands, key=lambda l: (load[l.name], l.name))
                path.append(link)
                if fabric.kind(link.dst) == SERVER:
                    break
                device = link.dst
        else:
            raise ValueError(mode)
        for link in path:
            load[link.name] += 1
            src_dev = link.src
            if len(fwd.candidates(src_dev, flow)) > 1:
                table[(src_dev, flow.flow_id)] = link.src_port
        paths[flow.flow_id] = path
    return table, paths


# ---------------------------------------------------------------------------
# Beyond-paper: collective-aware placement
# ---------------------------------------------------------------------------


def topology_aware_ring(
    group: Sequence[int], coords: Mapping[int, tuple[int, int, int]]
) -> list[int]:
    """Reorder a replica group so ring neighbours are topologically close.

    ``coords[d] = (pod, host, chip)``.  Sorting lexicographically makes all
    intra-host hops adjacent, then intra-pod, leaving exactly one
    pod-crossing edge per pod boundary (plus the wrap-around) — the minimum
    any ring can achieve.
    """
    return sorted(group, key=lambda d: coords[d])


def ring_edge_stats(
    group: Sequence[int], coords: Mapping[int, tuple[int, int, int]]
) -> dict[str, int]:
    """Count ring edges by locality class (chip/host/pod crossing)."""
    stats = {"intra_host": 0, "intra_pod": 0, "inter_pod": 0}
    n = len(group)
    for i in range(n):
        a, b = coords[group[i]], coords[group[(i + 1) % n]]
        if a[0] != b[0]:
            stats["inter_pod"] += 1
        elif a[1] != b[1]:
            stats["intra_pod"] += 1
        else:
            stats["intra_host"] += 1
    return stats


def balanced_port_spread(num_flows: int, num_ports: int) -> list[int]:
    """Static round-robin of flows onto ports (a 1-hop static table)."""
    return [i % num_ports for i in range(num_flows)]
