"""Flowlet reordering cost: out-of-order exposure -> goodput efficiency.

Spraying a flow over K paths trades path balance against out-of-order
delivery (paper Section V): packets of one flow now race each other down
paths with different residual congestion and (on irregular fabrics)
different hop counts, and what the receiver can *use* depends on how the
transport absorbs the resulting reordering — RoCE's go-back-N style NACK
semantics collapse under it, while an STrack-like transport (arXiv
2407.15266) tracks out-of-order ranges and loses little.  Until this
module existed the simulator modeled spraying as free, so every strategy
matrix overstated the spray win by construction.

The model has two transport-independent and one transport-dependent
stage, all vectorized over the ``(N flows, S seeds)`` Monte-Carlo grid:

1. **Exposure** (``flowlet_exposure``): a dimensionless per-(flow, seed)
   measure of how much out-of-order delivery the routing *induces*,
   computed from the flowlet columns of a ``VectorTraceResult``:

   * *path-length skew* — ``(max - min) / max(min, 1)`` of the hop
     counts across the flow's flowlets (packets on a longer path arrive
     structurally late);
   * *rate dispersion* — ``(max - min) / max`` of the flowlets' max-min
     rates per unit demand (a slow flowlet is a congested path, i.e.
     queueing delay the fast flowlets do not see).

   Both terms are exactly 0 for a single-flowlet flow, so every
   single-path strategy (and ``K=1`` spraying) has zero exposure by
   construction.

2. **Efficiency** (``reordering_efficiency``): a ``TransportProfile``
   maps exposure to a goodput multiplier in ``(0, 1]``::

       efficiency = 1 + (1 - floor) * expm1(-alpha * exposure)

   i.e. exponential decay from exactly 1.0 at zero exposure toward the
   profile's ``floor``.  ``expm1`` keeps the zero-exposure case *bit*-
   exact (no ``0.7 + 0.3`` float residue), which is what makes
   "K=1 spray == ECMP including effective goodput" hold to the last ulp.
   Efficiency is monotonically non-increasing in exposure for any valid
   profile — property-tested in tests/test_reordering.py.

3. **Goodput**: ``effective_goodput = max-min rate x efficiency``,
   surfaced by ``throughput_from_result`` / ``monte_carlo_throughput``
   via ``transport=`` (see core/vector_throughput.py).

Three profiles ship registered: ``ideal`` (reordering is free — the
pre-PR-5 behaviour, and the default), ``roce-nack`` (go-back-N-ish:
steep decay, low floor) and ``strack`` (out-of-order tracking: shallow
decay, high floor).  Register custom transports with
``register_transport``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .vector_sim import VectorTraceResult, segment_reduce


@dataclasses.dataclass(frozen=True)
class TransportProfile:
    """Reordering tolerance of a transport: exposure -> efficiency.

    ``alpha`` is the decay rate (how fast goodput erodes per unit
    exposure) and ``floor`` the asymptotic efficiency under unbounded
    reordering (the transport's worst case).  ``alpha=0`` or ``floor=1``
    makes reordering free.
    """

    name: str
    alpha: float
    floor: float

    def __post_init__(self):
        if self.alpha < 0:
            raise ValueError(f"alpha must be >= 0, got {self.alpha}")
        if not 0.0 < self.floor <= 1.0:
            raise ValueError(f"floor must be in (0, 1], got {self.floor}")


#: reordering is free — the historical model, and the default everywhere
IDEAL = TransportProfile("ideal", alpha=0.0, floor=1.0)
#: go-back-N-ish RoCE NACK semantics: any reordering triggers
#: retransmission of the whole window, goodput collapses fast
ROCE_NACK = TransportProfile("roce-nack", alpha=3.0, floor=0.25)
#: STrack-like out-of-order tracking (arXiv 2407.15266): the transport
#: absorbs most reordering, mild decay with a high floor
STRACK = TransportProfile("strack", alpha=0.6, floor=0.8)

_TRANSPORTS: dict[str, TransportProfile] = {}


def register_transport(profile: TransportProfile) -> TransportProfile:
    """Register ``profile`` so ``transport="name"`` resolves to it."""
    _TRANSPORTS[profile.name] = profile
    return profile


def available_transports() -> list[str]:
    return sorted(_TRANSPORTS)


def resolve_transport(
    transport: TransportProfile | str | None,
) -> TransportProfile:
    """A profile instance passes through; a name looks up the registry;
    ``None`` means ``ideal`` (reordering-free, the historical model)."""
    if transport is None:
        return IDEAL
    if isinstance(transport, TransportProfile):
        return transport
    if isinstance(transport, str):
        try:
            return _TRANSPORTS[transport]
        except KeyError:
            raise ValueError(
                f"unknown transport profile {transport!r}; "
                f"registered: {available_transports()}") from None
    raise TypeError(
        f"transport must be a TransportProfile, registered name, or None, "
        f"got {type(transport).__name__}")


for _p in (IDEAL, ROCE_NACK, STRACK):
    register_transport(_p)


def flowlet_exposure(
    result: VectorTraceResult,
    flowlet_rates: np.ndarray | None = None,
) -> np.ndarray:
    """(N, S) out-of-order exposure per flow per seed.

    ``flowlet_rates`` is the ``(Nf, S)`` per-column max-min rate tensor
    (``max_min_rates(result)``); passing it lets callers that already
    ran the fill (``throughput_from_result``) avoid a second one.
    Zero-link flowlets carry infinite max-min rates; they traverse no
    shared queue, so they are excluded from the dispersion term (a flow
    whose flowlets are *all* link-free disperses nothing).
    """
    n, s = result.num_flows, result.num_seeds
    fi = np.asarray(result.flow_index)
    if not result.is_multipath and fi.size == n and (
            fi == np.arange(n)).all():
        return np.zeros((n, s))            # single-path: no reordering

    hops = result.hop_counts().astype(np.float64)                 # (Nf, S)
    hmin = segment_reduce(hops, fi, n, np.minimum, np.inf)
    hmax = segment_reduce(hops, fi, n, np.maximum, -np.inf)
    skew = (hmax - hmin) / np.maximum(hmin, 1.0)

    if flowlet_rates is None:
        from .vector_throughput import max_min_rates
        flowlet_rates = max_min_rates(result)
    unit = flowlet_rates / result.column_weights()[:, None]
    finite = np.isfinite(unit)
    rmin = segment_reduce(np.where(finite, unit, np.inf), fi, n,
                          np.minimum, np.inf)
    rmax = segment_reduce(np.where(finite, unit, -np.inf), fi, n,
                          np.maximum, -np.inf)
    live = np.isfinite(rmax) & (rmax > 0)
    dispersion = np.where(live, (rmax - np.where(live, rmin, 0.0))
                          / np.where(live, rmax, 1.0), 0.0)
    exposure = skew + dispersion
    # parents with no columns (possible only through hand-built results)
    # reorder nothing; scrub the fallback's inf/nan seeds
    return np.where(np.isfinite(exposure), exposure, 0.0)


def reordering_efficiency(
    exposure: np.ndarray,
    transport: TransportProfile | str | None = None,
) -> np.ndarray:
    """Goodput multiplier in ``(0, 1]`` for an exposure array.

    ``1 + (1 - floor) * expm1(-alpha * exposure)``: exactly 1.0 at zero
    exposure (``expm1(-0) == 0`` — no float residue, so unexposed flows
    keep bit-identical goodput), decaying monotonically toward
    ``floor``.
    """
    p = resolve_transport(transport)
    e = np.asarray(exposure, np.float64)
    if (e < 0).any():
        raise ValueError("exposure must be non-negative")
    return 1.0 + (1.0 - p.floor) * np.expm1(-p.alpha * e)
