"""Flowlet reordering cost: out-of-order exposure -> goodput efficiency.

Spraying a flow over K paths trades path balance against out-of-order
delivery (paper Section V): packets of one flow now race each other down
paths with different residual congestion and (on irregular fabrics)
different hop counts, and what the receiver can *use* depends on how the
transport absorbs the resulting reordering — RoCE's go-back-N style NACK
semantics collapse under it, while an STrack-like transport (arXiv
2407.15266) tracks out-of-order ranges and loses little.  Until this
module existed the simulator modeled spraying as free, so every strategy
matrix overstated the spray win by construction.

The model has two transport-independent and one transport-dependent
stage, all vectorized over the ``(N flows, S seeds)`` Monte-Carlo grid:

1. **Exposure** (``flowlet_exposure``): a dimensionless per-(flow, seed)
   measure of how much out-of-order delivery the routing *induces*,
   computed from the flowlet columns of a ``VectorTraceResult``:

   * *path-length skew* — ``(max - min) / max(min, 1)`` of the hop
     counts across the flow's flowlets (packets on a longer path arrive
     structurally late);
   * *rate dispersion* — ``(max - min) / max`` of the flowlets' max-min
     rates per unit demand (a slow flowlet is a congested path, i.e.
     queueing delay the fast flowlets do not see).

   Both terms are exactly 0 for a single-flowlet flow, so every
   single-path strategy (and ``K=1`` spraying) has zero exposure by
   construction.

2. **Efficiency** (``reordering_efficiency``): a ``TransportProfile``
   maps exposure to a goodput multiplier in ``(0, 1]``::

       efficiency = 1 + (1 - floor) * expm1(-alpha * exposure)

   i.e. exponential decay from exactly 1.0 at zero exposure toward the
   profile's ``floor``.  ``expm1`` keeps the zero-exposure case *bit*-
   exact (no ``0.7 + 0.3`` float residue), which is what makes
   "K=1 spray == ECMP including effective goodput" hold to the last ulp.
   Efficiency is monotonically non-increasing in exposure for any valid
   profile — property-tested in tests/test_reordering.py.

3. **Goodput**: ``effective_goodput = max-min rate x efficiency``,
   surfaced by ``throughput_from_result`` / ``monte_carlo_throughput``
   via ``transport=`` (see core/vector_throughput.py).

Adaptive re-spray adds a fourth, *strategy-induced* exposure source: a
``VectorTraceResult`` may carry ``extra_exposure`` (each accepted
mid-flow path change of ``AdaptiveSpraying`` is a reordering burst the
static skew/dispersion terms cannot see), which ``flowlet_exposure``
adds on top.  ``None`` — every static strategy — keeps the PR-5 model
bit-exact.

Three profiles ship registered: ``ideal`` (reordering is free — the
pre-PR-5 behaviour, and the default), ``roce-nack`` (go-back-N
semantics) and ``strack`` (out-of-order tracking).  The lossy two are
no longer stylized constants: ``calibrate_transport`` fits alpha/floor
against anchor points read off the published goodput-vs-reordering
curves (STrack, arXiv 2407.15266 — STrack itself and its go-back-N
RoCE baseline, the regime IRN established), so the goodput claims the
strategy matrices make are anchored to measured transport behaviour.
Register custom transports with ``register_transport`` (duplicate names
raise — a silent overwrite would quietly re-anchor every benchmark).
"""

from __future__ import annotations

import dataclasses
from collections.abc import Sequence

import numpy as np

from .vector_sim import VectorTraceResult, segment_reduce


#: one fabric round-trip at datacenter scale (tens of microseconds) —
#: the unit the event-timed timeline prices adaptation in: a transfer
#: shorter than one RTT never sees feedback, so it cannot re-spray
DEFAULT_RTT_SECONDS = 25e-6


@dataclasses.dataclass(frozen=True)
class TransportProfile:
    """Reordering tolerance of a transport: exposure -> efficiency.

    ``alpha`` is the decay rate (how fast goodput erodes per unit
    exposure) and ``floor`` the asymptotic efficiency under unbounded
    reordering (the transport's worst case).  ``alpha=0`` or ``floor=1``
    makes reordering free.

    ``rtt_seconds`` is the transport's feedback loop length: under
    event-timed replay (``timing="event"``) an ``AdaptiveSpraying`` step
    gets one re-spray opportunity per RTT of its *derived* duration
    (``rtt_round_budget``), so the exposure charged for adaptation
    scales with how long the step actually holds the wire.  Static
    snapshots never read it.
    """

    name: str
    alpha: float
    floor: float
    rtt_seconds: float = DEFAULT_RTT_SECONDS

    def __post_init__(self):
        if self.alpha < 0:
            raise ValueError(f"alpha must be >= 0, got {self.alpha}")
        if not 0.0 < self.floor <= 1.0:
            raise ValueError(f"floor must be in (0, 1], got {self.floor}")
        if not self.rtt_seconds > 0:
            raise ValueError(
                f"rtt_seconds must be > 0, got {self.rtt_seconds}")


def calibrate_transport(
    name: str,
    anchors: Sequence[tuple[float, float]],
    *,
    grid: int = 4000,
) -> TransportProfile:
    """Fit a ``TransportProfile`` through published (exposure,
    efficiency) anchor points.

    The model ``eff = 1 + (1 - floor) * expm1(-alpha * exposure)`` is
    linear in ``(1 - floor)`` once alpha is fixed, so the fit is a 1-D
    deterministic grid search over alpha (log-spaced) with the
    closed-form least-squares ``floor`` at each candidate — no SciPy, no
    randomness, same constants on every machine.  Anchors at exposure 0
    are redundant (the model passes through (0, 1) exactly) and
    rejected to keep calibration data honest.
    """
    pts = [(float(x), float(y)) for x, y in anchors]
    if len(pts) < 2:
        raise ValueError(f"need >= 2 anchor points, got {len(pts)}")
    for x, y in pts:
        if x <= 0:
            raise ValueError(
                f"anchor exposure must be > 0 (the model is exact at 0), "
                f"got {x}")
        if not 0.0 < y < 1.0:
            raise ValueError(f"anchor efficiency must be in (0, 1), got {y}")
    x = np.array([p[0] for p in pts], dtype=np.float64)
    y = np.array([p[1] for p in pts], dtype=np.float64)
    alphas = np.exp(np.linspace(np.log(1e-3), np.log(50.0), grid))
    g = np.expm1(-alphas[:, None] * x[None, :])        # (grid, P)
    # least squares for u = 1 - floor in  (y - 1) = u * g,  clipped to
    # the valid floor range (0, 1]
    u = np.clip((g * (y - 1.0)[None, :]).sum(1) / (g * g).sum(1),
                0.0, 1.0 - 1e-9)
    sse = (((1.0 + u[:, None] * g) - y[None, :]) ** 2).sum(1)
    best = int(np.argmin(sse))
    return TransportProfile(name, alpha=float(alphas[best]),
                            floor=float(1.0 - u[best]))


#: reordering is free — the historical model, and the default everywhere
IDEAL = TransportProfile("ideal", alpha=0.0, floor=1.0)

#: anchor points (exposure, goodput efficiency) read off the published
#: goodput-vs-reordering behaviour in STrack (arXiv 2407.15266).  The
#: exposure axis is this module's dimensionless skew+dispersion measure:
#: ~0.25 is mild multipath reordering (packet spraying on a balanced
#: symmetric Clos), ~1 is heavy reordering (spraying across paths with
#: clearly unequal congestion), >=4 is the adversarial regime (spraying
#: plus failures/asymmetry).
#:
#: * RoCE with go-back-N loss recovery (STrack's RoCEv2 baseline; the
#:   regime IRN, SIGCOMM'18, measured): out-of-order arrivals are NACKed
#:   and the whole window retransmits, so goodput falls off a cliff —
#:   roughly a quarter of line rate once reordering is heavy, and it
#:   does not recover with more reordering (every window is already
#:   being resent).
ROCE_NACK_ANCHORS = ((0.25, 0.78), (0.5, 0.60), (1.0, 0.38), (4.0, 0.26))
#: * STrack tracks out-of-order ranges per path and selectively repeats
#:   only the missing ranges, sustaining near-line-rate goodput under
#:   spraying (its headline claim: ~39% over RoCE at 1% loss, minor
#:   degradation from reordering alone) with a high asymptotic floor.
STRACK_ANCHORS = ((0.25, 0.985), (0.5, 0.97), (1.0, 0.945), (4.0, 0.88))

#: go-back-N RoCE NACK semantics, calibrated through ROCE_NACK_ANCHORS
ROCE_NACK = calibrate_transport("roce-nack", ROCE_NACK_ANCHORS)
#: STrack-like out-of-order tracking, calibrated through STRACK_ANCHORS
STRACK = calibrate_transport("strack", STRACK_ANCHORS)

_TRANSPORTS: dict[str, TransportProfile] = {}


def register_transport(profile: TransportProfile, *,
                       replace: bool = False) -> TransportProfile:
    """Register ``profile`` so ``transport="name"`` resolves to it.

    A duplicate name raises unless ``replace=True``: every benchmark and
    test resolves transports by name, so silently overwriting e.g.
    ``"roce-nack"`` would re-anchor all their goodput numbers without a
    trace."""
    if not replace and profile.name in _TRANSPORTS:
        raise ValueError(
            f"transport profile {profile.name!r} is already registered "
            f"(registered: {available_transports()}); pass replace=True "
            f"to overwrite it")
    _TRANSPORTS[profile.name] = profile
    return profile


def available_transports() -> list[str]:
    return sorted(_TRANSPORTS)


def resolve_transport(
    transport: TransportProfile | str | None,
) -> TransportProfile:
    """A profile instance passes through; a name looks up the registry;
    ``None`` means ``ideal`` (reordering-free, the historical model)."""
    if transport is None:
        return IDEAL
    if isinstance(transport, TransportProfile):
        return transport
    if isinstance(transport, str):
        try:
            return _TRANSPORTS[transport]
        except KeyError:
            raise ValueError(
                f"unknown transport profile {transport!r}; "
                f"registered: {available_transports()}") from None
    raise TypeError(
        f"transport must be a TransportProfile, registered name, or None, "
        f"got {type(transport).__name__}")


for _p in (IDEAL, ROCE_NACK, STRACK):
    register_transport(_p)


def flowlet_exposure(
    result: VectorTraceResult,
    flowlet_rates: np.ndarray | None = None,
    engine: str = "numpy",
) -> np.ndarray:
    """(N, S) out-of-order exposure per flow per seed.

    ``engine="jax"`` runs the per-parent segment reductions (and any
    needed fill) on the device engine (``jax_engine.jax_flowlet_exposure``,
    differential-tested at 1e-6 against this host path).

    ``flowlet_rates`` is the ``(Nf, S)`` per-column max-min rate tensor
    (``max_min_rates(result)``); passing it lets callers that already
    ran the fill (``throughput_from_result``) avoid a second one.
    Zero-link flowlets carry infinite max-min rates; they traverse no
    shared queue, so they are excluded from the dispersion term (a flow
    whose flowlets are *all* link-free disperses nothing).

    ``result.extra_exposure`` — strategy-induced reordering the static
    terms cannot see (adaptive re-spray's accepted mid-flow path
    changes) — is added on top; ``None`` and all-zero both keep the
    static model's values bit-identical (``x + 0.0 == x`` for the
    non-negative exposures both terms produce).
    """
    if engine != "numpy":
        from .jax_engine import jax_flowlet_exposure, resolve_engine
        resolve_engine(engine)
        return jax_flowlet_exposure(result, flowlet_rates)
    n, s = result.num_flows, result.num_seeds
    extra = result.extra_exposure
    fi = np.asarray(result.flow_index)
    if not result.is_multipath and fi.size == n and (
            fi == np.arange(n, dtype=np.int64)).all():
        base = np.zeros((n, s))            # single-path: no reordering
        return base if extra is None else base + extra

    hops = result.hop_counts().astype(np.float64)                 # (Nf, S)
    hmin = segment_reduce(hops, fi, n, np.minimum, np.inf)
    hmax = segment_reduce(hops, fi, n, np.maximum, -np.inf)
    skew = (hmax - hmin) / np.maximum(hmin, 1.0)

    if flowlet_rates is None:
        from .vector_throughput import max_min_rates
        flowlet_rates = max_min_rates(result)
    unit = flowlet_rates / result.column_weights()[:, None]
    finite = np.isfinite(unit)
    rmin = segment_reduce(np.where(finite, unit, np.inf), fi, n,
                          np.minimum, np.inf)
    rmax = segment_reduce(np.where(finite, unit, -np.inf), fi, n,
                          np.maximum, -np.inf)
    live = np.isfinite(rmax) & (rmax > 0)
    dispersion = np.where(live, (rmax - np.where(live, rmin, 0.0))
                          / np.where(live, rmax, 1.0), 0.0)
    exposure = skew + dispersion
    # parents with no columns (possible only through hand-built results)
    # reorder nothing; scrub the fallback's inf/nan seeds
    exposure = np.where(np.isfinite(exposure), exposure, 0.0)
    return exposure if extra is None else exposure + extra


def rtt_round_budget(duration_s: float, rtt_s: float, cap: int) -> int:
    """Adaptation rounds a transfer of ``duration_s`` seconds affords.

    ``AdaptiveSpraying`` re-picks entropy once per RTT of congestion
    feedback; under event-timed replay the step duration is *derived*
    from the routed goodput, so the honest round budget is the number of
    RTTs the step actually spans: ``ceil(duration / rtt)``, floored at 1
    (the initial pick always happens — a sub-RTT barrier simply cannot
    adapt) and capped at the strategy's configured ``rounds`` (the
    herd-damped adaptation converges; simulating thousands of identical
    quiet rounds would only cost time).  This is what makes re-spray
    exposure a per-unit-*time* charge: a step that holds the wire longer
    pays for more adaptation, a blink-length step pays for none.
    """
    if not rtt_s > 0:
        raise ValueError(f"rtt_s must be > 0, got {rtt_s}")
    if cap < 1:
        raise ValueError(f"cap must be >= 1, got {cap}")
    if not duration_s >= 0:                # also rejects nan
        raise ValueError(f"duration_s must be >= 0, got {duration_s}")
    return int(np.clip(np.ceil(duration_s / rtt_s), 1, cap))


def reordering_efficiency(
    exposure: np.ndarray,
    transport: TransportProfile | str | None = None,
) -> np.ndarray:
    """Goodput multiplier in ``(0, 1]`` for an exposure array.

    ``1 + (1 - floor) * expm1(-alpha * exposure)``: exactly 1.0 at zero
    exposure (``expm1(-0) == 0`` — no float residue, so unexposed flows
    keep bit-identical goodput), decaying monotonically toward
    ``floor``.
    """
    p = resolve_transport(transport)
    e = np.asarray(exposure, np.float64)
    if (e < 0).any():
        raise ValueError("exposure must be non-negative")
    return 1.0 + (1.0 - p.floor) * np.expm1(-p.alpha * e)
