"""Path Analyzer (paper Steps 6-7): compile traced paths into the final,
easy-to-consume output — per-layer link-load tables, FIM, collision list.
"""

from __future__ import annotations

import dataclasses
import json
from collections.abc import Mapping, Sequence

from .fabric import Fabric, Link
from .fim import fim, layer_load_stats

Path = list[Link]


@dataclasses.dataclass
class PathReport:
    total_flows: int
    per_layer: dict[str, dict[str, int]]      # layer -> link name -> count
    per_layer_fim: dict[str, float]           # layer -> FIM %
    aggregate_fim: float
    collisions: list[tuple[str, int]]         # links above ideal, worst first
    ideal_per_layer: dict[str, float]

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2, sort_keys=True)

    def summary(self) -> str:
        lines = [f"FlowTracer report: {self.total_flows} flows traced"]
        for layer, lf in self.per_layer_fim.items():
            ideal = self.ideal_per_layer[layer]
            lines.append(f"  [{layer:14s}] FIM = {lf:6.2f}%  (ideal {ideal:.2f} flows/link)")
        lines.append(f"  aggregate FIM = {self.aggregate_fim:.2f}%")
        if self.collisions:
            worst = ", ".join(f"{n}={c}" for n, c in self.collisions[:5])
            lines.append(f"  worst links: {worst}")
        return "\n".join(lines)


def analyze_paths(
    paths: Mapping[int, Path],
    fabric: Fabric,
    *,
    layers: Sequence[str] | None = None,
) -> PathReport:
    # one layer_load_stats pass carries the per-link counts, totals,
    # ideals, and FIM together (fim.py is the single source; empty
    # layers are guarded there), so the report cannot disagree with the
    # metric it annotates
    stats = layer_load_stats(paths, fabric, layers=layers)

    collisions = [
        (name, c)
        for s in stats.values()
        for name, c in s.link_counts.items()
        if c > s.ideal
    ]
    collisions.sort(key=lambda x: -x[1])

    return PathReport(
        total_flows=len(paths),
        per_layer={k: dict(s.link_counts) for k, s in stats.items()},
        per_layer_fim={k: s.fim_pct for k, s in stats.items()},
        aggregate_fim=fim(paths, fabric, layers=layers),
        collisions=collisions,
        ideal_per_layer={k: s.ideal for k, s in stats.items()},
    )
