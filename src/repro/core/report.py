"""Path Analyzer (paper Steps 6-7): compile traced paths into the final,
easy-to-consume output — per-layer link-load tables, FIM, collision list.
"""

from __future__ import annotations

import dataclasses
import json
from collections import defaultdict
from collections.abc import Mapping, Sequence

from .fabric import Fabric, Link
from .fim import fim, link_flow_counts, per_layer_fim

Path = list[Link]


@dataclasses.dataclass
class PathReport:
    total_flows: int
    per_layer: dict[str, dict[str, int]]      # layer -> link name -> count
    per_layer_fim: dict[str, float]           # layer -> FIM %
    aggregate_fim: float
    collisions: list[tuple[str, int]]         # links above ideal, worst first
    ideal_per_layer: dict[str, float]

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2, sort_keys=True)

    def summary(self) -> str:
        lines = [f"FlowTracer report: {self.total_flows} flows traced"]
        for layer, lf in self.per_layer_fim.items():
            ideal = self.ideal_per_layer[layer]
            lines.append(f"  [{layer:14s}] FIM = {lf:6.2f}%  (ideal {ideal:.2f} flows/link)")
        lines.append(f"  aggregate FIM = {self.aggregate_fim:.2f}%")
        if self.collisions:
            worst = ", ".join(f"{n}={c}" for n, c in self.collisions[:5])
            lines.append(f"  worst links: {worst}")
        return "\n".join(lines)


def analyze_paths(
    paths: Mapping[int, Path],
    fabric: Fabric,
    *,
    layers: Sequence[str] | None = None,
) -> PathReport:
    counts = link_flow_counts(paths)
    layer_fims = per_layer_fim(paths, fabric, layers=layers)
    per_layer: dict[str, dict[str, int]] = defaultdict(dict)
    ideal: dict[str, float] = {}
    for layer, (f_val, n_links) in layer_fims.items():
        links = fabric.links_by_layer(layer)
        total = 0
        for l in links:
            c = counts.get(l.name, 0)
            per_layer[layer][l.name] = c
            total += c
        ideal[layer] = total / len(links)

    collisions = []
    for layer, linkmap in per_layer.items():
        for name, c in linkmap.items():
            if c > ideal[layer]:
                collisions.append((name, c))
    collisions.sort(key=lambda x: -x[1])

    return PathReport(
        total_flows=len(paths),
        per_layer={k: dict(v) for k, v in per_layer.items()},
        per_layer_fim={k: v[0] for k, v in layer_fims.items()},
        aggregate_fim=fim(paths, fabric, layers=layers),
        collisions=collisions,
        ideal_per_layer=ideal,
    )
