"""Pluggable routing strategies over the compiled-fabric candidate tables.

The paper's whole point is comparing routing schemes by flow imbalance
(Fig. 3: ECMP vs static).  PR 1-2 built the vectorized N-flows x S-seeds
path + max-min throughput engine; this module makes the *routing
decision* pluggable on top of it, so the schemes from the related work
can be evaluated under the same Monte-Carlo harness:

* ``EcmpStrategy`` — baseline per-flow ECMP, bit-identical to
  ``simulate_paths``'s default walk (and therefore to ``EcmpRouting`` +
  ``FlowTracer``); differential-tested in tests/test_strategies.py.
* ``PrimeSpraying`` — PRIME-style multi-part-entropy spraying
  (arXiv 2507.23012): each flow splits into K flowlets carrying 1/K of
  the demand, and every flowlet gets a distinct entropy label appended
  to its hash fields.  The label is *multi-part*: the flowlet index is
  decomposed into mixed-radix digits over ``parts`` and each digit rides
  as its own extra header field, so every switch's pseudo-random hash
  integrates several independently varying entropy sources.  K=1 appends
  nothing and degenerates to ECMP exactly.  ``min_bytes`` makes the
  spraying *demand-aware* (split only elephants, optionally with
  volume-proportional K) — spraying is not free (core/reordering.py
  prices the out-of-order delivery), so PRIME sprays selectively.
* ``CongestionAware`` — greedy congestion-aware path selection in the
  spirit of Predictive Load Balancing (arXiv 2506.08132): flows are
  placed one at a time and every hop picks the candidate egress link
  with the least demand already routed through it, falling back to the
  flow's ECMP hash only to break exact load ties (which keeps the
  hash-seed sweep meaningful: seeds explore the tie space).

A strategy consumes the compiled fabric + flow table + seed sweep and
returns a ``VectorTraceResult``; multi-path strategies emit flowlet
columns with ``flow_index`` / ``demand`` metadata, which
``link_flow_counts`` (demand-weighted FIM) and the weighted
``batched_max_min`` rate model aggregate back per parent flow.

Register custom schemes with ``register_strategy``; ``simulate_paths``
/ ``monte_carlo_fim`` / ``monte_carlo_throughput`` accept either a
registered name or a strategy instance via ``strategy=``.
"""

from __future__ import annotations

import dataclasses
import warnings
from collections.abc import Callable, Sequence

import numpy as np

from .compile_fabric import CompiledFabric
from .ecmp import FIELDS_5TUPLE, flow_fields_matrix
from .flows import Flow
from .vector_sim import (
    DEMAND_UNIFORM, ENGINE_NUMPY, EXACT, VectorTraceResult, ecmp_walk,
    flow_demand_weights, hash_grid,
)


class RoutingStrategy:
    """Interface: turn (compiled fabric, flows, seeds) into routed paths.

    ``route`` receives the already-normalized inputs from
    ``simulate_paths`` and must return a ``VectorTraceResult`` whose
    flowlet ``demand`` fractions sum to 1 per parent flow, carrying the
    ``demand_mode``-derived per-flow weights in ``flow_demand``
    (``flow_demand_weights`` is the standard derivation).  Strategies
    are free to *route* on the weights too — congestion-aware places
    heavy flows first.
    """

    #: registry name; instances may be configured, the name is the family
    name: str = "?"

    def route(
        self,
        comp: CompiledFabric,
        flows: list[Flow],
        seeds_u64: np.ndarray,
        *,
        fields: str = FIELDS_5TUPLE,
        hash_backend: str = EXACT,
        max_hops: int = 16,
        field_matrix: np.ndarray | None = None,
        demand_mode: str = DEMAND_UNIFORM,
        engine: str = ENGINE_NUMPY,
    ) -> VectorTraceResult:
        raise NotImplementedError


class EcmpStrategy(RoutingStrategy):
    """Per-flow ECMP — the baseline, bit-identical to the default walk."""

    name = "ecmp"

    def route(self, comp, flows, seeds_u64, *, fields=FIELDS_5TUPLE,
              hash_backend=EXACT, max_hops=16, field_matrix=None,
              demand_mode=DEMAND_UNIFORM, engine=ENGINE_NUMPY):
        from .vector_sim import simulate_paths
        return simulate_paths(comp, flows, seeds_u64, fields=fields,
                              hash_backend=hash_backend, max_hops=max_hops,
                              field_matrix=field_matrix,
                              demand_mode=demand_mode, engine=engine)


def _balanced_parts(k: int) -> tuple[int, ...]:
    """Default multi-part split of K flowlets: the most balanced two-factor
    decomposition (8 -> (2, 4)); prime or unit K stays single-part."""
    for a in range(int(np.sqrt(k)), 1, -1):
        if k % a == 0:
            return (a, k // a)
    return (k,)


#: default elephant threshold for demand-aware spraying: 64 MiB — on the
#: committed LLM scenarios this sprays the DP/FSDP ring elephants (which
#: carry ~80-97% of the bytes) and leaves the MB-scale MoE shuffles and
#: control mice on their ECMP paths
ELEPHANT_MIN_BYTES = 64 * 1024 * 1024


class PrimeSpraying(RoutingStrategy):
    """PRIME-style multi-part-entropy packet spraying (arXiv 2507.23012).

    Each flow is split into up to ``flowlets`` equal-demand flowlets;
    flowlet ``k``'s entropy label is the mixed-radix digit vector of
    ``k`` over ``parts`` (product must equal ``flowlets``), appended to
    the flow's hash fields as extra columns so every switch hash
    integrates all entropy parts.  With ``flowlets=1`` no label is
    appended and the walk is bit-identical to ``EcmpStrategy``.

    **Demand-aware spraying** (``min_bytes``): PRIME sprays adaptively,
    not blindly — splitting a mouse buys no balance (its bytes are
    noise) but still costs out-of-order delivery (core/reordering.py).
    With ``min_bytes`` set, only flows with ``Flow.bytes >= min_bytes``
    are split; the rest ride their exact per-flow ECMP path — the
    unsprayed columns are walked *without* entropy columns, so they stay
    bit-identical to ``EcmpStrategy`` flow by flow, and
    ``min_bytes=inf`` degenerates to ECMP wholesale.  ``volume_k=True``
    additionally makes K volume-proportional: ``min_bytes`` becomes the
    target bytes *per flowlet* and each flow splits into
    ``clip(ceil(bytes / min_bytes), 1, flowlets)`` flowlets, so a 2 GiB
    elephant fans wide while a 100 MiB flow (at the 64 MiB default
    target) splits in two; flows at or under one target-chunk stay
    single-path.

    ``min_bytes`` reads raw ``Flow.bytes`` — the elephant decision is a
    property of the workload, independent of the ``demand_mode``
    normalization used for FIM/max-min weighting.
    """

    name = "prime-spray"

    def __init__(self, flowlets: int = 8,
                 parts: Sequence[int] | None = None,
                 min_bytes: float | None = None,
                 volume_k: bool = False):
        if flowlets < 1:
            raise ValueError(f"flowlets must be >= 1, got {flowlets}")
        self.flowlets = int(flowlets)
        self.parts = (tuple(int(p) for p in parts) if parts is not None
                      else _balanced_parts(self.flowlets))
        if any(p < 1 for p in self.parts):
            raise ValueError(f"entropy parts must be >= 1: {self.parts}")
        if int(np.prod(self.parts)) != self.flowlets:
            raise ValueError(
                f"entropy parts {self.parts} do not multiply to "
                f"{self.flowlets} flowlets")
        if min_bytes is not None and not min_bytes > 0:
            raise ValueError(f"min_bytes must be > 0, got {min_bytes}")
        if volume_k and min_bytes is None:
            raise ValueError(
                "volume_k needs min_bytes (the target bytes per flowlet)")
        self.min_bytes = min_bytes
        self.volume_k = bool(volume_k)

    def entropy_labels(self) -> np.ndarray:
        """(K, P) uint64 mixed-radix digits, one row per flowlet."""
        k = np.arange(self.flowlets, dtype=np.uint64)
        cols = []
        for base in self.parts:
            cols.append(k % np.uint64(base))
            k = k // np.uint64(base)
        return np.stack(cols, axis=1)

    def flowlet_counts(self, flows: Sequence[Flow]) -> np.ndarray:
        """(N,) int64 flowlets per flow under the demand-aware policy."""
        n = len(flows)
        if self.min_bytes is None:
            return np.full(n, self.flowlets, np.int64)
        b = np.array([f.bytes for f in flows], np.float64)
        if self.volume_k:
            # ceil, not floor: one flowlet per started min_bytes chunk,
            # so anything over one chunk actually splits
            with np.errstate(invalid="ignore"):   # min_bytes=inf: b/inf -> 0
                k = np.ceil(b / self.min_bytes)
            return np.clip(np.nan_to_num(k), 1, self.flowlets).astype(np.int64)
        return np.where(b >= self.min_bytes, self.flowlets, 1).astype(np.int64)

    def route(self, comp, flows, seeds_u64, *, fields=FIELDS_5TUPLE,
              hash_backend=EXACT, max_hops=16, field_matrix=None,
              demand_mode=DEMAND_UNIFORM, engine=ENGINE_NUMPY):
        field_mat = (field_matrix if field_matrix is not None
                     else flow_fields_matrix(flows, fields))
        n = len(flows)
        k_f = self.flowlet_counts(flows)
        if (self.min_bytes is not None and np.isfinite(self.min_bytes)
                and n and all(f.bytes == 0 for f in flows)):
            # an explicit finite threshold against a volume-less workload
            # is almost certainly a mistake: every flow stays single-path
            # and the "spraying" comparison silently measures plain ECMP
            warnings.warn(
                f"{self.name}: min_bytes={self.min_bytes:g} but every "
                f"Flow.bytes is 0 (workload carries no volumes) — no flow "
                f"sprays, this is ECMP", stacklevel=2)
        total = int(k_f.sum())
        flow_index = np.repeat(np.arange(n, dtype=np.int32), k_f)
        starts = np.concatenate(([0], np.cumsum(k_f)[:-1]))
        local = np.arange(total, dtype=np.int64) - np.repeat(starts, k_f)
        demand = np.repeat(1.0 / k_f, k_f)
        endpoints = comp.flow_endpoint_ids(flows)
        sprayed = k_f[flow_index] > 1          # per column

        def walk(cols: np.ndarray, with_labels: bool) -> np.ndarray:
            fm = field_mat[flow_index[cols]]
            if with_labels:
                fm = np.concatenate(
                    [fm, self.entropy_labels()[local[cols]]], axis=1)
            ep = tuple(a[flow_index[cols]] for a in endpoints)
            return ecmp_walk(
                comp, *ep, fm, seeds_u64,
                hash_backend=hash_backend, max_hops=max_hops,
                engine=engine,
                describe=lambda j: (
                    f"flow {flows[int(flow_index[cols[int(j)]])].flow_id} "
                    f"flowlet {int(local[cols[int(j)]])}"))

        if sprayed.all():
            link_ids = walk(np.arange(total, dtype=np.int64), with_labels=True)
        elif not sprayed.any():
            # nothing crosses the elephant bar (or flowlets=1): one
            # label-free walk, bit-identical to EcmpStrategy
            link_ids = walk(np.arange(total, dtype=np.int64), with_labels=False)
        else:
            # mixed: sprayed columns walk with entropy labels, unsprayed
            # flows walk label-free (each stays on its exact ECMP path),
            # then the two tensors interleave back into parent order
            p_cols = np.flatnonzero(sprayed)
            u_cols = np.flatnonzero(~sprayed)
            p_ids = walk(p_cols, with_labels=True)
            u_ids = walk(u_cols, with_labels=False)
            hops = max(p_ids.shape[0], u_ids.shape[0])
            link_ids = np.full((hops, total, len(seeds_u64)), -1, np.int32)
            link_ids[:p_ids.shape[0], p_cols] = p_ids
            link_ids[:u_ids.shape[0], u_cols] = u_ids
        return VectorTraceResult(
            compiled=comp, flows=list(flows), seeds=seeds_u64,
            link_ids=link_ids, flow_index=flow_index,
            demand=demand, strategy=self.name,
            flow_demand=flow_demand_weights(flows, demand_mode))


def _weighted_link_loads(link_ids: np.ndarray, weights: np.ndarray,
                         num_links: int) -> np.ndarray:
    """(S, L) demand-weighted link loads of an ``(H, Nf, S)`` tensor —
    the same bincount ``VectorTraceResult.link_flow_counts`` runs, over
    an explicit tensor (adaptive re-spray recomputes it per round on its
    evolving paths)."""
    S = link_ids.shape[2]
    offset = np.arange(S, dtype=np.int64) * num_links
    keep = link_ids >= 0
    flat = (link_ids.astype(np.int64) + offset)[keep]
    w = np.broadcast_to(weights[None, :, None], link_ids.shape)[keep]
    return np.bincount(flat, weights=w,
                       minlength=S * num_links).reshape(S, num_links)


def _splitmix64(x: np.ndarray) -> np.ndarray:
    """Vectorized SplitMix64 finalizer — a stateless uint64 mixer for the
    adaptive re-spray coin flips (deterministic in the cell/seed/round
    coordinates, no global RNG state)."""
    z = x + np.uint64(0x9E3779B97F4A7C15)
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


def _path_max_load(link_ids: np.ndarray, loads: np.ndarray) -> np.ndarray:
    """(C, S) hottest-link load along each column's path, given (S, L)
    link loads.  Link-free columns read 0 (they queue nowhere)."""
    S, L = loads.shape
    flat = loads.reshape(-1)
    cells = link_ids.astype(np.int64) + (np.arange(S, dtype=np.int64) * L)
    vals = np.where(link_ids >= 0,
                    flat[np.where(link_ids >= 0, cells, 0)], 0.0)
    return vals.max(axis=0) if link_ids.shape[0] else np.zeros(
        link_ids.shape[1:])


class AdaptiveSpraying(PrimeSpraying):
    """PRIME's headline *adaptive* mode: per-RTT entropy re-pick under
    congestion feedback (arXiv 2507.23012).

    Static spraying commits each flowlet to one entropy label for the
    whole transfer; PRIME instead treats the label as disposable — when
    the fabric's feedback (ECN marks / RTT inflation) says a flowlet's
    path is congested, the sender re-picks the entropy value on the next
    round, re-rolling every switch hash on that flowlet's walk.  This
    strategy simulates ``rounds`` such RTTs on top of the (bit-identical)
    ``PrimeSpraying`` round-0 allocation:

    1. **feedback**: demand-weighted link loads of the current paths,
       per seed; a flowlet is *marked* when its path's hottest link
       carries more than ``ecn_factor`` x that seed's mean loaded-link
       load (the ECN-threshold analogue);
    2. **re-pick**: every marked (flowlet, seed) cell draws a fresh
       entropy salt (a new label value) and walks its candidate path
       against the frozen load snapshot;
    3. **accept**: the move is kept only when the candidate's hottest
       link plus the flowlet's own demand undercuts its current path's
       hottest link — the sender keeps entropy that works and discards
       picks that land somewhere worse (REPS-style "recycle good
       entropy"; cf. the accept/repair policy of arXiv 2506.08132).

    Unmarked cells keep their salt, so their walks replay bit-identically
    (``x ^ 0 == x`` in the salted walk) and a run whose feedback never
    fires returns exactly the static allocation.  ``rounds=1`` *is*
    ``PrimeSpraying`` wholesale.

    Re-picking is not free: every accepted move is a mid-flow path
    change — a reordering burst the static skew/dispersion exposure
    cannot see — charged as ``respray_cost`` per accepted round, scaled
    by the moved flowlet's demand fraction, via
    ``VectorTraceResult.extra_exposure`` (core/reordering.py adds it to
    the transport model's exposure).  The PR-5 lesson priced blind
    spraying; this prices the adaptation itself.
    """

    name = "adaptive-spray"

    def __init__(self, flowlets: int = 8,
                 parts: Sequence[int] | None = None,
                 min_bytes: float | None = None,
                 volume_k: bool = False,
                 rounds: int = 4,
                 ecn_factor: float = 1.25,
                 respray_cost: float = 0.05,
                 move_prob: float = 0.25):
        super().__init__(flowlets, parts, min_bytes=min_bytes,
                         volume_k=volume_k)
        if rounds < 1:
            raise ValueError(f"rounds must be >= 1, got {rounds}")
        if not ecn_factor > 0:
            raise ValueError(f"ecn_factor must be > 0, got {ecn_factor}")
        if respray_cost < 0:
            raise ValueError(
                f"respray_cost must be >= 0, got {respray_cost}")
        if not 0.0 < move_prob <= 1.0:
            raise ValueError(
                f"move_prob must be in (0, 1], got {move_prob}")
        self.rounds = int(rounds)
        self.ecn_factor = float(ecn_factor)
        self.respray_cost = float(respray_cost)
        self.move_prob = float(move_prob)

    def with_rounds(self, rounds: int) -> "AdaptiveSpraying":
        """A copy of this strategy with a different round budget — every
        other knob unchanged.  Event-timed replay (core/timeline.py)
        uses it to express ``rounds`` in RTTs of the *derived* step
        duration (``reordering.rtt_round_budget``): ``self.rounds``
        becomes the cap, and a step shorter than one RTT routes with the
        static round-1 allocation."""
        if rounds == self.rounds:
            return self
        return AdaptiveSpraying(
            self.flowlets, self.parts, min_bytes=self.min_bytes,
            volume_k=self.volume_k, rounds=rounds,
            ecn_factor=self.ecn_factor, respray_cost=self.respray_cost,
            move_prob=self.move_prob)

    def route(self, comp, flows, seeds_u64, *, fields=FIELDS_5TUPLE,
              hash_backend=EXACT, max_hops=16, field_matrix=None,
              demand_mode=DEMAND_UNIFORM, engine=ENGINE_NUMPY):
        res = super().route(comp, flows, seeds_u64, fields=fields,
                            hash_backend=hash_backend, max_hops=max_hops,
                            field_matrix=field_matrix,
                            demand_mode=demand_mode, engine=engine)
        if self.rounds == 1 or not res.is_multipath:
            return res                     # static spray / ECMP degenerate
        field_mat = (field_matrix if field_matrix is not None
                     else flow_fields_matrix(flows, fields))
        n, s = len(flows), len(seeds_u64)
        fi, demand = res.flow_index, res.demand
        col_w = res.column_weights()
        k_f = self.flowlet_counts(flows)
        spray_cols = np.flatnonzero(k_f[fi] > 1)
        starts = np.concatenate(([0], np.cumsum(k_f)[:-1]))
        local = np.arange(fi.size, dtype=np.int64) - starts[fi]
        # fixed walk inputs for the sprayed columns: the same entropy
        # labels as round 0, so salt == 0 replays the base walk exactly
        fm_s = np.concatenate(
            [field_mat[fi[spray_cols]],
             self.entropy_labels()[local[spray_cols]]], axis=1)
        endpoints = comp.flow_endpoint_ids(flows)
        ep_s = tuple(a[fi[spray_cols]] for a in endpoints)
        w_col = col_w[spray_cols][:, None]         # (C, 1)
        link_ids = res.link_ids
        salt = np.zeros((spray_cols.size, s), np.uint64)
        probe = np.zeros((spray_cols.size, s), np.uint64)
        resprays = np.zeros((spray_cols.size, s))

        def walk(cell_salt):
            return ecmp_walk(
                comp, *ep_s, fm_s, seeds_u64, hash_backend=hash_backend,
                max_hops=max_hops, cell_salt=cell_salt, engine=engine,
                describe=lambda j: (
                    f"flow {flows[int(fi[spray_cols[int(j)]])].flow_id} "
                    f"respray flowlet {int(local[spray_cols[int(j)]])}"))

        # per-cell coin identity: decorrelated across flowlets and seeds,
        # re-mixed with the round index below so each round flips fresh
        cell_id = (_splitmix64(spray_cols.astype(np.uint64))[:, None]
                   ^ seeds_u64[None, :])
        p_bits = np.uint64(int(self.move_prob * 2.0 ** 53))
        for rnd in range(self.rounds - 1):
            loads = _weighted_link_loads(link_ids, col_w, comp.num_links)
            cur = link_ids[:, spray_cols, :]
            path_max = _path_max_load(cur, loads)
            mean_load = (loads.sum(axis=1)
                         / np.maximum((loads > 0).sum(axis=1), 1))
            marked = path_max > self.ecn_factor * mean_load[None, :]
            if not marked.any():
                break
            # herd damping: every marked cell re-picks only with
            # probability ``move_prob`` per round — acceptance is judged
            # against a frozen load snapshot, so letting every congested
            # flowlet move at once stampedes them onto the same cool
            # links and *creates* the next hotspot
            coin = _splitmix64(
                cell_id ^ np.uint64((rnd + 1) * 0xD1B54A32D192ED03 &
                                    0xFFFFFFFFFFFFFFFF))
            marked &= (coin >> np.uint64(11)) < p_bits
            if not marked.any():
                continue
            probe = probe + marked                  # fresh salt per probe
            cand = walk(np.where(marked, probe, salt))
            cand_max = _path_max_load(cand, loads)
            accept = marked & (cand_max + w_col < path_max)
            if not accept.any():
                continue
            salt = np.where(accept, probe, salt)
            resprays += accept
            hops = max(link_ids.shape[0], cand.shape[0])
            merged = np.full((hops,) + cur.shape[1:], -1, np.int32)
            np.copyto(merged[:cur.shape[0]], cur)
            np.copyto(merged[:cand.shape[0]], cand[:hops],
                      where=accept[None, :, :])
            nxt = np.full((hops,) + link_ids.shape[1:], -1, np.int32)
            np.copyto(nxt[:link_ids.shape[0]], link_ids)
            nxt[:, spray_cols, :] = merged
            link_ids = nxt
        extra = np.zeros((n, s))
        np.add.at(extra, fi[spray_cols],
                  resprays * demand[spray_cols][:, None])
        return dataclasses.replace(res, link_ids=link_ids,
                                   extra_exposure=self.respray_cost * extra)


def _sequential_congestion_place(
    comp: CompiledFabric,
    flows: Sequence[Flow],
    field_mat: np.ndarray,
    seeds_u64: np.ndarray,
    endpoints: tuple,
    flow_demand: np.ndarray,
    order: np.ndarray,
    load: np.ndarray,
    link_ids: np.ndarray,
    *,
    hash_backend: str,
    max_hops: int,
    mask: np.ndarray | None = None,
) -> int:
    """The sequential greedy placement loop, shared by ``CongestionAware``
    (its whole route) and ``WaveCongestionAware`` (the round-cap fallback
    for still-conflicted residue).

    Routes the flows of ``order`` one at a time against — and charging —
    the ``(S, L)`` ``load`` tally, writing paths into ``link_ids`` (both
    mutated in place; ``load`` may arrive pre-seeded with already-committed
    demand).  With ``mask`` (an ``(N, S)`` bool of still-unplaced cells)
    only the True cells of each flow are written and charged: the walk is
    still vectorized over every seed, but committed cells keep their paths
    and are never double-counted.  Returns the hop-count high-water mark.
    """
    src_dev, dst_dev, src_key, dst_key = endpoints
    s = len(seeds_u64)
    load_flat = load.reshape(-1)           # writable view for scatters
    rows = np.arange(s, dtype=np.int64)
    row_off = rows * comp.num_links
    cand_w = comp.cand.shape[-1]
    col_idx = np.arange(cand_w, dtype=np.int64)[None, :]
    hops = 0
    for j in order:
        m = None if mask is None else mask[j]
        if m is not None and not m.any():
            continue
        w_j = flow_demand[j]
        state = np.full(s, int(src_dev[j]), np.int64)
        done = np.zeros(s, bool)
        t_end = 0
        for t in range(max_hops):
            if done.all():
                break
            t_end = t + 1
            key = np.where(comp.is_server[state], src_key[j], dst_key[j])
            nc = comp.cand_n[state, key]               # (S,)
            cw = min(int(nc.max()), cand_w) or 1       # live table width
            cands = comp.cand[state, key, :cw]         # (S, cw)
            valid = (col_idx[:, :cw] < nc[:, None]) & (cands >= 0)
            cl = np.where(valid,
                          load_flat[row_off[:, None]
                                    + np.maximum(cands, 0)],
                          np.inf)
            tie = valid & (cl == cl.min(axis=1)[:, None])
            n_tie = tie.sum(axis=1)
            multi = n_tie > 1
            if multi.any():                # hash only when a tie exists
                dev_seed = comp.dev_crc[state] ^ seeds_u64
                h = hash_grid(field_mat[j:j + 1], dev_seed[None, :],
                              hash_backend)[0]
                rank = np.where(
                    multi,
                    (h % np.maximum(n_tie, 1).astype(np.uint64)).astype(
                        np.int64),
                    0)
                col = (tie.cumsum(axis=1) <= rank[:, None]).sum(axis=1)
            else:
                col = tie.argmax(axis=1)   # unique minimum (or 0)
            link = cands[rows, np.minimum(col, cw - 1)]
            link = np.where(done | (nc == 0), -1, link)
            if m is None:
                link_ids[t, j] = link
            else:
                link_ids[t, j, m] = link[m]
            active = link >= 0
            nxt = np.where(active, comp.link_dst[np.maximum(link, 0)],
                           state)
            done |= ~active | comp.is_server[nxt]
            state = nxt
        hops = max(hops, t_end)
        settled = done if m is None else (done | ~m)
        if not settled.all():
            raise RuntimeError(
                f"flow {flows[j].flow_id} did not terminate in "
                f"{max_hops} hops")
        arrived = done & (state == dst_dev[j])
        if m is not None:
            arrived |= ~m
        if not arrived.all():
            bad = int(np.flatnonzero(~arrived)[0])
            raise RuntimeError(
                f"flow {flows[j].flow_id} (seed index {bad}) terminated "
                f"at {comp.device_names[int(state[bad])]}, expected "
                f"{flows[j].dst}")
        # fused load tally over all hops at once: (seed, link) cells of
        # one flow are unique (loop-free path, per-device link ids), so
        # a direct fancy-index add is exact — no ufunc.at needed
        taken = link_ids[:t_end, j]                    # (h, S)
        keep = taken >= 0
        if m is not None:
            keep = keep & m[None, :]
        cells = (taken.astype(np.int64) + row_off[None, :])[keep]
        load_flat[cells] += w_j
    return hops


class CongestionAware(RoutingStrategy):
    """Greedy congestion-aware selection (cf. arXiv 2506.08132).

    Flows are routed sequentially (the placement order models a
    connection-setup sequence); at every hop the flow takes the candidate
    egress link carrying the least demand routed so far *under that
    seed*, with the flow's ECMP hash breaking exact load ties.  Under
    ``demand_mode="bytes"`` flows are placed **largest-first** (the
    standard greedy bin-packing order — elephants claim the emptiest
    paths while the fabric is still balanced, mice fill the gaps) and
    each flow adds its demand weight, not 1, to the links it takes.

    The walk is a Python loop over flows but vectorized over seeds *and*
    batched over hops: the per-hop tie-break hash is only evaluated when
    some seed actually has a load tie (ties die out as loads
    differentiate), and the load tally is deferred to one fused scatter
    over all (hop, seed) cells of the finished flow — exact, because a
    loop-free walk never revisits a device, so a flow's later candidate
    sets cannot contain its own earlier links.  A 256-flow x 1024-seed
    sweep stays well under a second.
    """

    name = "congestion-aware"

    def route(self, comp, flows, seeds_u64, *, fields=FIELDS_5TUPLE,
              hash_backend=EXACT, max_hops=16, field_matrix=None,
              demand_mode=DEMAND_UNIFORM, engine=ENGINE_NUMPY):
        # ``engine`` is accepted (front-end contract) but the placement
        # loop itself stays host-side: greedy sequential routing is a
        # data-dependent chain over flows (each placement reads the loads
        # the previous ones wrote) — ``WaveCongestionAware`` below is the
        # device-friendly reformulation.  Downstream fill/exposure
        # still honor the engine via throughput_from_result(engine=).
        field_mat = (field_matrix if field_matrix is not None
                     else flow_fields_matrix(flows, fields))
        n, s = len(flows), len(seeds_u64)
        endpoints = comp.flow_endpoint_ids(flows)
        flow_demand = flow_demand_weights(flows, demand_mode)
        # stable largest-first placement: uniform demand keeps the
        # original order exactly (all keys equal), so demand_mode="bytes"
        # with homogeneous volumes stays bit-identical to "uniform"
        order = np.argsort(-flow_demand, kind="stable")
        load = np.zeros((s, comp.num_links))
        link_ids = np.full((max_hops, n, s), -1, np.int32)
        hops = _sequential_congestion_place(
            comp, flows, field_mat, seeds_u64, endpoints, flow_demand,
            order, load, link_ids, hash_backend=hash_backend,
            max_hops=max_hops)
        return VectorTraceResult(
            compiled=comp, flows=list(flows), seeds=seeds_u64,
            link_ids=link_ids[:hops], strategy=self.name,
            flow_demand=flow_demand)


def _wave_choice(cands: np.ndarray, valid: np.ndarray, cl: np.ndarray,
                 h: np.ndarray, cw: int, cool: bool = False,
                 near: bool = False) -> np.ndarray:
    """Hash tie-break over the eligible candidate set, batched over
    arbitrary leading axes: the documented wave decision rule.

    With ``cool=False`` the eligible set is the least-loaded candidates;
    exact (quantized) load ties are broken by ``hash % n_tie`` counted
    over the tied candidates in table order — the *same* arithmetic as
    the sequential loop (whose cumsum form degenerates to ``tie.argmax``
    when the minimum is unique), so wave and sequential replay identical
    decisions given identical loads.  On a fresh fabric every candidate
    ties at zero and the rule *is* plain ECMP
    (``rank == hash % n_candidates``).

    With ``cool=True`` the eligible set widens to every candidate no
    hotter than the (quantized) candidate *mean*: repair waves use it to
    hash-spread their *arrivals* across the whole cool half of the
    table.  A wave of movers all steering for the strict argmin piles
    onto it and mints a fresh hotspot (the sink side of the herd
    problem — departures are already rate-limited by the
    excess-proportional repair probability, but thousands of simultaneous
    movers share a handful of argmin links); landing uniformly on the
    cool set bounds arrivals per link by ``movers / |cool|``, and the
    accept-if-better filter discards the landings that didn't help.
    ``cl`` is quantized to integers, so the minimum is always <= the
    floored mean and the cool set is never empty.

    With ``near=True`` (only meaningful together with ``cool``) the
    eligible set narrows to candidates within one quantum of the
    minimum: the polish-phase arrival rule.  Once mover volume is small
    the herd risk is gone and uniform-over-cool arrivals stop helping —
    they never preferentially fill the *under*-loaded tail, which is
    where the remaining imbalance lives — so late repair steers
    near-min (still hash-spread across the whole near-min window, not
    the strict argmin)."""
    if cool and near:
        m = np.where(valid, cl, np.inf).min(axis=-1)
        tie = valid & (cl <= m[..., None] + 1.0)
    elif cool:
        n_valid = np.maximum(valid.sum(axis=-1), 1)
        mean = np.where(valid, cl, 0.0).sum(axis=-1) / n_valid
        tie = valid & (cl <= np.floor(mean)[..., None])
    else:
        tie = valid & (cl == cl.min(axis=-1)[..., None])
    n_tie = tie.sum(axis=-1)
    rank = np.where(
        n_tie > 1,
        (h % np.maximum(n_tie, 1).astype(np.uint64)).astype(np.int64),
        0)
    col = (tie.cumsum(axis=-1) <= rank[..., None]).sum(axis=-1)
    return np.take_along_axis(
        cands, np.minimum(col, cw - 1)[..., None], axis=-1)[..., 0]


def _wave_walk_numpy(comp, src_dev, dst_dev, src_key, dst_key, field_mat,
                     seeds_u64, loads, *, hash_backend, max_hops, quantum,
                     cool=False, near=False):
    """One speculative wave: every (flow, seed) cell walks the fabric
    against the *frozen* ``(S, L)`` load snapshot — fully vectorized over
    flows, seeds, and candidates (no per-flow Python loop).  Decisions
    compare loads quantized to ``quantum`` (see ``WaveCongestionAware``),
    so near-equal links tie and the hash spreads the wave across them
    instead of herding every cell onto one strict argmin.  Returns the
    ``(hops, N, S)`` link tensor plus the final state / done grids for
    the caller's arrival checks."""
    na, S = len(src_dev), len(seeds_u64)
    state = np.broadcast_to(src_dev[:, None], (na, S)).copy()
    done = np.zeros((na, S), bool)
    out = np.full((max_hops, na, S), -1, np.int32)
    flat = np.floor(loads.reshape(-1) / quantum)
    row_off = np.arange(S, dtype=np.int64) * comp.num_links
    cand_w = comp.cand.shape[-1]
    col_idx = np.arange(cand_w, dtype=np.int64)
    hops = 0
    for t in range(max_hops):
        if done.all():
            break
        hops = t + 1
        key = np.where(comp.is_server[state], src_key[:, None],
                       dst_key[:, None])
        nc = comp.cand_n[state, key]                   # (N, S)
        cw = min(int(nc.max()), cand_w) or 1           # live table width
        cands = comp.cand[state, key, :cw]             # (N, S, cw)
        valid = (col_idx[:cw] < nc[..., None]) & (cands >= 0)
        cl = np.where(valid,
                      flat[row_off[None, :, None] + np.maximum(cands, 0)],
                      np.inf)
        dev_seed = comp.dev_crc[state] ^ seeds_u64[None, :]
        h = hash_grid(field_mat, dev_seed, hash_backend)
        link = _wave_choice(cands, valid, cl, h, cw, cool, near)
        link = np.where(done | (nc == 0), -1, link)
        out[t] = link
        nxt = np.where(link >= 0, comp.link_dst[np.maximum(link, 0)], state)
        done |= (link < 0) | comp.is_server[nxt]
        state = nxt
    return out[:hops], state, done


def _wave_conflicts(comp, ids, src_dev, src_key, dst_key,
                    spec_loads, w_flow, *, quantum, tol=1.0):
    """``(conflict, rate)`` over the (N, S) cells of a routed assignment.

    ``conflict`` flags cells whose chosen link at some hop carries at
    least ``tol`` quanta *more than the mean of its candidate set*
    under ``spec_loads`` — ECN-style overload marking, the same
    mean-relative rule the adaptive re-spray uses.  It pairs with the
    cool-half arrival rule: movers land hash-uniformly on the
    at-most-mean half of the candidate table, so a cell is marked
    exactly when it sits above the level repair can take it to, and
    the mark needs no self-exclusion (a link ``tol`` quanta hotter
    than its neighbours is overloaded no matter which flows make up
    the load).

    Marking distance-to-*minimum* instead was measured and rejected:
    zero min-relative conflicts is discrepancy-``tol`` balance at every
    decision layer simultaneously — a fixpoint parallel repair cannot
    reach (and with integer layer means, literal perfection), so the
    marks never drain, every round re-walks thousands of movers, and
    the strategy runs slower than the sequential loop it replaces.

    ``rate`` is the excess-proportional repair probability: a marked
    cell on a link of quantized load ``L`` with context mean ``mu``
    gets ``(L - mu) / (2 L)`` —
    sampling movers at that rate takes an *expected* ``(L - m) / 2``
    flows off the link, half the excess, so repair is aggressive on a
    fresh ECMP stampede and self-anneals to single-flow nudges near the
    fixpoint instead of herding.

    The scan is context-factored: the candidate mean only depends on
    the (device, key, seed) forwarding context — a few thousand rows of
    the compiled tables — never on which cell is asking, so the means
    are tabulated once per round as a ``(V, K, S)`` grid and each hop
    of each cell costs two gathers (own load + context mean) instead
    of a per-cell sweep of the whole candidate row.  At bench scale
    this is the difference between the rescan dominating the round and
    the rescan being noise."""
    n_hops, na, S = ids.shape
    flatq = np.floor(spec_loads.reshape(-1) / quantum)
    row_off = np.arange(S, dtype=np.int64) * comp.num_links
    V, K, C = comp.cand.shape
    valid_vk = (np.arange(C, dtype=np.int64) < comp.cand_n[..., None]) \
        & (comp.cand >= 0)
    clq = flatq[np.maximum(comp.cand, 0)[..., None] + row_off]  # (V,K,C,S)
    n_valid = np.maximum(valid_vk.sum(axis=-1), 1)              # (V,K)
    mu = (np.where(valid_vk[..., None], clq, 0.0).sum(axis=2)
          / n_valid[..., None])                                 # (V,K,S)
    state = np.broadcast_to(src_dev[:, None], (na, S)).copy()
    conflict = np.zeros((na, S), bool)
    rate = np.zeros((na, S))
    cols = np.arange(S, dtype=np.int64)
    for t in range(n_hops):
        chosen = ids[t]                                # (N, S)
        walked = chosen >= 0
        if not walked.any():
            break
        key = np.where(comp.is_server[state], src_key[:, None],
                       dst_key[:, None])
        own = flatq[np.maximum(chosen, 0).astype(np.int64)
                    + row_off[None, :]]
        mu_c = mu[state, key, cols[None, :]]
        hop_conf = walked & (own >= mu_c + tol)
        conflict |= hop_conf
        hop_rate = np.where(
            hop_conf, (own - mu_c) / np.maximum(2.0 * own, 1.0), 0.0)
        rate = np.maximum(rate, hop_rate)
        state = np.where(walked, comp.link_dst[np.maximum(chosen, 0)], state)
    return conflict, rate


def _scatter_cell_loads(sel: np.ndarray, w_flow: np.ndarray,
                        row_off: np.ndarray, num_links: int) -> np.ndarray:
    """(S, L) demand scatter of an ``(H, N, S)`` link tensor (−1 skipped);
    bincount, because distinct flows legitimately share (seed, link)
    cells — the fused fancy-index add of the sequential loop is only
    exact within one flow."""
    S = sel.shape[2]
    keep = sel >= 0
    cells = (sel.astype(np.int64) + row_off[None, None, :])[keep]
    w = np.broadcast_to(w_flow[None, :, None], sel.shape)[keep]
    return np.bincount(cells, weights=w,
                       minlength=S * num_links).reshape(S, num_links)


def _mover_accept(new_ids: np.ndarray, old_ids: np.ndarray,
                  loads: np.ndarray, w_flow: np.ndarray,
                  quantum: float) -> np.ndarray:
    """(Na, S) bool: does the re-walked path strictly improve the mover's
    hottest *differing* hop, judged self-free under the frozen snapshot?

    Only the hops where old and new path disagree enter the comparison:
    a path's overall maximum usually sits on a forced or evenly-loaded
    layer (e.g. the per-NIC server links, identical for every choice at
    the layers below), and comparing whole-path maxima would let that
    shared bottleneck veto every repair beneath it.  ``loads`` still
    carries each mover's old path (nothing is retracted until the round
    commits), so loads are read with the cell's own demand removed:
    every old-path link carries it by construction, and a new-path link
    carries it exactly when it is also an old-path link.  The comparison
    is quantized like every other wave decision: an equal-quantum swap
    is NOT an improvement, so symmetric movers can never trade places
    forever (anti-flip-flop), and a re-walk that reproduces the old path
    exactly is simply not a move."""
    H = max(new_ids.shape[0], old_ids.shape[0])

    def pad(ids):
        if ids.shape[0] == H:
            return ids
        out = np.full((H,) + ids.shape[1:], -1, np.int32)
        out[:ids.shape[0]] = ids
        return out

    new_ids, old_ids = pad(new_ids), pad(old_ids)
    S, L = loads.shape
    flat = loads.reshape(-1)
    off = np.arange(S, dtype=np.int64) * L

    def path_loads(ids):
        cells = np.where(ids >= 0, ids.astype(np.int64) + off, 0)
        return np.where(ids >= 0, flat[cells], 0.0)

    w = w_flow[None, :, None]
    diff = new_ids != old_ids
    old_l = path_loads(old_ids) - np.where(old_ids >= 0, w, 0.0)
    member = ((new_ids[:, None] == old_ids[None]) & (new_ids[:, None] >= 0)
              ).any(axis=1)
    new_l = path_loads(new_ids) - np.where(member, w, 0.0)
    old_max = np.where(diff & (old_ids >= 0), old_l, -np.inf).max(axis=0)
    new_max = np.where(diff & (new_ids >= 0), new_l, -np.inf).max(axis=0)
    return (diff & (new_ids >= 0)).any(axis=0) & (
        np.floor((new_max + w_flow[:, None]) / quantum)
        < np.floor((old_max + w_flow[:, None]) / quantum))


class WaveCongestionAware(CongestionAware):
    """Wave-parallel congestion-aware placement: speculative accept/repair
    (the predictive routing policy of arXiv 2506.08132, vectorized).

    ``CongestionAware`` is a data-dependent chain — flow *k*'s placement
    reads the loads flows *1..k-1* wrote — so it runs as a Python loop
    over flows and caps the strategy matrix at toy scale.  This variant
    replaces the chain with speculate-then-repair:

    1. **wave**: every (flow, seed) cell walks the empty fabric in one
       vectorized shot.  With all loads zero every candidate ties and
       the load-tie-break rule *is* plain ECMP, so round 0 simply runs
       the engine-dispatched ``ecmp_walk`` — the speculative start of
       the accept/repair scheme — and the whole wave commits as a
       complete assignment at once;
    2. **detect** (``_wave_conflicts``): a cell is **conflicted** when
       its chosen link at some hop carries at least ``tol`` quanta more
       than the mean of that hop's candidate set (ECN-style overload
       marking, context-factored into a ``(V, K, S)`` mean table).
       Stampede rounds scan at ``tolerance``; once marks fall below a
       quarter of the cells the loop latches into *polish* rounds that
       scan at the minimum meaningful tolerance of one quantum;
    3. **repair**: a damped subset of the conflicted cells re-walks
       against the frozen load snapshot (``_wave_walk_numpy`` /
       ``jax_engine.jax_wave_walk``) — each conflicted cell moves with
       the excess-proportional probability from the scan (times
       ``move_prob``) under a deterministic splitmix64 coin (cell- and
       round-keyed), except the earliest conflicted flow in placement
       order per seed, which is always eligible (so a round can never
       select nobody).  Undamped repair stampedes every conflicted cell
       onto the same cool links and conflicts them right back — the
       same herd the adaptive re-spray damps.  Mover *arrivals* land
       hash-uniformly across the cool (at-most-mean) half of each
       candidate table during stampede rounds and across the near-min
       window during polish rounds (``_wave_choice``);
    4. **accept**: a move is kept only when it strictly improves the
       cell's hottest *differing* hop by at least one quantum, judged
       self-free under the frozen snapshot (``_mover_accept``) —
       "equally good elsewhere" is NOT a move, so symmetric conflicts
       cannot flip-flop;
    5. **commit**: per round, all accepted movers retract their old
       loads and charge their new ones in ONE atomic scatter pair —
       never flow by flow, which would re-introduce the sequential chain
       and make the conflict test order-dependent within a round;
    6. repeat until no cell is conflicted (fixpoint) or ``max_rounds``;
       residue still conflicted at the cap (scanned at ``tolerance``)
       is retracted and placed by the sequential greedy loop against
       the committed loads (masked so committed cells are neither
       rewritten nor double-charged).

    Quantized parallel repair works where flows are *interchangeable
    quanta*: it needs the mean per-link load to be several quanta deep
    before its fixpoint is as tight as the sequential chain
    (``min_wave_load``, measured crossover ~7 quanta), and it needs
    the per-flow weights to be equal — heterogeneous demand hands the
    sequential chain a heaviest-first ordering advantage the repair
    dynamics consistently fail to reproduce (measured across byte
    mixes, flow counts, equal-mass band decompositions, and round
    budgets).  Outside that regime — small problems, or
    ``demand_mode="bytes"`` with genuinely unequal volumes — ``route``
    delegates to the sequential loop wholesale and stays bit-identical
    to ``CongestionAware``.  Inside it, the vectorized path is both
    faster (5x+ at 10x the bench flow count) and, measured at bench
    scale, tighter-balanced than sequential greedy.

    **Tie-break policy (the documented contract):** decisions take the
    least-loaded candidate with the sequential loop's exact
    ``hash % n_tie`` tie-break over the tied set in table order
    (``_wave_choice``), where loads compare *quantized* to ``quantum`` —
    one mean flow demand (``flow_demand_weights`` normalizes both demand
    modes to mean 1).  Uniform demand therefore compares exact integer
    loads unchanged, while continuous byte-weighted loads keep a tie
    structure the hash can spread waves across (strict float argmin
    would herd every repair onto the single coolest link and immediately
    re-conflict it).

    The result is a *fixpoint of the repair dynamics*, not a replay of
    the sequential order: at convergence no chosen link sits a quantum
    above its candidate-set mean — flows are interchangeable under the
    greedy rule, so the wave reaches a different (measured: tighter)
    member of the same local-optimum family.  The differential test
    (tests/test_wave.py) pins the divergence contract: placements
    bit-identical to ``CongestionAware`` everywhere the cutover
    delegates (small problems, heterogeneous weights), and
    demand-weighted FIM <= sequential greedy on the wave path itself.
    """

    name = "wave-congestion-aware"

    def __init__(self, max_rounds: int = 16, quantum: float = 1.0,
                 move_prob: float = 1.0, tolerance: float = 2.0,
                 min_wave_load: float = 7.0):
        if max_rounds < 1:
            raise ValueError(f"max_rounds must be >= 1, got {max_rounds}")
        if not quantum > 0:
            raise ValueError(f"quantum must be > 0, got {quantum}")
        if not 0.0 < move_prob <= 1.0:
            raise ValueError(
                f"move_prob must be in (0, 1], got {move_prob}")
        if tolerance < 1:
            raise ValueError(f"tolerance must be >= 1, got {tolerance}")
        if min_wave_load < 0:
            raise ValueError(
                f"min_wave_load must be >= 0, got {min_wave_load}")
        self.max_rounds = int(max_rounds)
        self.quantum = float(quantum)
        self.move_prob = float(move_prob)
        self.tolerance = float(tolerance)
        self.min_wave_load = float(min_wave_load)

    def _wave_walk(self, comp, sub, field_mat, seeds_u64, loads, *,
                   hash_backend, max_hops, engine, cool=False, near=False):
        if engine != ENGINE_NUMPY:
            from .jax_engine import jax_wave_walk, resolve_engine
            resolve_engine(engine)
            return jax_wave_walk(
                comp, *sub, field_mat, seeds_u64, loads,
                hash_backend=hash_backend, max_hops=max_hops,
                quantum=self.quantum, cool=cool, near=near)
        return _wave_walk_numpy(
            comp, *sub, field_mat, seeds_u64, loads,
            hash_backend=hash_backend, max_hops=max_hops,
            quantum=self.quantum, cool=cool, near=near)

    @staticmethod
    def _check_wave(comp, flows, act, state, done, dst_dev, max_hops):
        if not np.asarray(done).all():
            raise RuntimeError(
                f"some flows did not terminate in {max_hops} hops")
        state = np.asarray(state)
        arrived = state == dst_dev[:, None]
        if not arrived.all():
            i, k = np.argwhere(~arrived)[0]
            raise RuntimeError(
                f"flow {flows[int(act[i])].flow_id} (seed index {int(k)}) "
                f"terminated at {comp.device_names[int(state[i, k])]}, "
                f"expected {flows[int(act[i])].dst}")

    def route(self, comp, flows, seeds_u64, *, fields=FIELDS_5TUPLE,
              hash_backend=EXACT, max_hops=16, field_matrix=None,
              demand_mode=DEMAND_UNIFORM, engine=ENGINE_NUMPY):
        n, s = len(flows), len(seeds_u64)
        flow_demand = flow_demand_weights(flows, demand_mode)
        # Cutover: quantized parallel repair can only discriminate loads
        # down to one quantum, so it needs the mean per-link load to be
        # several quanta deep before its fixpoint is as tight as the
        # sequential chain's placement (measured crossover ~7 quanta on
        # the paper fabric); below that the sequential loop is the
        # better tool on both axes and the wave simply delegates to it.
        # Heterogeneous per-flow weights delegate too: repair treats
        # flows as interchangeable quanta, which can never reproduce the
        # sequential chain's heaviest-first ordering advantage (measured
        # consistently behind it across byte mixes, flow counts, band
        # decompositions, and round budgets).
        if (n * 1.0 / comp.num_links < self.min_wave_load
                or (n > 0 and not (flow_demand == flow_demand[0]).all())):
            return super().route(
                comp, flows, seeds_u64, fields=fields,
                hash_backend=hash_backend, max_hops=max_hops,
                field_matrix=field_matrix, demand_mode=demand_mode,
                engine=engine)
        field_mat = (field_matrix if field_matrix is not None
                     else flow_fields_matrix(flows, fields))
        endpoints = comp.flow_endpoint_ids(flows)
        order = np.argsort(-flow_demand, kind="stable")  # same as sequential
        o_rank = np.empty(n, np.int64)
        o_rank[order] = np.arange(n, dtype=np.int64)
        row_off = np.arange(s, dtype=np.int64) * comp.num_links
        cols = np.arange(s, dtype=np.int64)
        # round 0: the whole wave walks the empty fabric — every
        # candidate ties at zero, so the wave decision rule degenerates
        # to plain ECMP and the round IS the (engine-dispatched)
        # optimized ECMP walk, committed as a complete assignment in
        # one atomic scatter
        ids0 = ecmp_walk(
            comp, *endpoints, field_mat, seeds_u64,
            hash_backend=hash_backend, max_hops=max_hops, engine=engine)
        hops = ids0.shape[0]
        link_ids = np.full((max_hops, n, s), -1, np.int32)
        link_ids[:hops] = ids0
        load = _scatter_cell_loads(ids0, flow_demand, row_off,
                                   comp.num_links)
        coin_id = (_splitmix64(np.arange(n, dtype=np.uint64))[:, None]
                   ^ seeds_u64[None, :])
        conflict = np.zeros((n, s), bool)
        # Two-phase repair: stampede rounds mark at ``tolerance`` and
        # spread arrivals over the whole cool half of each candidate
        # table (herd-proof while movers are plentiful); once marks drop
        # below a quarter of the cells the round latches into *polish* —
        # marking at the minimum meaningful tolerance of one quantum and
        # steering arrivals near-min, which is what fills the
        # under-loaded tail the cool-uniform rule never targets.
        polish = False
        for rnd in range(self.max_rounds):
            conflict, rate = _wave_conflicts(
                comp, link_ids[:hops], endpoints[0], endpoints[2],
                endpoints[3], load, flow_demand, quantum=self.quantum,
                tol=1.0 if polish else self.tolerance)
            if not conflict.any():
                break
            polish = polish or conflict.sum() < 0.25 * conflict.size
            # damped repair: each conflicted cell moves with the
            # excess-proportional probability from the scan (scaled by
            # move_prob) under a deterministic cell+round-keyed coin ...
            coin = _splitmix64(
                coin_id ^ np.uint64((rnd + 1) * 0x9E3779B97F4A7C15
                                    & 0xFFFFFFFFFFFFFFFF))
            coin_u = (coin >> np.uint64(11)) * 2.0 ** -53
            movers = conflict & (coin_u < self.move_prob * rate)
            # ... except the earliest conflicted flow in placement order
            # per seed, which is always eligible — a repair round can
            # never select nobody
            rk = np.where(conflict, o_rank[:, None], np.iinfo(np.int64).max)
            first = rk.argmin(axis=0)                    # (S,)
            movers[first, cols] |= conflict[first, cols]
            act = np.flatnonzero(movers.any(axis=1))
            sub = tuple(a[act] for a in endpoints)
            w_a = flow_demand[act]
            ids_a, state, done = self._wave_walk(
                comp, sub, field_mat[act], seeds_u64, load,
                hash_backend=hash_backend, max_hops=max_hops, engine=engine,
                cool=True, near=polish)
            self._check_wave(comp, flows, act, state, done, sub[1], max_hops)
            old = link_ids[:hops][:, act, :]
            accept = movers[act] & _mover_accept(
                ids_a, old, load, w_a, self.quantum)
            if not accept.any():
                continue
            t_end = max(hops, ids_a.shape[0])
            pad_new = np.full((t_end,) + ids_a.shape[1:], -1, np.int32)
            pad_new[:ids_a.shape[0]] = ids_a
            pad_old = np.full_like(pad_new, -1)
            pad_old[:hops] = old
            sel_new = np.where(accept[None], pad_new, -1)
            sel_old = np.where(accept[None], pad_old, -1)
            # atomic per-round commit: every accepted mover's old demand
            # is retracted and its new demand charged in ONE scatter
            # pair — never flow by flow
            load += (_scatter_cell_loads(sel_new, w_a, row_off,
                                         comp.num_links)
                     - _scatter_cell_loads(sel_old, w_a, row_off,
                                           comp.num_links))
            merged = link_ids[:t_end][:, act, :]
            np.copyto(merged, pad_new, where=accept[None])
            link_ids[:t_end][:, act, :] = merged
            hops = t_end
        else:
            # round cap without fixpoint: retract whatever is still
            # conflicted and place it with the sequential greedy loop
            # against the committed loads (documented fallback)
            residue, _ = _wave_conflicts(
                comp, link_ids[:hops], endpoints[0], endpoints[2],
                endpoints[3], load, flow_demand, quantum=self.quantum,
                tol=self.tolerance)
            if residue.any():
                sel = np.where(residue[None], link_ids[:hops], -1)
                load -= _scatter_cell_loads(sel, flow_demand, row_off,
                                            comp.num_links)
                np.copyto(link_ids[:hops], np.int32(-1),
                          where=residue[None])
                hops = max(hops, _sequential_congestion_place(
                    comp, flows, field_mat, seeds_u64, endpoints,
                    flow_demand, order, load, link_ids,
                    hash_backend=hash_backend, max_hops=max_hops,
                    mask=residue))
        return VectorTraceResult(
            compiled=comp, flows=list(flows), seeds=seeds_u64,
            link_ids=link_ids[:hops], strategy=self.name,
            flow_demand=flow_demand)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[[], RoutingStrategy]] = {}


def register_strategy(name: str,
                      factory: Callable[[], RoutingStrategy],
                      *, replace: bool = False) -> None:
    """Register a strategy factory under ``name`` so benchmarks and the
    ``strategy="..."`` string form can construct it on demand.

    A duplicate name raises unless ``replace=True``: every benchmark
    matrix and Monte-Carlo front end resolves strategies by name, so a
    silent overwrite of e.g. ``"ecmp"`` would swap the baseline out from
    under all of them without a trace."""
    if not replace and name in _REGISTRY:
        raise ValueError(
            f"routing strategy {name!r} is already registered "
            f"(registered: {available_strategies()}); pass replace=True "
            f"to overwrite it")
    _REGISTRY[name] = factory


def available_strategies() -> list[str]:
    return sorted(_REGISTRY)


def resolve_strategy(strategy: RoutingStrategy | str) -> RoutingStrategy:
    """A ``RoutingStrategy`` instance passes through; a string constructs
    the registered default configuration of that family."""
    if isinstance(strategy, RoutingStrategy):
        return strategy
    if isinstance(strategy, str):
        try:
            return _REGISTRY[strategy]()
        except KeyError:
            raise ValueError(
                f"unknown routing strategy {strategy!r}; "
                f"registered: {available_strategies()}") from None
    raise TypeError(
        f"strategy must be a RoutingStrategy or registered name, "
        f"got {type(strategy).__name__}")


register_strategy("ecmp", EcmpStrategy)
register_strategy("prime-spray", PrimeSpraying)
register_strategy("prime-spray-elephant",
                  lambda: PrimeSpraying(min_bytes=ELEPHANT_MIN_BYTES,
                                        volume_k=True))
register_strategy("congestion-aware", CongestionAware)
register_strategy("wave-congestion-aware", WaveCongestionAware)
register_strategy("adaptive-spray", AdaptiveSpraying)
register_strategy("adaptive-spray-elephant",
                  lambda: AdaptiveSpraying(min_bytes=ELEPHANT_MIN_BYTES,
                                           volume_k=True))
