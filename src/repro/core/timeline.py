"""Time-expanded simulation: phased collectives over one compiled fabric.

The Monte-Carlo front ends (``monte_carlo_fim`` /
``monte_carlo_throughput``) flatten a workload into ONE steady-state
flow set — fine for the paper's saturating bipartite sweep, wrong for
the phased LLM mixes of ``core/llm_workload.py``: a training step runs
its collectives in *phases* (forward all-gather, MoE all-to-all,
backward reduce-scatter, gradient all-reduce, barrier), so merging them
into a single snapshot both **overstates contention** between
collectives that never share the wire and **hides phase-local
hotspots** that the other phases' flows average away.  Same class of
silent modeling bug the byte-blind FIM (PR 4) and free spraying (PR 5)
were: the simulation answers a question the workload never asks.

This module adds the time axis:

* a schedule is a list of ``TimelineStep``s, each naming the collective
  *channels* (``CollectiveOp.channel_id``) active during that step and a
  relative duration ``weight``;
* ``simulate_timeline`` partitions one flow list by channel, routes each
  step's active flow set independently over ONE shared
  ``compile_fabric`` pass, and scores each step with the *same* engines
  the merged path uses — ``simulate_paths`` + ``fim_from_counts`` +
  ``throughput_from_result`` — so a one-step schedule containing every
  channel reproduces the merged snapshot **bit-identically** (the
  differential anchor in tests/test_timeline.py);
* ``TimelineResult`` carries the per-step series and the time-weighted
  totals.

**Step weights are durations, not byte shares.**  With byte-proportional
weights the time-weighted FIM can *never* exceed the merged FIM (the
merged load vector is the byte-weighted mean of the step load vectors,
and MAPE is convex — triangle inequality), which would hide exactly the
bug this module exposes.  Equal default weights model a synchronous
schedule — every phase holds the fabric for one barrier-to-barrier
interval regardless of how many bytes it moves — and make the
phased-vs-merged gap visible in both directions: a schedule whose steps
are dominated by one hot collective reads *lower* contention merged
(the cold phases dilute it) and *higher* phase-local FIM expanded.

Schedule emitters for the committed LLM scenarios live in
``core/llm_workload.py`` (``llm_collective_phases`` et al.) with two
modes: ``"sequential"`` (every phase alone, the synchronous-training
default) and ``"dp-overlap"`` (gradient all-reduce overlapped into the
backward phase, the standard DP-overlap optimization).
"""

from __future__ import annotations

import dataclasses
import re
from collections.abc import Sequence

import numpy as np

from .compile_fabric import CompiledFabric, compile_fabric
from .fabric import Fabric
from .flows import Flow, WorkloadDescription
from .vector_sim import (
    MonteCarloFim, SimSpec, _UNSET, fim_from_counts,
    resolve_flows, resolve_spec, simulate_paths,
)
from .vector_throughput import MonteCarloThroughput, throughput_from_result

_CHANNEL_RE = re.compile(r"#ch(\d+)$")


@dataclasses.dataclass(frozen=True, slots=True)
class TimelineStep:
    """One schedule step: the channels on the wire and how long they hold it.

    ``channels`` are ``CollectiveOp.channel_id`` values (the flow labels
    carry them as the ``#ch<N>`` suffix ``collectives_to_flows`` emits);
    a channel may appear in several steps (an overlapped collective
    spans phases).  ``weight`` is the step's relative *duration* — see
    the module docstring for why it is not a byte share.
    """

    name: str
    channels: tuple[int, ...]
    weight: float = 1.0

    def __post_init__(self):
        if not self.channels:
            raise ValueError(f"step {self.name!r} has no channels")
        if not self.weight > 0:
            raise ValueError(
                f"step {self.name!r} weight must be > 0, got {self.weight}")


def merged_step(schedule: Sequence[TimelineStep],
                name: str = "merged") -> TimelineStep:
    """The degenerate one-step schedule: every channel of ``schedule``
    active at once — the merged-snapshot view the time axis replaces,
    kept as the differential anchor."""
    seen: dict[int, None] = {}
    for step in schedule:
        for ch in step.channels:
            seen.setdefault(ch, None)
    return TimelineStep(name=name, channels=tuple(seen))


def flow_channel(flow: Flow) -> int | None:
    """The collective channel id a flow belongs to, parsed from the
    ``#ch<N>`` label suffix ``collectives_to_flows`` writes.  ``None``
    for unlabeled flows (synthetic bipartite workloads)."""
    m = _CHANNEL_RE.search(flow.label)
    return int(m.group(1)) if m else None


def partition_flows(
    flows: Sequence[Flow], schedule: Sequence[TimelineStep]
) -> list[list[Flow]]:
    """Each step's active flow sublist, in original flow order (order
    preservation is what makes the one-step schedule bit-identical to
    the merged run).  Flows whose channel appears in no step raise —
    silently dropping traffic is exactly the class of bug this module
    exists to remove."""
    chans = [flow_channel(f) for f in flows]
    covered = {ch for step in schedule for ch in step.channels}
    stray = sorted({c for c in chans if c is not None and c not in covered})
    if stray:
        raise ValueError(
            f"flows on channels {stray} appear in no schedule step "
            f"(steps cover {sorted(covered)}); every collective must be "
            f"scheduled somewhere")
    unlabeled = sum(c is None for c in chans)
    if unlabeled:
        raise ValueError(
            f"{unlabeled} flows carry no '#ch<N>' label — "
            f"time-expanded simulation needs collective-derived flows "
            f"(see core/llm_workload.py)")
    return [[f for f, c in zip(flows, chans) if c in step.channels]
            for step in schedule]


@dataclasses.dataclass
class StepResult:
    """One step's full scoring: the routed flow set, FIM distribution,
    and throughput/goodput distribution — exactly what the merged
    pipeline would report had this step been the whole workload."""

    step: TimelineStep
    flows: list[Flow]
    fim: MonteCarloFim
    throughput: MonteCarloThroughput

    @property
    def mean_goodput(self) -> np.ndarray:
        """(S,) mean per-flow goodput under each seed."""
        return self.throughput.goodput.mean(axis=0)

    @property
    def mean_rate(self) -> np.ndarray:
        """(S,) mean per-flow max-min rate under each seed."""
        return self.throughput.rates.mean(axis=0)


@dataclasses.dataclass
class TimelineResult:
    """Per-step series + time-weighted totals of a scheduled simulation.

    The totals weight each step by its normalized duration
    (``weights``): ``fim`` is the duration-weighted mean of the per-step
    aggregate FIM — "the imbalance a uniformly-sampling observer sees" —
    and ``goodput`` / ``rates`` the duration-weighted mean of per-step
    mean flow goodput/rate.  For a one-step schedule every series is the
    step's own, bit-identically.
    """

    seeds: np.ndarray                   # (S,)
    steps: list[StepResult]
    weights: np.ndarray                 # (K,) normalized step durations
    fim: np.ndarray                     # (S,) time-weighted aggregate FIM
    goodput: np.ndarray                 # (S,) time-weighted mean goodput
    rates: np.ndarray                   # (S,) time-weighted mean rate

    @property
    def num_steps(self) -> int:
        return len(self.steps)

    def step_fim(self) -> np.ndarray:
        """(K, S) per-step aggregate FIM series."""
        return np.stack([s.fim.aggregate for s in self.steps])

    def summary(self) -> dict[str, dict[str, float]]:
        rows: dict[str, np.ndarray] = {
            "fim": self.fim,
            "goodput": self.goodput,
            "rate": self.rates,
        }
        for sr in self.steps:
            rows[f"fim[{sr.step.name}]"] = sr.fim.aggregate
            rows[f"goodput[{sr.step.name}]"] = sr.mean_goodput
        out = {}
        for name, v in rows.items():
            v = np.asarray(v, np.float64).ravel()
            out[name] = {
                "mean": float(v.mean()),
                "std": float(v.std()),
                "min": float(v.min()),
                "p50": float(np.percentile(v, 50)),
                "max": float(v.max()),
            }
        return out


def simulate_timeline(
    fabric: Fabric | CompiledFabric,
    workload: WorkloadDescription | Sequence[Flow],
    schedule: Sequence[TimelineStep],
    seeds: Sequence[int] | np.ndarray,
    *,
    spec: SimSpec | None = None,
    fields=_UNSET,
    hash_backend=_UNSET,
    strategy=_UNSET,
    demand_mode=_UNSET,
    transport=_UNSET,
    layers: Sequence[str] | None = None,
    only_used_leaves: bool = False,
    engine=_UNSET,
) -> TimelineResult:
    """Simulate a phase schedule step by step over one compiled fabric.

    Every step routes ONLY its active flows (the others are off the wire
    — that is the fix), through the identical ``simulate_paths`` →
    ``fim_from_counts`` → ``throughput_from_result`` pipeline the merged
    front ends run, under the same ``SimSpec`` contract — pass one as
    ``spec=`` or the legacy ``strategy`` / ``demand_mode`` /
    ``transport`` / ``engine`` kwargs, not both (``strategy`` accepts a
    registry name string or instance, resolved once up front and shared
    by every step; ``engine="jax"`` routes every step through the
    device engine).  The compiled fabric is shared across steps;
    a ``CompiledFabric`` passes through unchanged, so sweeps over
    schedules or strategies pay compilation once.

    Steps whose flow set is empty (e.g. a MoE step on a spec with
    ``moe_layers=0``) are dropped, with their duration excluded from the
    weighting; a schedule whose every step is empty raises.
    """
    s = resolve_spec(spec, dict(
        fields=fields, hash_backend=hash_backend, strategy=strategy,
        demand_mode=demand_mode, transport=transport, engine=engine))
    comp = (fabric if isinstance(fabric, CompiledFabric)
            else compile_fabric(fabric))
    flows = resolve_flows(comp, workload)
    if not schedule:
        raise ValueError("schedule must contain at least one step")
    parts = partition_flows(flows, schedule)
    steps: list[StepResult] = []
    durations: list[float] = []
    for step, sub in zip(schedule, parts):
        if not sub:
            continue
        res = simulate_paths(comp, sub, seeds, spec=s)
        agg, per_layer = fim_from_counts(
            res.link_flow_counts(), comp,
            layers=layers, only_used_leaves=only_used_leaves)
        tp = throughput_from_result(res, transport=s.transport,
                                    engine=s.engine)
        steps.append(StepResult(
            step=step, flows=sub,
            fim=MonteCarloFim(seeds=res.seeds, aggregate=agg,
                              per_layer=per_layer),
            throughput=tp))
        durations.append(step.weight)
    if not steps:
        raise ValueError("every schedule step resolved to an empty flow set")
    w = np.asarray(durations, np.float64)
    w = w / w.sum()
    if len(steps) == 1:
        # the degenerate anchor: no weighting arithmetic may perturb it
        fim = steps[0].fim.aggregate
        goodput = steps[0].mean_goodput
        rates = steps[0].mean_rate
    else:
        fim = np.einsum("k,ks->s", w, np.stack(
            [s.fim.aggregate for s in steps]))
        goodput = np.einsum("k,ks->s", w, np.stack(
            [s.mean_goodput for s in steps]))
        rates = np.einsum("k,ks->s", w, np.stack(
            [s.mean_rate for s in steps]))
    return TimelineResult(seeds=steps[0].fim.seeds, steps=steps,
                          weights=w, fim=fim, goodput=goodput, rates=rates)
