"""Time-expanded simulation: phased collectives over one compiled fabric.

The Monte-Carlo front ends (``monte_carlo_fim`` /
``monte_carlo_throughput``) flatten a workload into ONE steady-state
flow set — fine for the paper's saturating bipartite sweep, wrong for
the phased LLM mixes of ``core/llm_workload.py``: a training step runs
its collectives in *phases* (forward all-gather, MoE all-to-all,
backward reduce-scatter, gradient all-reduce, barrier), so merging them
into a single snapshot both **overstates contention** between
collectives that never share the wire and **hides phase-local
hotspots** that the other phases' flows average away.  Same class of
silent modeling bug the byte-blind FIM (PR 4) and free spraying (PR 5)
were: the simulation answers a question the workload never asks.

This module adds the time axis:

* a schedule is a list of ``TimelineStep``s, each naming the collective
  *channels* (``CollectiveOp.channel_id``) active during that step and a
  relative ``duration`` (``weight`` is the deprecated alias);
* ``simulate_timeline`` partitions one flow list by channel, routes each
  step's active flow set independently over ONE shared
  ``compile_fabric`` pass, and scores each step with the *same* engines
  the merged path uses — ``simulate_paths`` + ``fim_from_counts`` +
  ``throughput_from_result`` — so a one-step schedule containing every
  channel reproduces the merged snapshot **bit-identically** (the
  differential anchor in tests/test_timeline.py);
* ``TimelineResult`` carries the per-step series and the time-weighted
  totals.

**Two timing models** (``SimSpec.timing``):

``timing="static"`` (default) weights steps by their exogenous
``TimelineStep.duration`` constants.  Step durations are relative
durations, not byte shares: with byte-proportional weights the
time-weighted FIM can *never* exceed the merged FIM (the merged load
vector is the byte-weighted mean of the step load vectors, and MAPE is
convex — triangle inequality), which would hide exactly the bug this
module exposes.  Equal default durations model a synchronous schedule —
every phase holds the fabric for one barrier-to-barrier interval
regardless of how many bytes it moves.

``timing="event"`` *derives* each step's duration from the routing
under test: every flow carries its byte volume (``Flow.bytes``, the
emitters attach it per collective), the routed max-min goodput drains
those bytes, flows **depart** as they finish — each departure re-fills
the survivors' rates over the already-computed path tensors
(``vector_throughput.departure_fill``; no re-walk) — and the step ends
when its slowest flow completes.  A routing strategy that collides
badly now looks worse in *time*, not just in FIM: the collision-halved
elephant is the slowest flow, and its lengthened step is exactly the
operator-visible symptom (LLMPrism reconstructs timelines from it;
STrack evaluates load balancing by flow completion time).
``TimelineResult`` then also carries absolute per-step start/end times,
per-flow completion times, and the per-seed **job completion time** —
and the per-step FIM/rate/goodput snapshots are computed exactly as in
static mode, so a one-step schedule stays bit-identical across timings.
Under event timing an ``AdaptiveSpraying`` strategy's round budget is
expressed in RTTs of the derived duration
(``reordering.rtt_round_budget``): the step is first routed with the
static round-1 allocation to derive its length, then re-routed with the
rounds that length affords — so re-spray exposure is charged per unit
time, and a sub-RTT barrier cannot adapt at all.

Schedule emitters for the committed LLM scenarios live in
``core/llm_workload.py`` (``llm_collective_phases`` et al.) with two
modes: ``"sequential"`` (every phase alone, the synchronous-training
default) and ``"dp-overlap"`` (gradient all-reduce overlapped into the
backward phase, the standard DP-overlap optimization).  Channel ids are
registered by name (``register_channel``) so schedule-validation errors
name the ``CH_*`` vocabulary instead of bare ints.
"""

from __future__ import annotations

import dataclasses
import re
import warnings
from collections.abc import Sequence

import numpy as np

from .compile_fabric import CompiledFabric, compile_fabric
from .fabric import Fabric
from .flows import Flow, WorkloadDescription
from .strategies import AdaptiveSpraying
from .vector_sim import (
    MonteCarloFim, SimSpec, TIMING_EVENT, TIMING_STATIC, _UNSET,
    fim_from_counts, resolve_flows, resolve_spec, segment_reduce,
    simulate_paths,
)
from .vector_throughput import (
    MonteCarloThroughput, departure_fill, max_min_rates,
    throughput_from_result,
)

_CHANNEL_RE = re.compile(r"#ch(\d+)$")

#: bytes -> gigabits (the unit ``departure_fill`` drains at Gb/s rates)
_GBITS_PER_BYTE = 8e-9

_WEIGHT_ALIAS_WARNED = False


# ---------------------------------------------------------------------------
# channel registry: ids -> CH_* names, for readable validation errors
# ---------------------------------------------------------------------------

_CHANNEL_NAMES: dict[int, str] = {}


def register_channel(channel_id: int, name: str, *,
                     replace: bool = False) -> int:
    """Name a collective channel id so schedule-validation errors read
    ``4 (CH_MOE_A2A)`` instead of a bare int.

    A duplicate id with a *different* name raises unless
    ``replace=True`` — the same contract as ``register_transport`` /
    ``register_strategy``: silently renaming a channel would relabel
    every schedule that references it.  Re-registering the same
    (id, name) pair is a no-op, so emitter modules can register at
    import time safely.  Returns the id, so emitters can write
    ``CH_FOO = register_channel(7, "CH_FOO")``."""
    cid = int(channel_id)
    if not replace and cid in _CHANNEL_NAMES and _CHANNEL_NAMES[cid] != name:
        raise ValueError(
            f"channel {cid} is already registered as "
            f"{_CHANNEL_NAMES[cid]!r} (known: {known_channels()}); "
            f"pass replace=True to rename it")
    _CHANNEL_NAMES[cid] = name
    return cid


def known_channels() -> list[str]:
    """The registered channel vocabulary, sorted by id, as
    ``"<id> (<name>)"`` strings — what validation errors print."""
    return [f"{cid} ({name})" for cid, name in sorted(_CHANNEL_NAMES.items())]


def channel_name(channel_id: int) -> str:
    """``"<id> (<name>)"`` when registered, the bare id otherwise."""
    name = _CHANNEL_NAMES.get(channel_id)
    return f"{channel_id} ({name})" if name is not None else str(channel_id)


# ---------------------------------------------------------------------------
# schedule vocabulary
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True, slots=True, init=False)
class TimelineStep:
    """One schedule step: the channels on the wire and how long they hold it.

    ``channels`` are ``CollectiveOp.channel_id`` values (the flow labels
    carry them as the ``#ch<N>`` suffix ``collectives_to_flows`` emits);
    a channel may appear in several steps (an overlapped collective
    spans phases).  ``duration`` is the step's relative duration under
    ``timing="static"`` — see the module docstring for why it is not a
    byte share — and is ignored under ``timing="event"``, where the
    duration is derived from the routed goodput.  ``weight=`` is
    accepted as a deprecated alias of ``duration=`` (one warning per
    process; passing both raises)."""

    name: str
    channels: tuple[int, ...]
    duration: float

    def __init__(self, name: str, channels: Sequence[int],
                 duration: float | None = None, *,
                 weight: float | None = None):
        if weight is not None:
            if duration is not None:
                raise TypeError(
                    "pass duration= only (weight= is its deprecated "
                    "alias), not both")
            global _WEIGHT_ALIAS_WARNED
            if not _WEIGHT_ALIAS_WARNED:
                warnings.warn(
                    "TimelineStep(weight=...) is deprecated; the field "
                    "is named duration (identical semantics: relative "
                    "step length under timing='static')",
                    DeprecationWarning, stacklevel=2)
                _WEIGHT_ALIAS_WARNED = True
            duration = weight
        object.__setattr__(self, "name", name)
        object.__setattr__(self, "channels", tuple(channels))
        object.__setattr__(self, "duration",
                           1.0 if duration is None else float(duration))
        if not self.channels:
            raise ValueError(f"step {self.name!r} has no channels")
        if not self.duration > 0:
            raise ValueError(
                f"step {self.name!r} duration must be > 0, "
                f"got {self.duration}")

    @property
    def weight(self) -> float:
        """Deprecated alias of ``duration`` (kept so existing readers of
        the old field name keep working; prefer ``duration``)."""
        return self.duration


def merged_step(schedule: Sequence[TimelineStep],
                name: str = "merged") -> TimelineStep:
    """The degenerate one-step schedule: every channel of ``schedule``
    active at once — the merged-snapshot view the time axis replaces,
    kept as the differential anchor."""
    seen: dict[int, None] = {}
    for step in schedule:
        for ch in step.channels:
            seen.setdefault(ch, None)
    return TimelineStep(name=name, channels=tuple(seen))


def flow_channel(flow: Flow) -> int | None:
    """The collective channel id a flow belongs to, parsed from the
    ``#ch<N>`` label suffix ``collectives_to_flows`` writes.  ``None``
    for unlabeled flows (synthetic bipartite workloads)."""
    m = _CHANNEL_RE.search(flow.label)
    return int(m.group(1)) if m else None


def partition_flows(
    flows: Sequence[Flow], schedule: Sequence[TimelineStep]
) -> list[list[Flow]]:
    """Each step's active flow sublist, in original flow order (order
    preservation is what makes the one-step schedule bit-identical to
    the merged run).

    Validation is strict in both directions — silently dropping traffic
    *or* silently simulating an idle step is exactly the class of bug
    this module exists to remove:

    * flows whose channel appears in no step raise (unscheduled
      traffic);
    * flows without a ``#ch<N>`` label raise (unattributable traffic);
    * a step referencing a channel that no flow carries — unknown id or
      legitimately empty collective — raises, naming the known channels
      (``register_channel`` vocabulary), so emitters must filter absent
      phases explicitly (``llm_schedule`` does).
    """
    if not flows:
        raise ValueError(
            "no flows to partition: the flow list is empty, so every "
            "schedule step would resolve to an empty flow set")
    chans = [flow_channel(f) for f in flows]
    unlabeled = sum(c is None for c in chans)
    if unlabeled:
        raise ValueError(
            f"{unlabeled} flows carry no '#ch<N>' label — "
            f"time-expanded simulation needs collective-derived flows "
            f"(see core/llm_workload.py)")
    present = {c for c in chans if c is not None}
    for step in schedule:
        missing = sorted(set(step.channels) - present)
        if missing:
            raise ValueError(
                f"step {step.name!r} references channel(s) "
                f"{[channel_name(c) for c in missing]} that no flow "
                f"carries; known channels here: "
                f"{[channel_name(c) for c in sorted(present)]} "
                f"(registered vocabulary: {known_channels()})")
    covered = {ch for step in schedule for ch in step.channels}
    stray = sorted({c for c in present if c not in covered})
    if stray:
        raise ValueError(
            f"flows on channels {stray} appear in no schedule step "
            f"(steps cover {sorted(covered)}); every collective must be "
            f"scheduled somewhere")
    return [[f for f, c in zip(flows, chans) if c in step.channels]
            for step in schedule]


def step_byte_totals(flows: Sequence[Flow],
                     schedule: Sequence[TimelineStep]) -> np.ndarray:
    """(K,) total wire bytes active during each step — the byte totals
    the ``llm_workload`` emitters attach to a schedule through the
    flows' ``#ch`` labels, and what ``timing="event"`` drains.  Shares
    ``partition_flows``'s strict validation; an overlapped flow (its
    channel in several steps) counts toward every step it is active in."""
    parts = partition_flows(flows, schedule)
    return np.array([float(sum(f.bytes for f in sub)) for sub in parts],
                    np.float64)


# ---------------------------------------------------------------------------
# results
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StepResult:
    """One step's full scoring: the routed flow set, FIM distribution,
    and throughput/goodput distribution — exactly what the merged
    pipeline would report had this step been the whole workload.

    Under ``timing="event"`` two more series appear: ``completion`` is
    the per-(flow, seed) completion time in seconds *relative to the
    step's start* (the departure-ordered drain of the flow's bytes) and
    ``duration`` the per-seed step duration — the completion of the
    slowest flow.  ``None`` under static timing."""

    step: TimelineStep
    flows: list[Flow]
    fim: MonteCarloFim
    throughput: MonteCarloThroughput
    completion: np.ndarray | None = None   # (N, S) seconds from step start
    duration: np.ndarray | None = None     # (S,) seconds

    @property
    def mean_goodput(self) -> np.ndarray:
        """(S,) mean per-flow goodput under each seed."""
        return self.throughput.goodput.mean(axis=0)

    @property
    def mean_rate(self) -> np.ndarray:
        """(S,) mean per-flow max-min rate under each seed."""
        return self.throughput.rates.mean(axis=0)


@dataclasses.dataclass
class TimelineResult:
    """Per-step series + time-weighted totals of a scheduled simulation.

    The totals weight each step by its normalized duration: ``fim`` is
    the duration-weighted mean of the per-step aggregate FIM — "the
    imbalance a uniformly-time-sampling observer sees" — and ``goodput``
    / ``rates`` the duration-weighted mean of per-step mean flow
    goodput/rate.  For a one-step schedule every series is the step's
    own, bit-identically.

    Under ``timing="static"`` the weights are the exogenous
    ``TimelineStep.duration`` constants (normalized, identical across
    seeds).  Under ``timing="event"`` each *seed* has its own derived
    step durations, so the totals are weighted per seed and the result
    additionally carries the absolute time axis: ``step_durations`` /
    ``step_starts`` / ``step_ends`` are ``(K, S)`` seconds (steps run
    back to back in schedule order — the synchronous-training contract),
    and ``job_completion`` is the per-seed end of the last step: the
    training-step wall-clock a collision-lengthened elephant directly
    inflates.  ``weights`` then reports the seed-mean duration shares
    (display/compat; the totals use the exact per-seed shares)."""

    seeds: np.ndarray                   # (S,)
    steps: list[StepResult]
    weights: np.ndarray                 # (K,) normalized step durations
    fim: np.ndarray                     # (S,) time-weighted aggregate FIM
    goodput: np.ndarray                 # (S,) time-weighted mean goodput
    rates: np.ndarray                   # (S,) time-weighted mean rate
    timing: str = TIMING_STATIC
    step_durations: np.ndarray | None = None   # (K, S) seconds (event)
    step_starts: np.ndarray | None = None      # (K, S) absolute seconds
    step_ends: np.ndarray | None = None        # (K, S) absolute seconds
    job_completion: np.ndarray | None = None   # (S,) seconds (event)

    @property
    def num_steps(self) -> int:
        return len(self.steps)

    def step_fim(self) -> np.ndarray:
        """(K, S) per-step aggregate FIM series."""
        return np.stack([s.fim.aggregate for s in self.steps])

    def flow_completion(self, step_index: int) -> np.ndarray:
        """(N, S) *absolute* completion times (seconds from job start)
        of step ``step_index``'s flows — the step's relative departure
        times shifted by its start.  Event timing only."""
        if self.timing != TIMING_EVENT:
            raise ValueError(
                "flow_completion is only defined under timing='event' "
                f"(this result is timing={self.timing!r})")
        return (self.step_starts[step_index]
                + self.steps[step_index].completion)

    def summary(self) -> dict[str, dict[str, float]]:
        rows: dict[str, np.ndarray] = {
            "fim": self.fim,
            "goodput": self.goodput,
            "rate": self.rates,
        }
        if self.job_completion is not None:
            rows["job_completion_s"] = self.job_completion
        for sr in self.steps:
            rows[f"fim[{sr.step.name}]"] = sr.fim.aggregate
            rows[f"goodput[{sr.step.name}]"] = sr.mean_goodput
            if sr.duration is not None:
                rows[f"duration_s[{sr.step.name}]"] = sr.duration
        out = {}
        for name, v in rows.items():
            v = np.asarray(v, np.float64).ravel()
            out[name] = {
                "mean": float(v.mean()),
                "std": float(v.std()),
                "min": float(v.min()),
                "p50": float(np.percentile(v, 50)),
                "max": float(v.max()),
            }
        return out


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


def _score_step(comp, sub, seeds, s, layers, only_used_leaves):
    """Route + score one step's flow set: the identical pipeline the
    merged front ends run, with the flowlet fill shared between the
    throughput snapshot and (under event timing) the departure drain."""
    res = simulate_paths(comp, sub, seeds, spec=s)
    agg, per_layer = fim_from_counts(
        res.link_flow_counts(), comp,
        layers=layers, only_used_leaves=only_used_leaves)
    flowlet_rates = max_min_rates(res, engine=s.engine)
    tp = throughput_from_result(res, transport=s.transport,
                                engine=s.engine,
                                flowlet_rates=flowlet_rates)
    fim = MonteCarloFim(seeds=res.seeds, aggregate=agg, per_layer=per_layer)
    return res, fim, tp, flowlet_rates


def _event_step_times(res, tp, flowlet_rates):
    """((N, S) per-flow completion seconds, (S,) step duration) of one
    routed step under the departure-ordered drain.

    Each tensor column drains its byte share — the parent flow's bytes
    times the flowlet's demand fraction — at goodput = max-min rate x
    transport efficiency.  Efficiency comes from the committed routing's
    exposure (held fixed across departures, see ``departure_fill``); the
    full-set fill is reused as round 1, so event timing adds only the
    departure re-fills on top of the static cost.  Byte volumes are
    floored at one byte: a zero-byte control flow completes in epsilon
    time rather than zero, keeping every step's duration positive (the
    duration-share weighting needs a nonzero total)."""
    fi = np.asarray(res.flow_index)
    bytes_f = np.array([f.bytes for f in res.flows], np.float64)
    gbits_f = np.maximum(bytes_f, 1.0) * _GBITS_PER_BYTE
    col_gbits = gbits_f[fi] * np.asarray(res.demand, np.float64)
    w = res.column_weights()
    dep = departure_fill(
        res.link_ids, res.compiled.link_gbps, col_gbits,
        weights=None if (w == 1.0).all() else w,
        efficiency=np.asarray(tp.efficiency)[fi],
        assume_unique=True, initial_rates=flowlet_rates)
    # a flow completes when its last flowlet does
    completion = np.ascontiguousarray(segment_reduce(
        dep.completion, fi, res.num_flows, np.maximum, 0.0))
    return completion, dep.duration


def simulate_timeline(
    fabric: Fabric | CompiledFabric,
    workload: WorkloadDescription | Sequence[Flow],
    schedule: Sequence[TimelineStep],
    seeds: Sequence[int] | np.ndarray,
    *,
    spec: SimSpec | None = None,
    fields=_UNSET,
    hash_backend=_UNSET,
    strategy=_UNSET,
    demand_mode=_UNSET,
    transport=_UNSET,
    layers: Sequence[str] | None = None,
    only_used_leaves: bool = False,
    engine=_UNSET,
    timing=_UNSET,
    max_hops=_UNSET,
) -> TimelineResult:
    """Simulate a phase schedule step by step over one compiled fabric.

    Every step routes ONLY its active flows (the others are off the wire
    — that is the fix), through the identical ``simulate_paths`` →
    ``fim_from_counts`` → ``throughput_from_result`` pipeline the merged
    front ends run, under the same ``SimSpec`` contract — pass one as
    ``spec=`` or the legacy ``strategy`` / ``demand_mode`` /
    ``transport`` / ``engine`` / ``timing`` kwargs, not both
    (``strategy`` accepts a registry name string or instance, resolved
    once up front and shared by every step; ``engine="jax"`` routes
    every step through the device engine).  The compiled fabric is
    shared across steps; a ``CompiledFabric`` passes through unchanged,
    so sweeps over schedules or strategies pay compilation once.

    ``timing="static"`` (default) weights the totals by the exogenous
    ``TimelineStep.duration`` constants.  ``timing="event"`` derives
    each step's duration from the routed goodput instead — flows depart
    as their bytes finish (``departure_fill``), the step ends with its
    slowest flow — and fills in the absolute time axis on the result
    (``step_starts`` / ``step_ends`` / ``job_completion``, per-flow
    ``StepResult.completion``).  The per-step FIM/rate/goodput
    *snapshots* are computed identically under both timings (full
    active-set allocation), so a one-step schedule is bit-identical
    across timings and to the merged front ends.  Under event timing an
    ``AdaptiveSpraying`` step is first routed at its static round-1
    allocation to derive the duration, then re-routed with the round
    budget that duration affords in transport RTTs
    (``rtt_round_budget`` — re-spray exposure priced per unit time).

    Schedules are validated strictly (``partition_flows``): stray flows,
    unlabeled flows, and steps whose channels no flow carries all raise
    — nothing is silently dropped or silently idle.
    """
    s = resolve_spec(spec, dict(
        fields=fields, hash_backend=hash_backend, strategy=strategy,
        demand_mode=demand_mode, transport=transport, engine=engine,
        timing=timing, max_hops=max_hops))
    comp = (fabric if isinstance(fabric, CompiledFabric)
            else compile_fabric(fabric))
    flows = resolve_flows(comp, workload)
    if not schedule:
        raise ValueError("schedule must contain at least one step")
    parts = partition_flows(flows, schedule)
    event = s.timing == TIMING_EVENT
    # AdaptiveSpraying under event timing: probe with the static round-1
    # allocation first, then spend the RTT budget the duration affords
    adaptive = (event and isinstance(s.strategy, AdaptiveSpraying)
                and s.strategy.rounds > 1)
    if adaptive:
        from .reordering import IDEAL, rtt_round_budget
        rtt = (s.transport.rtt_seconds if s.transport is not None
               else IDEAL.rtt_seconds)
    steps: list[StepResult] = []
    durations: list = []
    for step, sub in zip(schedule, parts):
        spec_k = (dataclasses.replace(s, strategy=s.strategy.with_rounds(1))
                  if adaptive else s)
        res, fim_k, tp, fr = _score_step(comp, sub, seeds, spec_k,
                                         layers, only_used_leaves)
        if not event:
            steps.append(StepResult(step=step, flows=sub, fim=fim_k,
                                    throughput=tp))
            durations.append(step.duration)
            continue
        completion, duration = _event_step_times(res, tp, fr)
        if adaptive:
            budget = rtt_round_budget(float(duration.mean()), rtt,
                                      s.strategy.rounds)
            if budget > 1:
                spec_k = dataclasses.replace(
                    s, strategy=s.strategy.with_rounds(budget))
                res, fim_k, tp, fr = _score_step(
                    comp, sub, seeds, spec_k, layers, only_used_leaves)
                completion, duration = _event_step_times(res, tp, fr)
        steps.append(StepResult(step=step, flows=sub, fim=fim_k,
                                throughput=tp, completion=completion,
                                duration=duration))
        durations.append(duration)
    if not event:
        w = np.asarray(durations, np.float64)
        w = w / w.sum()
        if len(steps) == 1:
            # the degenerate anchor: no weighting arithmetic may perturb it
            fim = steps[0].fim.aggregate
            goodput = steps[0].mean_goodput
            rates = steps[0].mean_rate
        else:
            fim = np.einsum("k,ks->s", w, np.stack(
                [s_.fim.aggregate for s_ in steps]))
            goodput = np.einsum("k,ks->s", w, np.stack(
                [s_.mean_goodput for s_ in steps]))
            rates = np.einsum("k,ks->s", w, np.stack(
                [s_.mean_rate for s_ in steps]))
        return TimelineResult(seeds=steps[0].fim.seeds, steps=steps,
                              weights=w, fim=fim, goodput=goodput,
                              rates=rates, timing=s.timing)
    dmat = np.stack(durations)             # (K, S) derived seconds
    ends = np.cumsum(dmat, axis=0)         # steps run back to back
    starts = ends - dmat
    job = ends[-1]
    w = dmat.mean(axis=1)
    w = w / w.sum()
    if len(steps) == 1:
        # the degenerate anchor: no weighting arithmetic may perturb it
        fim = steps[0].fim.aggregate
        goodput = steps[0].mean_goodput
        rates = steps[0].mean_rate
    else:
        wks = dmat / dmat.sum(axis=0)      # per-seed duration shares
        fim = (wks * np.stack(
            [s_.fim.aggregate for s_ in steps])).sum(axis=0)
        goodput = (wks * np.stack(
            [s_.mean_goodput for s_ in steps])).sum(axis=0)
        rates = (wks * np.stack(
            [s_.mean_rate for s_ in steps])).sum(axis=0)
    return TimelineResult(seeds=steps[0].fim.seeds, steps=steps,
                          weights=w, fim=fim, goodput=goodput, rates=rates,
                          timing=s.timing, step_durations=dmat,
                          step_starts=starts, step_ends=ends,
                          job_completion=job)
