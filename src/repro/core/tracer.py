"""FlowTracer: the paper's Algorithm 1.

Parallel hop-by-hop path discovery for every flow of a workload:

  * the workload's (s, d) pairs are divided among P processes (Step 2-3);
  * each process opens communication channels to the devices it needs
    (Step 4) and retrieves + filters the per-pair flow 5-tuples (Step 5,
    the ``ss`` / NIC-driver query);
  * the pair's flows are divided among T threads, each of which walks the
    flow hop-by-hop (Step 5, right side of Fig. 1): query the current
    device for the flow's egress interface (the switch's ECMP
    hash-visibility CLI), follow the topology file to the next device's
    ingress interface, repeat until the destination server is reached;
  * results are compiled by the Path Analyzer (report.py, Steps 6-7).

Device access goes through ``DeviceChannel`` objects whose connection
setup/query costs reproduce the paper's three SSH strategies (Fig. 5):
ADHOC (connect per query), PERSISTENT (one connection per device reused),
and persistent+threads (= the paper's Parallel+Persistent).  Latencies are
injected by a ``LatencyModel`` so Fig. 4/5 scaling is measurable on any
machine; set it to zero for pure-logic tests.

The tracer is deliberately jax-free so worker processes stay lightweight.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import ProcessPoolExecutor, ThreadPoolExecutor
from collections.abc import Sequence

from .ecmp import RoutingPolicy
from .fabric import Fabric, Link, SERVER
from .flows import Flow, PairSpec, WorkloadDescription

ADHOC = "adhoc"
PERSISTENT = "persistent"

Path = list[Link]


@dataclasses.dataclass(frozen=True, slots=True)
class LatencyModel:
    """Synthetic device-access costs (seconds).  ``connect_s`` dominates in
    practice — that is the entire point of the paper's Fig. 5."""

    connect_s: float = 0.0
    query_s: float = 0.0

    def sleep_connect(self):
        if self.connect_s:
            time.sleep(self.connect_s)

    def sleep_query(self):
        if self.query_s:
            time.sleep(self.query_s)


@dataclasses.dataclass
class ChannelStats:
    connects: int = 0
    queries: int = 0

    def merge(self, other: "ChannelStats") -> None:
        self.connects += other.connects
        self.queries += other.queries


class DeviceChannel:
    """An (SSH) session to one device.  ``query_egress`` is the switch
    hash-visibility CLI / server route+driver lookup."""

    def __init__(self, device: str, routing: RoutingPolicy,
                 latency: LatencyModel, stats: ChannelStats):
        self.device = device
        self.routing = routing
        self.latency = latency
        self.stats = stats
        self._open = False

    def connect(self) -> "DeviceChannel":
        self.latency.sleep_connect()
        self.stats.connects += 1
        self._open = True
        return self

    def query_egress(self, flow: Flow, ingress_port: str | None) -> Link:
        assert self._open, "channel used before connect()"
        self.latency.sleep_query()
        self.stats.queries += 1
        return self.routing.egress(self.device, flow, ingress_port)

    def query_flows(self, flows: Sequence[Flow], pair: PairSpec) -> list[Flow]:
        """Server-side 5-tuple retrieval (ss / NIC driver)."""
        assert self._open
        self.latency.sleep_query()
        self.stats.queries += 1
        return [f for f in flows if f.src == pair.src and f.dst == pair.dst]

    def close(self) -> None:
        self._open = False


class ConnectionManager:
    """Per-thread channel cache implementing the paper's SSH strategies."""

    def __init__(self, routing: RoutingPolicy, latency: LatencyModel,
                 mode: str = PERSISTENT):
        assert mode in (ADHOC, PERSISTENT), mode
        self.routing = routing
        self.latency = latency
        self.mode = mode
        self._local = threading.local()
        self._lock = threading.Lock()
        self._all_stats: list[ChannelStats] = []

    def _cache(self) -> dict[str, DeviceChannel]:
        if not hasattr(self._local, "chans"):
            self._local.chans = {}
        return self._local.chans

    def channel(self, device: str) -> DeviceChannel:
        if self.mode == ADHOC:
            # fresh connection, caller is expected to close after each use
            return DeviceChannel(device, self.routing, self.latency,
                                 self._thread_stats()).connect()
        cache = self._cache()
        if device not in cache:
            cache[device] = DeviceChannel(device, self.routing, self.latency,
                                          self._thread_stats()).connect()
        return cache[device]

    def _thread_stats(self) -> ChannelStats:
        if not hasattr(self._local, "stats"):
            self._local.stats = ChannelStats()
            with self._lock:
                self._all_stats.append(self._local.stats)
        return self._local.stats

    def release(self, chan: DeviceChannel) -> None:
        if self.mode == ADHOC:
            chan.close()

    def totals(self) -> ChannelStats:
        total = ChannelStats()
        for s in getattr(self, "_all_stats", []):
            total.merge(s)
        return total


@dataclasses.dataclass
class TraceResult:
    """Output of Algorithm 1 + bookkeeping for the scalability analysis."""

    paths: dict[int, Path]
    flows: list[Flow]
    wall_time_s: float
    stats: ChannelStats
    num_processes: int
    num_threads: int

    def merge(self, other: "TraceResult") -> None:
        self.paths.update(other.paths)
        self.flows.extend(other.flows)
        self.stats.merge(other.stats)


class FlowTracer:
    """Paper Algorithm 1.  ``flows`` is the ground-truth traffic the fabric
    carries (what the NIC driver / ss would report when queried)."""

    def __init__(
        self,
        fabric: Fabric,
        routing: RoutingPolicy,
        workload: WorkloadDescription,
        flows: Sequence[Flow],
        *,
        num_processes: int = 1,
        num_threads: int = 1,
        connection_mode: str = PERSISTENT,
        latency: LatencyModel | None = None,
        max_hops: int = 16,
    ):
        self.fabric = fabric
        self.routing = routing
        self.workload = workload
        self.flows = list(flows)
        self.num_processes = max(1, num_processes)
        self.num_threads = max(1, num_threads)
        self.connection_mode = connection_mode
        self.latency = latency or LatencyModel()
        self.max_hops = max_hops

    # -- hop-by-hop discovery for one flow (paper Section III-B) ----------
    def _trace_flow(self, flow: Flow, conns: ConnectionManager) -> Path:
        path: Path = []
        device, ingress = flow.src, None
        for _ in range(self.max_hops):
            chan = conns.channel(device)
            link = chan.query_egress(flow, ingress)
            conns.release(chan)
            path.append(link)
            nxt = link.dst
            if self.fabric.kind(nxt) == SERVER:
                if nxt != flow.dst:
                    raise RuntimeError(
                        f"flow {flow.flow_id} terminated at {nxt}, expected {flow.dst}"
                    )
                return path
            # topology file: egress interface -> next hop's ingress interface
            device, ingress = nxt, link.dst_port
        raise RuntimeError(f"flow {flow.flow_id} exceeded {self.max_hops} hops")

    # -- per-pair tracing: retrieve + filter + fan out over threads --------
    def _trace_pairs(self, pairs: Sequence[PairSpec]) -> TraceResult:
        t0 = time.perf_counter()
        conns = ConnectionManager(self.routing, self.latency, self.connection_mode)
        paths: dict[int, Path] = {}
        all_flows: list[Flow] = []
        lock = threading.Lock()

        def work(flow: Flow) -> None:
            p = self._trace_flow(flow, conns)
            with lock:
                paths[flow.flow_id] = p

        # One pool for the whole process: threads (and their persistent
        # channel caches) live across pairs, matching long-lived SSH
        # sessions in the Parallel+Persistent configuration.
        pool = (
            ThreadPoolExecutor(max_workers=self.num_threads)
            if self.num_threads > 1 else None
        )
        try:
            for pair in pairs:
                src_chan = conns.channel(pair.src)
                pair_flows = src_chan.query_flows(self.flows, pair)
                conns.release(src_chan)
                pair_flows = self.workload.filter(pair_flows)  # Alg.1 line 7
                all_flows.extend(pair_flows)
                if pool is None:
                    for f in pair_flows:
                        work(f)
                else:
                    list(pool.map(work, pair_flows))
        finally:
            if pool is not None:
                pool.shutdown(wait=True)
        return TraceResult(
            paths=paths,
            flows=all_flows,
            wall_time_s=time.perf_counter() - t0,
            stats=conns.totals(),
            num_processes=1,
            num_threads=self.num_threads,
        )

    # -- Algorithm 1 entry point -------------------------------------------
    def trace(self) -> TraceResult:
        t0 = time.perf_counter()
        pairs = self.workload.pairs
        if self.num_processes == 1 or len(pairs) <= 1:
            result = self._trace_pairs(pairs)
        else:
            shards = [pairs[i :: self.num_processes] for i in range(self.num_processes)]
            shards = [s for s in shards if s]
            with ProcessPoolExecutor(max_workers=len(shards)) as ex:
                results = list(
                    ex.map(
                        _process_entry,
                        [
                            (self.fabric, self.routing, self.workload, self.flows,
                             shard, self.num_threads, self.connection_mode,
                             self.latency, self.max_hops)
                            for shard in shards
                        ],
                    )
                )
            result = results[0]
            for r in results[1:]:
                result.merge(r)
        result.wall_time_s = time.perf_counter() - t0
        result.num_processes = self.num_processes
        result.num_threads = self.num_threads
        return result


def _process_entry(payload) -> TraceResult:
    (fabric, routing, workload, flows, shard, num_threads, mode, latency,
     max_hops) = payload
    tracer = FlowTracer(
        fabric, routing, WorkloadDescription(pairs=list(shard),
                                             filter_protocols=workload.filter_protocols),
        flows, num_threads=num_threads, connection_mode=mode,
        latency=latency, max_hops=max_hops,
    )
    return tracer._trace_pairs(list(shard))


def auto_processes(num_pairs: int, max_procs: int = 8) -> int:
    """Paper: the process count 'can be automatically calculated based on
    the total number of pairs in the workload'."""
    return max(1, min(max_procs, num_pairs))
