"""Vectorized fabric path simulation: all flows x all hash seeds at once.

``FlowTracer`` discovers paths the way the paper's tool does — one flow,
one hop, one (simulated) device query at a time.  That is the right model
for the *measurement* tool, but evaluating routing schemes (paper Fig. 3a
"repeated multiple times"; PRIME/congestion-aware selection in PAPERS.md)
needs Monte-Carlo over thousands of hash seeds, where the per-hop Python
walk is ~1000x too slow.

This module replays the exact same forwarding process as whole-array
operations on a ``CompiledFabric``:

* state is an ``(N flows, S seeds)`` array of current-device ids;
* each hop gathers the candidate row for every (flow, seed), evaluates
  ``ecmp_hash`` — the same splitmix64-over-CRC32-fields mix, lifted to
  numpy uint64 (which wraps mod 2**64 exactly like the masked Python
  int arithmetic) — and indexes the chosen egress link;
* the walk stops when every (flow, seed) lands on a server.

The result is **bit-identical** to ``EcmpRouting`` + ``FlowTracer``
(differential-tested in tests/test_vector_sim.py) while ~100-1000x
faster per seed.  Link loads and FIM come from one ``bincount`` over the
link-id tensor instead of dict loops.

An optional ``hash_backend="murmur"`` routes the per-hop hash through the
``bulk_hash`` Pallas kernel path (TPU-native murmur3 avalanche) instead
— statistically equivalent, *not* bit-identical to the Python tracer; use
it for accelerator-scale sweeps.
"""

from __future__ import annotations

import dataclasses
from collections.abc import Sequence

import numpy as np

from .compile_fabric import CompiledFabric, compile_fabric
from .contracts import check_spec, check_trace_result, contracts_enabled
from .ecmp import (
    FIELDS_5TUPLE, FIELDS_IP_PAIR, FIELDS_VXLAN, HASH_INIT,
    flow_fields_matrix,
)
from .fabric import Fabric
from .flows import Flow, WorkloadDescription, synthesize_flows
from .fim import Path


def resolve_flows(
    comp: CompiledFabric,
    workload: WorkloadDescription | Sequence[Flow],
) -> list[Flow]:
    """Standard Monte-Carlo front-end contract: a ``WorkloadDescription``
    is synthesized into flows (NIC plan read from the compiled fabric's
    recorded ``nic_indices``); an explicit flow sequence passes through.

    Synthesis round-robins over the *recorded* NIC indices, not over
    ``range(max_index + 1)``: a fabric whose servers expose a sparse NIC
    numbering (say NICs 0 and 4 on a half-populated host) must never
    synthesize traffic for NICs that have no links."""
    if isinstance(workload, WorkloadDescription):
        from .fabric import nic_ip
        idx = comp.nic_indices
        return synthesize_flows(
            workload, nic_ip=lambda srv, k: nic_ip(srv, idx[k]),
            nics_per_server=len(idx))
    return list(workload)

EXACT = "exact"    # splitmix64 over CRC32 fields == core/ecmp.py bit-for-bit
MURMUR = "murmur"  # kernels/flowhash murmur3 (TPU bulk_hash path)

ENGINE_NUMPY = "numpy"  # host engine: the differential reference
ENGINE_JAX = "jax"      # jitted device engine (core/jax_engine.py)


def resolve_hash_backend(hash_backend: str | None, engine: str) -> str:
    """``None`` means "the engine's natural backend": the numpy engine
    (and jax on CPU, where the differential CI runs) keep the exact
    tracer-identical splitmix64; the jax engine on a real accelerator
    defaults to the murmur kernel path (64-bit multiplies are hostile
    there).  An explicit backend always wins; an unknown one fails here,
    before any routing work happens."""
    if hash_backend is not None:
        if hash_backend not in (EXACT, MURMUR):
            raise ValueError(
                f"unknown hash_backend {hash_backend!r}; "
                f"have {(EXACT, MURMUR)}")
        return hash_backend
    if engine == ENGINE_JAX:
        from .jax_engine import default_hash_backend
        return default_hash_backend(engine)
    return EXACT

DEMAND_UNIFORM = "uniform"  # every flow weighs 1 (the PR 1-3 behaviour)
DEMAND_BYTES = "bytes"      # flows weigh their wire bytes (mean-normalized)


def flow_demand_weights(flows: Sequence[Flow], demand_mode: str) -> np.ndarray:
    """(N,) strictly positive per-flow demand weights.

    ``"uniform"`` is all-ones — the historical unit-demand model.
    ``"bytes"`` weighs each flow by ``Flow.bytes``, normalized to mean 1
    so weighted link loads stay magnitude-comparable with unweighted
    counts (total demand is N either way, FIM is scale-invariant
    regardless).  All-equal bytes — including the all-zero fallback —
    return exact ones, so ``demand_mode="bytes"`` on a homogeneous
    workload is bit-identical to ``"uniform"``.  Zero-byte flows inside
    a heterogeneous workload (barriers, control traffic) are floored at
    1 byte: they still exist on the wire and the max-min fill requires
    strictly positive demand.
    """
    n = len(flows)
    if demand_mode == DEMAND_UNIFORM:
        return np.ones(n)
    if demand_mode != DEMAND_BYTES:
        raise ValueError(
            f"unknown demand_mode {demand_mode!r}; "
            f"expected {DEMAND_UNIFORM!r} or {DEMAND_BYTES!r}")
    b = np.array([f.bytes for f in flows], np.float64)
    if n == 0 or (b == b[0]).all():
        return np.ones(n)
    b = np.maximum(b, 1.0)
    return b / b.mean()


# ---------------------------------------------------------------------------
# SimSpec: the one validated description of *how* to simulate
# ---------------------------------------------------------------------------

# Legacy-kwarg sentinel: front ends default every per-simulation kwarg to
# this so "not passed" is distinguishable from "passed its default" — a
# caller who mixes an explicit kwarg with ``spec=`` gets a loud error
# instead of a silent winner.
_UNSET = object()

_KNOWN_FIELDS = (FIELDS_5TUPLE, FIELDS_VXLAN, FIELDS_IP_PAIR)

TIMING_STATIC = "static"  # exogenous step durations (TimelineStep.duration)
TIMING_EVENT = "event"    # durations derived from routed goodput: a step
#                           ends when its slowest flow's bytes finish, and
#                           flows depart mid-step (vector_throughput.
#                           departure_fill); see core/timeline.py


@dataclasses.dataclass(frozen=True)
class SimSpec:
    """Every knob that selects *how* a simulation runs, in one place.

    The four Monte-Carlo front ends (``simulate_paths``,
    ``monte_carlo_fim``, ``monte_carlo_throughput``,
    ``simulate_timeline``) historically re-declared the same sprawling
    kwarg set with per-function validation; a ``SimSpec`` carries it
    once and ``resolve()`` validates and normalizes everything in one
    place.  Front ends accept ``spec=SimSpec(...)`` *or* the legacy
    kwargs (which build a SimSpec internally); passing both raises.

    Fields (all optional — the zero-argument ``SimSpec()`` is the
    historical default everywhere):

    * ``strategy`` — ``None`` (per-flow ECMP), a registry name string
      (``"wave-congestion-aware"``), or a ``RoutingStrategy`` instance;
    * ``demand_mode`` — ``"uniform"`` or ``"bytes"``
      (``flow_demand_weights``);
    * ``engine`` — ``"numpy"`` or ``"jax"``;
    * ``hash_backend`` — ``"exact"``, ``"murmur"``, or ``None`` for the
      engine's natural backend (``resolve_hash_backend`` owns the
      engine->backend coupling);
    * ``transport`` — ``None``/name/``TransportProfile`` for the
      reordering-cost model (only throughput-bearing front ends read
      it; carrying it on a paths-only spec is harmless);
    * ``fields`` — the hash-field mode (``"5tuple"``/``"vxlan"``/
      ``"ip-pair"``);
    * ``max_hops`` — walk hop budget;
    * ``timing`` — how ``simulate_timeline`` prices the time axis:
      ``"static"`` (exogenous ``TimelineStep.duration`` weights, the
      historical model) or ``"event"`` (step durations *derived* from
      the achieved max-min goodput, with flows departing as their bytes
      finish — core/timeline.py).  Snapshot front ends ignore it.

    ``resolve()`` is idempotent, so a resolved spec can be handed from
    front end to front end without re-validating work: names become
    registry instances, ``hash_backend=None`` becomes the engine's
    concrete backend, and every enum-ish field is range-checked.
    Per-*call* inputs (the fabric, flows, seeds, a precomputed
    ``field_matrix``, FIM layer selections) stay arguments — a spec
    describes the simulation contract, not one invocation's data."""

    strategy: object = None
    demand_mode: str = DEMAND_UNIFORM
    engine: str = ENGINE_NUMPY
    hash_backend: str | None = None
    transport: object = None
    fields: str = FIELDS_5TUPLE
    max_hops: int = 16
    timing: str = TIMING_STATIC

    def resolve(self) -> "SimSpec":
        if self.engine not in (ENGINE_NUMPY, ENGINE_JAX):
            raise ValueError(
                f"unknown engine {self.engine!r}; "
                f"expected {ENGINE_NUMPY!r} or {ENGINE_JAX!r}")
        if self.timing not in (TIMING_STATIC, TIMING_EVENT):
            raise ValueError(
                f"unknown timing {self.timing!r}; "
                f"expected {TIMING_STATIC!r} or {TIMING_EVENT!r}")
        if self.demand_mode not in (DEMAND_UNIFORM, DEMAND_BYTES):
            raise ValueError(
                f"unknown demand_mode {self.demand_mode!r}; "
                f"expected {DEMAND_UNIFORM!r} or {DEMAND_BYTES!r}")
        if self.fields not in _KNOWN_FIELDS:
            raise ValueError(
                f"unknown fields mode {self.fields!r}; "
                f"have {_KNOWN_FIELDS}")
        if int(self.max_hops) < 1:
            raise ValueError(f"max_hops must be >= 1, got {self.max_hops}")
        strategy = self.strategy
        if strategy is not None:
            from .strategies import resolve_strategy
            strategy = resolve_strategy(strategy)
        transport = self.transport
        if transport is not None:
            from .reordering import resolve_transport
            transport = resolve_transport(transport)
        return dataclasses.replace(
            self, strategy=strategy, transport=transport,
            hash_backend=resolve_hash_backend(self.hash_backend, self.engine),
            max_hops=int(self.max_hops))


def resolve_spec(spec: SimSpec | None, kwargs: dict) -> SimSpec:
    """Front-end glue: the resolved ``SimSpec`` from ``spec=`` OR legacy
    kwargs (values still ``_UNSET`` are dropped, so dataclass defaults
    apply).  Mixing both raises — explicitly, naming the kwargs — and a
    non-SimSpec ``spec`` fails as a type error rather than an attribute
    error three calls deep."""
    passed = {k: v for k, v in kwargs.items() if v is not _UNSET}
    if spec is not None:
        if passed:
            raise ValueError(
                "pass either spec= or the per-simulation kwargs, not both "
                f"(got spec= together with {sorted(passed)})")
        if not isinstance(spec, SimSpec):
            raise TypeError(
                f"spec must be a SimSpec, got {type(spec).__name__}")
        s = spec.resolve()
    else:
        s = SimSpec(**passed).resolve()
    if contracts_enabled():
        check_spec(s)
    return s


_M1 = np.uint64(0xBF58476D1CE4E5B9)
_M2 = np.uint64(0x94D049BB133111EB)
_INIT = np.uint64(HASH_INIT)


def _mix64_vec(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer on uint64 arrays — numpy wraparound arithmetic
    matches ``ecmp._mix64``'s masked Python ints exactly."""
    x = (x ^ (x >> np.uint64(30))) * _M1
    x = (x ^ (x >> np.uint64(27))) * _M2
    return x ^ (x >> np.uint64(31))


def ecmp_hash_vec(fields: np.ndarray, seeds: np.ndarray) -> np.ndarray:
    """Batched ``ecmp_hash``: fields (N, F) uint64, seeds (...,) uint64
    broadcastable against (N, ...) -> hashes of fields under each seed."""
    h = _mix64_vec(seeds ^ _INIT)
    for f in range(fields.shape[1]):
        h = _mix64_vec(h ^ fields[:, f].reshape(
            (-1,) + (1,) * (h.ndim - 1)))
    return h


def _murmur_hash_grid(fields: np.ndarray, dev_seed: np.ndarray) -> np.ndarray:
    """Per-(flow, seed) murmur3 hash grid, seed-as-init convention.

    The ONE murmur definition, shared across every consumer: the hash
    starts at the (truncated) device seed and folds the field columns —
    exactly what the Pallas ``bulk_hash`` kernel computes for a scalar
    seed and what ``jax_engine``'s device grid computes per cell.  The
    fold/fmix formulas are imported from the kernel module (they are
    polymorphic over numpy and jnp arrays), so the numpy backend can
    never drift from the kernel — and needs no jax round-trip."""
    from ..kernels.flowhash.kernel import murmur_fmix, murmur_fold

    h = (dev_seed & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    f32 = fields.astype(np.uint32)
    for f in range(fields.shape[1]):
        h = murmur_fold(h, f32[:, f].reshape((-1,) + (1,) * (h.ndim - 1)))
    return murmur_fmix(h).astype(np.uint64)


def hash_grid(field_mat: np.ndarray, dev_seed: np.ndarray,
              hash_backend: str) -> np.ndarray:
    """Per-(flow, seed) hash grid under the selected backend — the one
    dispatch point shared by the ECMP walk and the routing strategies
    (so e.g. the congestion-aware tie-break honors ``hash_backend`` the
    same way the main walk does)."""
    if hash_backend == EXACT:
        return ecmp_hash_vec(field_mat, dev_seed)
    if hash_backend == MURMUR:
        return _murmur_hash_grid(field_mat, dev_seed)
    raise ValueError(f"unknown hash backend: {hash_backend}")


@dataclasses.dataclass
class VectorTraceResult:
    """Paths for N flows under S seeds, as a dense link-id tensor.

    Multi-path strategies (PRIME-style spraying) emit more tensor columns
    than there are flows: each column is a *flowlet* — ``flow_index[j]``
    names its parent flow (row into ``flows``) and ``demand[j]`` the
    fraction of the parent's demand it carries (flowlet demands sum to 1
    per flow).  Single-path strategies leave the defaults
    (``flow_index == arange(N)``, ``demand == 1``), and every consumer
    below degenerates to the PR-1 behaviour exactly.

    ``flow_demand`` carries the *per-flow* demand weight (paper Step 1
    names flow volumes, not just pairs): ``demand_mode="bytes"`` derives
    it from ``Flow.bytes`` normalized to mean 1.  It composes
    multiplicatively with the flowlet fractions — a column's effective
    weight is ``flow_demand[flow_index[j]] * demand[j]``
    (``column_weights``) — so a sprayed elephant's flowlets each carry
    1/K of the elephant's weight, not of a unit.
    """

    compiled: CompiledFabric
    flows: list[Flow]
    seeds: np.ndarray        # (S,) uint64 (as given, masked to 64 bit)
    link_ids: np.ndarray     # (H, Nf, S) int32 link ids, -1 past arrival
    flow_index: np.ndarray | None = None   # (Nf,) parent-flow row per column
    demand: np.ndarray | None = None       # (Nf,) demand fraction per column
    strategy: str = "ecmp"
    flow_demand: np.ndarray | None = None  # (N,) per-flow demand weight
    #: optional (N, S) strategy-induced reordering exposure on top of what
    #: the flowlet tensors imply — adaptive re-spray charges each accepted
    #: mid-flow path change here (core/strategies.AdaptiveSpraying), and
    #: ``flowlet_exposure`` adds it to the skew + dispersion terms.  None
    #: (every static strategy) keeps the PR-5 exposure model bit-exact.
    extra_exposure: np.ndarray | None = None

    def __post_init__(self):
        nf = self.link_ids.shape[1]
        if self.flow_index is None:
            self.flow_index = np.arange(nf, dtype=np.int32)
        if self.demand is None:
            self.demand = np.ones(nf)
        if self.flow_demand is None:
            self.flow_demand = np.ones(len(self.flows))

    @property
    def num_flows(self) -> int:
        return len(self.flows)

    @property
    def num_flowlets(self) -> int:
        return self.link_ids.shape[1]

    @property
    def num_seeds(self) -> int:
        return len(self.seeds)

    @property
    def is_multipath(self) -> bool:
        return self.num_flowlets != self.num_flows

    def hop_counts(self) -> np.ndarray:
        """(Nf, S) links crossed per tensor column per seed — the
        path-length grid the reordering model's skew term reads."""
        return (self.link_ids >= 0).sum(axis=0)

    def paths_for_seed(self, seed_index: int) -> dict[int, Path]:
        """Materialize one seed's paths in ``FlowTracer`` format (for
        differential testing / drop-in use with the dict-based tools).
        Single-path results only; multi-path callers want
        ``flowlet_paths_for_seed``."""
        if self.is_multipath:
            raise ValueError(
                f"{self.strategy!r} result has {self.num_flowlets} flowlets "
                f"for {self.num_flows} flows; use flowlet_paths_for_seed")
        links = self.compiled.links
        out: dict[int, Path] = {}
        ids = self.link_ids[:, :, seed_index]
        for j, flow in enumerate(self.flows):
            out[flow.flow_id] = [links[i] for i in ids[:, j] if i >= 0]
        return out

    def flowlet_paths_for_seed(self, seed_index: int) -> dict[int, list[Path]]:
        """One seed's paths per flow id, as a *list* of flowlet paths."""
        links = self.compiled.links
        out: dict[int, list[Path]] = {f.flow_id: [] for f in self.flows}
        ids = self.link_ids[:, :, seed_index]
        for j in range(self.num_flowlets):
            fid = self.flows[int(self.flow_index[j])].flow_id
            out[fid].append([links[i] for i in ids[:, j] if i >= 0])
        return out

    def column_weights(self) -> np.ndarray:
        """(Nf,) effective demand per tensor column: the parent flow's
        ``flow_demand`` times the column's flowlet fraction.  Uniform
        flow demand short-circuits to ``demand`` itself so the
        single-path / unit-demand fast paths stay bit-identical."""
        if (self.flow_demand == 1.0).all():
            return self.demand
        return self.flow_demand[self.flow_index] * self.demand

    def link_flow_counts(self) -> np.ndarray:
        """(S, L) flow load per link per seed — one bincount, no dicts.

        Columns contribute their effective demand (``column_weights``):
        a sprayed flow still adds up to its ``flow_demand`` per layer
        crossing, total load per layer is demand-invariant across
        strategies, and uniform unit demand keeps the exact integer
        counts of the single-path engine.
        """
        L, S = self.compiled.num_links, self.num_seeds
        ids = self.link_ids                      # (H, Nf, S)
        offset = np.arange(S, dtype=np.int64) * L
        keep = ids >= 0
        flat = (ids.astype(np.int64) + offset)[keep]
        weights = self.column_weights()
        if (weights == 1.0).all():
            return np.bincount(flat, minlength=S * L).reshape(S, L)
        w = np.broadcast_to(weights[None, :, None], ids.shape)[keep]
        return np.bincount(flat, weights=w, minlength=S * L).reshape(S, L)


def segment_reduce(values: np.ndarray, fi: np.ndarray, n: int,
                   ufunc: np.ufunc, fill: float) -> np.ndarray:
    """Per-parent ``ufunc`` reduction over the column axis of an
    ``(Nf, S)`` array, grouping columns by ``fi`` (their parent-flow
    rows) into ``(n, S)``.  Parent-sorted contiguous ``fi`` — the
    flowlet layout every built-in multi-path strategy emits — takes the
    ``reduceat`` fast path; anything else falls back to a scatter
    reduction seeded with ``fill``.  Shared by the flowlet->flow rate
    aggregation (vector_throughput) and the reordering exposure model,
    so the two can never disagree on the grouping."""
    if fi.size and (np.diff(fi) >= 0).all():
        starts = np.flatnonzero(np.diff(fi, prepend=-1) > 0)
        if starts.size == n:               # every parent has >= 1 column
            return ufunc.reduceat(values, starts, axis=0)
    out = np.full((n, values.shape[1]), fill)
    ufunc.at(out, fi, values)
    return out


def normalize_seeds(seeds: Sequence[int] | np.ndarray) -> np.ndarray:
    """(S,) uint64 seed array, masked to 64 bit like the Python tracer."""
    return np.array(
        [int(s) & 0xFFFFFFFFFFFFFFFF for s in np.asarray(seeds).tolist()],
        np.uint64)


def ecmp_walk(
    comp: CompiledFabric,
    src_dev: np.ndarray,
    dst_dev: np.ndarray,
    src_key: np.ndarray,
    dst_key: np.ndarray,
    field_mat: np.ndarray,
    seeds_u64: np.ndarray,
    *,
    hash_backend: str | None = None,
    max_hops: int = 16,
    cell_salt: np.ndarray | None = None,
    describe=lambda n: f"column {n}",
    engine: str = ENGINE_NUMPY,
) -> np.ndarray:
    """The raw hop-by-hop hashed walk over explicit endpoint/field arrays.

    Exactly ``EcmpRouting``'s decision at each hop: candidates from the
    compiled ``Forwarder`` tables, ``hash % n_candidates`` when the set
    has more than one member, first (only) candidate otherwise.  Returns
    the ``(hops, N, S)`` link-id tensor.  ``simulate_paths`` is the
    flow-level front end; routing strategies (``core/strategies.py``)
    call this directly with expanded per-flowlet arrays.

    ``engine="jax"`` runs the identical walk as a jitted
    ``lax.while_loop`` on the accelerator (``core/jax_engine.py``) —
    bit-identical to the numpy walk backend for backend (the
    differential contract).  ``hash_backend=None`` resolves to the
    engine's natural backend (``resolve_hash_backend``).

    ``cell_salt`` optionally perturbs the entropy of individual
    ``(column, seed)`` cells: a ``(N, S)`` uint64 array XORed into every
    hop's device seed before hashing.  A zero cell leaves that cell's
    walk bit-identical to the salt-free walk (``x ^ 0 == x``), a nonzero
    cell re-rolls every hop decision — the vector equivalent of a sender
    re-picking its flowlet's entropy header value, which adaptive
    per-RTT re-spray does per cell under congestion feedback.
    """
    hash_backend = resolve_hash_backend(hash_backend, engine)
    if engine != ENGINE_NUMPY:
        from .jax_engine import jax_ecmp_walk, resolve_engine
        resolve_engine(engine)
        return jax_ecmp_walk(
            comp, src_dev, dst_dev, src_key, dst_key, field_mat, seeds_u64,
            hash_backend=hash_backend, max_hops=max_hops,
            cell_salt=cell_salt, describe=describe)
    N, S = len(src_dev), len(seeds_u64)
    state = np.broadcast_to(src_dev[:, None], (N, S)).copy()   # (N, S)
    done = np.zeros((N, S), bool)
    link_ids = np.full((max_hops, N, S), -1, np.int32)

    hops = 0
    for t in range(max_hops):
        if done.all():
            break
        hops = t + 1
        # src-keyed on the source host (hop 0), dst-keyed at every switch
        key = np.where(comp.is_server[state], src_key[:, None], dst_key[:, None])
        n = comp.cand_n[state, key]                    # (N, S)
        dev_seed = comp.dev_crc[state] ^ seeds_u64[None, :]
        if cell_salt is not None:
            dev_seed = dev_seed ^ cell_salt
        h = hash_grid(field_mat, dev_seed, hash_backend)
        safe_n = np.maximum(n, 1).astype(np.uint64)
        choice = np.where(n > 1, (h % safe_n).astype(np.int64), 0)
        link = comp.cand[state, key, choice]
        link = np.where(done | (n == 0), -1, link)
        link_ids[t] = link
        nxt = np.where(link >= 0, comp.link_dst[np.maximum(link, 0)], state)
        done |= (link < 0) | comp.is_server[nxt]
        state = nxt

    if not done.all():
        raise RuntimeError(f"some flows did not terminate in {max_hops} hops")
    arrived = state == np.broadcast_to(dst_dev[:, None], (N, S))
    if not arrived.all():
        bad = np.argwhere(~arrived)[0]
        raise RuntimeError(
            f"{describe(bad[0])} (seed index {bad[1]}) terminated "
            f"at {comp.device_names[state[bad[0], bad[1]]]}")
    return link_ids[:hops]


def simulate_paths(
    fabric: Fabric | CompiledFabric,
    flows: Sequence[Flow],
    seeds: Sequence[int] | np.ndarray,
    *,
    spec: SimSpec | None = None,
    fields=_UNSET,
    hash_backend=_UNSET,
    max_hops=_UNSET,
    field_matrix: np.ndarray | None = None,
    strategy=_UNSET,
    demand_mode=_UNSET,
    engine=_UNSET,
) -> VectorTraceResult:
    """Walk every flow through the fabric under every seed, vectorized.

    How to simulate is described by a ``SimSpec`` — pass one as
    ``spec=`` or pass the legacy kwargs (``strategy=``,
    ``demand_mode=``, ``engine=``, ``hash_backend=``, ``fields=``,
    ``max_hops=``), which build the spec internally; mixing both
    raises.  See ``SimSpec`` for the field contracts.

    The default is per-flow ECMP, bit-identical to ``EcmpRouting`` +
    ``FlowTracer``; ``strategy`` (name string or instance) routes the
    whole simulation through that strategy's vectorized implementation
    instead (the result may carry flowlet columns — see
    ``VectorTraceResult``).

    ``field_matrix`` optionally supplies precomputed
    ``flow_fields_matrix`` output so repeated sweeps over the same flow
    table skip the per-flow CRC pass (per-call data, so it stays an
    argument rather than a spec field).
    """
    s = resolve_spec(spec, dict(
        fields=fields, hash_backend=hash_backend, max_hops=max_hops,
        strategy=strategy, demand_mode=demand_mode, engine=engine))
    comp = fabric if isinstance(fabric, CompiledFabric) else compile_fabric(fabric)
    flows = list(flows)
    seeds_u64 = normalize_seeds(seeds)
    if len(flows) == 0:
        raise ValueError("simulate_paths needs at least one flow")
    if s.strategy is not None:
        # demand_mode / engine are only forwarded when they actually ask
        # for something: custom strategies registered against the older
        # route() signatures keep working under the defaults, and a
        # non-default request against one fails loudly (TypeError)
        # instead of silently dropping the ask
        extra = ({} if s.demand_mode == DEMAND_UNIFORM
                 else {"demand_mode": s.demand_mode})
        if s.engine != ENGINE_NUMPY:
            extra["engine"] = s.engine
        res = s.strategy.route(
            comp, flows, seeds_u64, fields=s.fields,
            hash_backend=s.hash_backend, max_hops=s.max_hops,
            field_matrix=field_matrix, **extra)
        if contracts_enabled():
            check_trace_result(res)
        return res
    flow_demand = flow_demand_weights(flows, s.demand_mode)
    field_mat = (field_matrix if field_matrix is not None
                 else flow_fields_matrix(flows, s.fields))  # (N, F) uint64
    src_dev, dst_dev, src_key, dst_key = comp.flow_endpoint_ids(flows)
    link_ids = ecmp_walk(
        comp, src_dev, dst_dev, src_key, dst_key, field_mat, seeds_u64,
        hash_backend=s.hash_backend, max_hops=s.max_hops,
        describe=lambda n: f"flow {flows[n].flow_id}", engine=s.engine)
    res = VectorTraceResult(
        compiled=comp, flows=flows, seeds=seeds_u64, link_ids=link_ids,
        flow_demand=flow_demand)
    if contracts_enabled():
        check_trace_result(res)
    return res


# ---------------------------------------------------------------------------
# Vectorized link loads / FIM (array twin of core/fim.py)
# ---------------------------------------------------------------------------


def fim_from_counts(
    counts: np.ndarray,
    comp: CompiledFabric,
    *,
    layers: Sequence[str] | None = None,
    only_used_leaves: bool = False,
) -> tuple[np.ndarray, dict[str, np.ndarray]]:
    """Aggregate and per-layer FIM per seed from an (S, L) count matrix.

    Mirrors ``fim``/``per_layer_fim`` semantics exactly: per layer,
    ideal = total/links, MAPE over links; layers with zero traffic are
    dropped; the aggregate weights each layer by its link count.  With
    ``only_used_leaves`` links are restricted per seed to those whose both
    endpoints carried traffic under that seed.
    """
    S = counts.shape[0]
    # `layers or ...` mirrors fim()/per_layer_fim(): an empty list also
    # means "all layers"
    layer_list = list(layers) if layers else comp.layer_names
    if only_used_leaves:
        present = counts > 0                       # (S, L)
        used = np.zeros((S, comp.num_devices), bool)
        rows = np.broadcast_to(
            np.arange(S, dtype=np.int64)[:, None], present.shape)
        np.logical_or.at(used, (rows, comp.link_src[None, :]), present)
        np.logical_or.at(used, (rows, comp.link_dst[None, :]), present)

    num = np.zeros(S)
    den = np.zeros(S)
    per_layer: dict[str, np.ndarray] = {}
    for layer in layer_list:
        if layer not in comp.layer_names:
            continue
        lid = comp.layer_names.index(layer)
        sel = np.flatnonzero(comp.link_layer == lid)
        if sel.size == 0:
            continue
        c = counts[:, sel].astype(np.float64)      # (S, Ll)
        if only_used_leaves:
            mask = (used[:, comp.link_src[sel]]
                    & used[:, comp.link_dst[sel]]).astype(np.float64)
        else:
            mask = np.ones_like(c)
        n_links = mask.sum(axis=1)                 # (S,)
        total = (c * mask).sum(axis=1)
        live = (total > 0) & (n_links > 0)
        ideal = np.where(live, total / np.maximum(n_links, 1), 1.0)
        mape = (100.0 / np.maximum(n_links, 1)
                * (np.abs(c - ideal[:, None]) / ideal[:, None] * mask).sum(1))
        mape = np.where(live, mape, 0.0)
        if not live.any():
            continue
        per_layer[layer] = mape
        num += np.where(live, mape * n_links, 0.0)
        den += np.where(live, n_links, 0.0)
    agg = np.divide(num, den, out=np.zeros(S), where=den > 0)
    return agg, per_layer


def fim_vector(
    result: VectorTraceResult,
    *,
    layers: Sequence[str] | None = None,
    only_used_leaves: bool = False,
) -> np.ndarray:
    """(S,) aggregate FIM per seed — vectorized ``fim()``."""
    agg, _ = fim_from_counts(result.link_flow_counts(), result.compiled,
                             layers=layers, only_used_leaves=only_used_leaves)
    return agg


# ---------------------------------------------------------------------------
# Monte-Carlo front end
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MonteCarloFim:
    """FIM distributions over a hash-seed sweep."""

    seeds: np.ndarray                       # (S,)
    aggregate: np.ndarray                   # (S,) FIM per seed
    per_layer: dict[str, np.ndarray]        # layer -> (S,) FIM per seed

    def summary(self) -> dict[str, dict[str, float]]:
        out = {}
        rows = {"aggregate": self.aggregate, **self.per_layer}
        for name, v in rows.items():
            out[name] = {
                "mean": float(v.mean()),
                "std": float(v.std()),
                "min": float(v.min()),
                "p50": float(np.percentile(v, 50)),
                "p95": float(np.percentile(v, 95)),
                "max": float(v.max()),
            }
        return out


def monte_carlo_fim(
    fabric: Fabric | CompiledFabric,
    workload: WorkloadDescription | Sequence[Flow],
    seeds: Sequence[int] | np.ndarray,
    *,
    spec: SimSpec | None = None,
    fields=_UNSET,
    hash_backend=_UNSET,
    layers: Sequence[str] | None = None,
    only_used_leaves: bool = False,
    strategy=_UNSET,
    demand_mode=_UNSET,
    engine=_UNSET,
    max_hops=_UNSET,
) -> MonteCarloFim:
    """FIM distribution of a routing strategy across a hash-seed sweep.

    ``workload`` may be a ``WorkloadDescription`` (flows are synthesized
    the standard way, NIC count inferred from the fabric) or an explicit
    flow list.  How to simulate comes from a ``SimSpec`` — pass one as
    ``spec=`` or the legacy kwargs, not both (``simulate_paths``
    contract; default: per-flow ECMP, unit demand;
    ``demand_mode="bytes"`` makes the FIM byte-weighted).  ``layers`` /
    ``only_used_leaves`` describe what to *measure*, not how to route,
    so they stay per-call arguments.

    ``engine="jax"`` with plain ECMP takes the fused device pipeline
    (walk + counts + FIM in one pass, ``jax_engine``); other strategies
    route on the jax walk and aggregate on host.
    """
    s = resolve_spec(spec, dict(
        fields=fields, hash_backend=hash_backend, strategy=strategy,
        demand_mode=demand_mode, engine=engine, max_hops=max_hops))
    comp = fabric if isinstance(fabric, CompiledFabric) else compile_fabric(fabric)
    if s.engine != ENGINE_NUMPY and _is_plain_ecmp(s.strategy):
        from .jax_engine import fused_monte_carlo_fim, resolve_engine
        resolve_engine(s.engine)
        return fused_monte_carlo_fim(
            comp, workload, seeds, fields=s.fields,
            hash_backend=s.hash_backend,
            layers=layers, only_used_leaves=only_used_leaves,
            demand_mode=s.demand_mode, max_hops=s.max_hops)
    flows = resolve_flows(comp, workload)
    res = simulate_paths(comp, flows, seeds, spec=s)
    agg, per_layer = fim_from_counts(
        res.link_flow_counts(), comp,
        layers=layers, only_used_leaves=only_used_leaves)
    return MonteCarloFim(seeds=res.seeds, aggregate=agg, per_layer=per_layer)


def _is_plain_ecmp(strategy) -> bool:
    """True when ``strategy`` requests the default per-flow ECMP walk —
    the shape the fused device pipeline implements.  Configured or custom
    strategies (including subclasses of ``EcmpStrategy``) route through
    their own ``route`` with the device walk underneath instead."""
    if strategy is None or strategy == "ecmp":
        return True
    from .strategies import EcmpStrategy
    return type(strategy) is EcmpStrategy
