"""Batched progressive-filling max-min fairness: all seeds fill at once.

``max_min_throughput`` (core/fim.py) is the readable reference: one seed,
dict-of-sets bookkeeping, one bottleneck link frozen per iteration.  The
paper's headline comparison (Fig. 3a) is only half FIM — the other half
is *throughput*: colliding RoCE flows halving each other under max-min
sharing (paper Section I).  Evaluating a routing scheme therefore needs
the per-pair **rate distribution** over thousands of hash seeds, and the
scalar loop is orders of magnitude too slow for that.

This module runs the same filling on the dense ``(H, N, S)`` link-id
tensor that ``vector_sim.simulate_paths`` produces, using the classic
*parallel* formulation of progressive filling: a (link, seed) cell is a
bottleneck as soon as its fair share ``residual / active_flows`` equals
the minimum share seen anywhere on the path of **every** flow crossing
it — not just when it is the global minimum of its seed.  Freezing all
such local bottlenecks at once collapses the ~1-per-distinct-rate-level
iteration count of the scalar loop into the depth of the bottleneck
dependency chain (~10 rounds for thousands of seeds), and every round is
whole-array numpy:

* per-flow bottleneck shares are one gather + running ``minimum`` over
  the hop axis;
* per-cell neighbourhood minima are one ``minimum.at`` scatter;
* the drain of frozen flows is two ``bincount``s over their cells.

Because max-min rates are unique, freezing any local bottleneck (rather
than the scalar code's global minimum) yields the same allocation; float
drift from the different freeze order is ~1e-15 relative, and the engine
is differentially tested against the scalar reference at 1e-9 on
randomized fabrics, workloads, and seeds (tests/test_vector_throughput.py).

Seeds are processed in blocks sized so the per-cell state (share,
residual, counts) stays cache-resident; cell ids are block-local, which
also keeps them safely within int32 for any realistic sweep.
"""

from __future__ import annotations

import dataclasses
from collections.abc import Sequence

import numpy as np

from .compile_fabric import CompiledFabric, compile_fabric
from .contracts import check_throughput, contracts_enabled
from .fabric import Fabric
from .flows import Flow, WorkloadDescription
from .vector_sim import (
    ENGINE_NUMPY, SimSpec, VectorTraceResult, _UNSET,
    _is_plain_ecmp, resolve_flows, resolve_spec,
    segment_reduce, simulate_paths,
)

# Seeds per cache block: per-cell state is ~5 arrays of seed_block * L
# float64, which stays L2-resident for typical fabrics (L ~ a few hundred).
DEFAULT_SEED_BLOCK = 48


def dedup_link_ids(link_ids: np.ndarray) -> np.ndarray:
    """Copy of an ``(H, N, S)`` link-id tensor with repeated links within
    one (flow, seed) path collapsed to a single entry (-1 elsewhere).

    The scalar engine keys link membership on *sets* of flow ids, so a
    flow crossing the same link twice is counted (and drained) once.
    Fabric-walked paths are loop-free, but synthetic tensors (and future
    multi-path schemes) may not be.

    Each hop row is compared against all earlier rows in ONE broadcast
    (``(ids[h] == ids[:h]).any(0)``) — quadratic in H but vectorized
    over the big (N, S) axes, which is what matters: H is capped by
    ``max_hops`` (16) while flowlet tensors grow N into the thousands.
    A value match against any earlier hop suffices (matching a -1 can
    only happen when ``ids[h]`` is itself -1, which the write guard
    excludes), so the old per-pair ``ids[g] >= 0`` masks are gone.  The
    prescribed sort-along-hop + shift-compare rewrite was measured and
    rejected: numpy's axis sorts cost 3-5x these compares at every
    realistic shape (still 1.5x slower at H=128, far past any walk).
    """
    ids = np.array(link_ids, copy=True)
    for h in range(1, ids.shape[0]):
        dup = (ids[h] == ids[:h]).any(axis=0)
        np.copyto(ids[h], -1, where=dup & (ids[h] >= 0))
    return ids


def _fill_block(sub: np.ndarray, sentinel: int, cap: np.ndarray,
                rates_out: np.ndarray, ws: dict) -> None:
    """Progressive-fill one seed block in place.

    ``sub``: (H, cols) int32 cell ids (cell = seed_in_block * L + link),
    ``sentinel`` past-the-end cell id for "no link at this hop",
    ``cap``: (cells,) float64 capacity per cell, ``rates_out``: (cols,)
    output view.  ``ws`` holds reusable scratch buffers.
    """
    H, NS = sub.shape
    SL = sentinel

    counts = ws["counts"][:SL + 1]         # sentinel slot absorbs the
    residual = ws["residual"][:SL + 1]     # no-link hops of short paths
    counts[:] = np.bincount(sub.ravel(), minlength=SL + 1)
    residual[:SL] = cap
    residual[SL] = 0.0
    share = np.full(SL + 1, np.inf)
    nz = counts[:SL] > 0
    share[:SL][nz] = residual[:SL][nz] / counts[:SL][nz]

    haslink = sub[0] < SL
    for h in range(1, H):
        haslink |= sub[h] < SL
    if haslink.all():
        aidx = None                       # common case: every flow routed
        A = NS
        first = sub                       # round 1 reads sub in place
    else:
        rates_out[~haslink] = np.inf      # fim.py's infinite-rate branch
        idx = np.flatnonzero(haslink).astype(np.int32)
        aidx = idx
        A = idx.size
        np.take(sub, idx, axis=1, out=ws["subw"][0][:, :A])
        first = None
    subw, sv, fzb, ek, wk, nbr = (ws["subw"], ws["sv"], ws["fzb"],
                                  ws["ek"], ws["wk"], ws["nbr"])
    freezable = ws["freezable"]
    freezable[SL] = False
    cur = 0
    while A:
        s = first if first is not None else subw[cur][:, :A]
        svv = sv[:, :A]
        for h in range(H):                 # per-flow bottleneck share
            np.take(share, s[h], out=svv[h])
        fm = svv[0]
        for h in range(1, H):
            np.minimum(fm, svv[h], out=fm)
        nbr_v = nbr[:SL + 1]               # per-cell min of member shares
        nbr_v.fill(np.inf)
        for h in range(H):
            np.minimum.at(nbr_v, s[h], fm)
        np.equal(nbr_v[:SL], share[:SL], out=freezable[:SL])
        fzv = fzb[:, :A]                   # flow crosses a local bottleneck
        for h in range(H):
            np.take(freezable, s[h], out=fzv[h])
        fz = fzv[0]
        for h in range(1, H):
            fz |= fzv[h]
        fidx = np.flatnonzero(fz)
        F = fidx.size
        w_f = fm[fidx]
        if aidx is None:
            rates_out[fidx] = w_f
        else:
            rates_out[aidx[fidx]] = w_f
        if F == A:                         # everything froze: no survivors
            break                          # to drain for
        ekv = ek[:H * F].reshape(H, F)     # drain the frozen flows
        np.take(s, fidx, axis=1, out=ekv)
        wkv = wk[:H * F].reshape(H, F)
        wkv[:] = w_f
        ekf = ek[:H * F]
        np.subtract.at(counts, ekf, 1.0)
        np.subtract.at(residual, ekf, wk[:H * F])
        # recompute shares at the touched cells; duplicate entries simply
        # rewrite the same value, so no dedup pass is needed
        c2 = counts[ekf]
        r2 = residual[ekf]
        share[ekf] = np.where(c2 > 0, r2 / np.maximum(c2, 1.0), np.inf)
        share[SL] = np.inf                 # sentinel must stay unroutable
        kidx = np.flatnonzero(~fz)         # compact to surviving flows
        A = kidx.size
        nxt = 1 - cur
        np.take(s, kidx, axis=1, out=subw[nxt][:, :A])
        if aidx is not None:
            aidx = aidx[kidx]
        else:
            aidx = kidx.astype(np.int32)
        first = None
        cur = nxt


def _fill_block_weighted(sub: np.ndarray, sentinel: int, cap: np.ndarray,
                         w: np.ndarray, rates_out: np.ndarray) -> None:
    """Weighted progressive-fill of one seed block (flowlet demand model).

    Same parallel local-bottleneck formulation as ``_fill_block``, with
    every flow (column) carrying a positive demand weight ``w``: a link's
    fair share is ``residual / sum of member weights`` (share *per unit
    demand*), a flow's rate is ``w * min share over its path``, and the
    max-min objective is over normalized rates — the standard weighted
    max-min fairness that makes K equal flowlets of one flow share
    exactly like the single parent flow when their paths coincide.

    Weighted link occupancy drifts by float epsilons as flows drain, so
    emptiness is tracked by an exact integer membership count alongside
    the weighted sum.  Kept separate from the unweighted path, which
    stays byte-identical to the PR-2 engine.
    """
    H, NS = sub.shape
    SL = sentinel
    mem = np.bincount(sub.ravel(), minlength=SL + 1).astype(np.float64)
    counts = np.bincount(sub.ravel(),
                         weights=np.broadcast_to(w, (H, NS)).ravel(),
                         minlength=SL + 1)
    residual = np.empty(SL + 1)
    residual[:SL] = cap
    residual[SL] = 0.0
    share = np.full(SL + 1, np.inf)
    nz = mem[:SL] > 0
    share[:SL][nz] = residual[:SL][nz] / counts[:SL][nz]

    haslink = (sub < SL).any(axis=0)
    rates_out[~haslink] = np.inf           # fim.py's infinite-rate branch
    aidx = np.flatnonzero(haslink)
    s = sub[:, aidx]
    wa = w[aidx]
    freezable = np.zeros(SL + 1, bool)
    while aidx.size:
        fm = share[s].min(axis=0)          # per-flow bottleneck share
        nbr = np.full(SL + 1, np.inf)      # per-cell min of member shares
        for h in range(H):
            np.minimum.at(nbr, s[h], fm)
        np.equal(nbr[:SL], share[:SL], out=freezable[:SL])
        fz = freezable[s].any(axis=0)      # flow crosses a local bottleneck
        fidx = np.flatnonzero(fz)
        fnorm = fm[fidx]
        rates_out[aidx[fidx]] = wa[fidx] * fnorm
        if fidx.size == aidx.size:         # everything froze: no survivors
            break                          # to drain for
        cells = s[:, fidx]                 # (H, F) drain the frozen flows
        flat = cells.ravel()
        np.subtract.at(mem, flat, 1.0)
        np.subtract.at(counts, flat,
                       np.broadcast_to(wa[fidx], cells.shape).ravel())
        np.subtract.at(residual, flat,
                       np.broadcast_to(wa[fidx] * fnorm, cells.shape).ravel())
        m2 = mem[flat]
        share[flat] = np.where(
            m2 > 0, residual[flat] / np.maximum(counts[flat], 1e-300), np.inf)
        share[SL] = np.inf                 # sentinel must stay unroutable
        keep = ~fz
        s = np.ascontiguousarray(s[:, keep])
        aidx = aidx[keep]
        wa = wa[keep]


def batched_max_min(
    link_ids: np.ndarray,
    link_gbps: np.ndarray,
    *,
    assume_unique: bool = False,
    seed_block: int = DEFAULT_SEED_BLOCK,
    weights: np.ndarray | None = None,
    engine: str = ENGINE_NUMPY,
) -> np.ndarray:
    """Max-min fair rates (Gb/s) for an ``(H, N, S)`` link-id tensor.

    ``engine="jax"`` runs the same parallel local-bottleneck fill as a
    jitted ``lax.while_loop`` on the accelerator
    (``jax_engine.jax_batched_max_min``; results agree to float-epsilon
    freeze-order drift, differential-tested at 1e-6).

    ``link_ids[h, n, s]`` is the id of the h-th link flow ``n`` crosses
    under seed ``s`` (-1 past the end of the path); ``link_gbps`` maps
    link id -> capacity.  Returns ``(N, S)`` rates; a flow crossing zero
    links gets ``inf`` exactly like the scalar reference.

    ``weights`` optionally gives every tensor column a positive demand
    weight (flowlets of a sprayed flow carry fractions of the parent's
    demand): the allocation becomes weighted max-min — fair share per
    unit demand — and a column's rate is its weight times its bottleneck
    share.  ``None`` (or all-ones) is the exact unweighted PR-2 engine.

    ``assume_unique`` skips the within-path duplicate-link collapse —
    safe for tensors from ``simulate_paths``, whose walked paths are
    loop-free by construction.  ``seed_block`` tunes the cache-residency
    granularity and never changes results.
    """
    if engine != ENGINE_NUMPY:
        from .jax_engine import jax_batched_max_min, resolve_engine
        resolve_engine(engine)
        return jax_batched_max_min(link_ids, link_gbps,
                                   assume_unique=assume_unique,
                                   weights=weights)
    link_ids = np.asarray(link_ids)
    if link_ids.ndim != 3:
        raise ValueError(f"link_ids must be (H, N, S), got {link_ids.shape}")
    if not assume_unique:
        link_ids = dedup_link_ids(link_ids)
    H, N, S = link_ids.shape
    if weights is not None:
        weights = np.asarray(weights, np.float64)
        if weights.shape != (N,):
            raise ValueError(
                f"weights must be ({N},) to match link_ids columns, "
                f"got {weights.shape}")
        if not (weights > 0).all():
            raise ValueError("weights must be strictly positive")
        if (weights == 1.0).all():
            weights = None                 # uniform: take the exact path
    L = len(link_gbps)
    cap = np.asarray(link_gbps, np.float64)
    rates = np.empty((S, N))
    if H == 0 or N == 0 or S == 0:
        rates[:] = np.inf if H == 0 else 0.0
        return rates.T
    # seed-major layout: all cells of one seed share one L-window of the
    # per-cell state, so gathers/scatters are cache-local
    ids_all = np.ascontiguousarray(link_ids.transpose(0, 2, 1))  # (H, S, N)

    Sb = max(1, min(seed_block, S))
    NSb, SLb = N * Sb, Sb * L
    offs = np.repeat(np.arange(Sb, dtype=np.int32) * np.int32(L), N)
    ws = {
        "subw": np.empty((2, H, NSb), np.int32),
        "sv": np.empty((H, NSb)),
        "fzb": np.empty((H, NSb), bool),
        "ek": np.empty(H * NSb, np.int32),
        "wk": np.empty(H * NSb),
        "nbr": np.empty(SLb + 1),
        "freezable": np.zeros(SLb + 1, bool),
        "residual": np.empty(SLb + 1),
        "counts": np.empty(SLb + 1),
        "sub": np.empty((H, NSb), np.int32),
        "cap": np.empty(SLb),
    } if weights is None else {
        "sub": np.empty((H, NSb), np.int32),
        "cap": np.empty(SLb),
    }
    for s0 in range(0, S, Sb):
        s1 = min(s0 + Sb, S)
        Sc = s1 - s0
        NS, SL = N * Sc, Sc * L
        blk = ids_all[:, s0:s1, :].reshape(H, NS)
        sub = ws["sub"][:, :NS]
        np.add(blk, offs[None, :NS], out=sub)
        sub[blk < 0] = SL
        capb = ws["cap"][:SL]
        capb[:] = np.broadcast_to(cap, (Sc, L)).ravel()
        if weights is None:
            _fill_block(sub, SL, capb, rates[s0:s1].reshape(-1), ws)
        else:
            _fill_block_weighted(sub, SL, capb, np.tile(weights, Sc),
                                 rates[s0:s1].reshape(-1))
    return rates.T                         # (N, S) transposed view


def max_min_rates(result: VectorTraceResult,
                  engine: str = ENGINE_NUMPY) -> np.ndarray:
    """``(Nf, S)`` max-min rates for every tensor column (flowlet) under
    every traced seed.  Single-path unit-demand results: one column per
    flow, the PR-2 behaviour exactly.  Otherwise every column carries
    its *effective* demand — the parent flow's ``flow_demand`` times the
    flowlet fraction (``column_weights``) — as its max-min weight, so a
    byte-weighted elephant claims share proportional to its volume; a
    plain ``result.demand`` here would silently revert every flow to
    unit demand.  Aggregate per parent flow with
    ``flow_rates_from_flowlets``."""
    w = result.column_weights()
    if (w == 1.0).all():
        w = None
    return batched_max_min(result.link_ids, result.compiled.link_gbps,
                           assume_unique=True, weights=w, engine=engine)


def flow_rates_from_flowlets(result: VectorTraceResult,
                             flowlet_rates: np.ndarray) -> np.ndarray:
    """Aggregate ``(Nf, S)`` flowlet rates into ``(N, S)`` per-flow rates
    by summing columns of the same parent (``result.flow_index``) — the
    same segment reduction (``vector_sim.segment_reduce``) the exposure
    model runs, so the two can never disagree on the grouping."""
    fi = result.flow_index
    if not result.is_multipath and (
            fi == np.arange(len(fi), dtype=np.int64)).all():
        return flowlet_rates
    return np.ascontiguousarray(
        segment_reduce(flowlet_rates, fi, result.num_flows, np.add, 0.0),
        dtype=np.float64)


@dataclasses.dataclass
class DepartureFill:
    """Result of a departure-ordered max-min drain (``departure_fill``).

    ``completion[n, s]`` is the absolute time (seconds) at which tensor
    column ``n``'s bytes finish under seed ``s``; ``duration[s]`` is the
    completion time of the slowest column — the step's derived duration;
    ``rounds`` counts the re-fill rounds the drain needed (one per
    distinct departure epoch, bounded by the column count).
    """

    completion: np.ndarray               # (Nf, S) seconds per column
    duration: np.ndarray                 # (S,) slowest-column completion
    rounds: int


def departure_fill(
    link_ids: np.ndarray,
    link_gbps: np.ndarray,
    col_gbits: np.ndarray,
    *,
    weights: np.ndarray | None = None,
    efficiency: np.ndarray | None = None,
    assume_unique: bool = False,
    seed_block: int = DEFAULT_SEED_BLOCK,
    initial_rates: np.ndarray | None = None,
    engine: str = ENGINE_NUMPY,
) -> DepartureFill:
    """Water-filling with departures over an ``(H, N, S)`` link-id tensor.

    Every column ``n`` carries ``col_gbits[n]`` gigabits.  All columns
    start draining at their max-min rate (``batched_max_min``, weighted
    by ``weights`` exactly like ``max_min_rates``); the earliest-finishing
    cells *depart* — their remaining bytes hit zero — and the survivors'
    rates are re-filled over the **same** path tensor with the departed
    (column, seed) cells deactivated, so tail flows speed up as elephants
    drain.  No re-walk happens: deactivating a cell is writing ``-1``
    over its link ids, which the fill already treats as "crosses no
    links" per (column, seed) cell.  Seeds progress independently (each
    has its own departure order); the fill itself stays batched across
    the surviving seed-set every round, and fully-drained columns are
    compacted out of the tensor between rounds.

    ``efficiency`` optionally scales each cell's drain rate (goodput =
    rate x efficiency, the transport reordering model); it is held fixed
    across re-fills — the exposure a routing assignment induces is a
    property of the committed paths, not of who has already left the
    wire.  ``initial_rates`` lets callers that already ran the full-set
    fill (``throughput_from_result``) reuse it as round 1; it is only
    trusted when every column starts active, otherwise it is recomputed.

    Zero-gigabit columns complete at t=0 and never contend; columns that
    cross no links drain at infinite rate and also complete at t=0.
    Times are seconds for ``col_gbits`` in gigabits and ``link_gbps`` in
    Gb/s (``bytes * 8e-9`` converts).

    ``engine="jax"`` delegates the drain to this host loop (after
    validating the engine name): every departure epoch re-fills a
    *shrunken* column set, which under jit would re-trace per shape —
    and the numpy compacting fill already dominates the jax fill ~17x on
    CPU (PR 7 measurement, see ROADMAP) before paying any of that.  The
    walk that produced ``link_ids`` may of course come from either
    engine; the drain is bit-identical downstream of it.
    """
    if engine != ENGINE_NUMPY:
        from .jax_engine import resolve_engine
        resolve_engine(engine)
    link_ids = np.asarray(link_ids)
    if link_ids.ndim != 3:
        raise ValueError(f"link_ids must be (H, N, S), got {link_ids.shape}")
    if not assume_unique:
        link_ids = dedup_link_ids(link_ids)
    H, N, S = link_ids.shape
    gb = np.asarray(col_gbits, np.float64)
    if gb.shape != (N,):
        raise ValueError(
            f"col_gbits must be ({N},) to match link_ids columns, "
            f"got {gb.shape}")
    if (gb < 0).any() or not np.isfinite(gb).all():
        raise ValueError("col_gbits must be finite and >= 0")
    if efficiency is None:
        eff = np.ones((N, S))
    else:
        eff = np.asarray(efficiency, np.float64)
        if eff.shape != (N, S):
            raise ValueError(
                f"efficiency must be ({N}, {S}), got {eff.shape}")
        if not ((eff > 0) & np.isfinite(eff)).all():
            raise ValueError("efficiency must be finite and > 0")
    completion = np.zeros((N, S))
    if N == 0 or S == 0 or H == 0:
        return DepartureFill(completion=completion,
                             duration=completion.max(axis=0, initial=0.0),
                             rounds=0)
    t = np.zeros(S)
    rem = np.broadcast_to(gb[:, None], (N, S)).copy()
    active = rem > 0.0
    ids = link_ids.copy()
    ids[:, ~active] = -1                   # zero-gigabit cells never contend
    rounds = 0
    while True:
        alive = active.any(axis=1)         # column compaction
        if not alive.any():
            break
        rounds += 1
        if rounds > N + 1:                 # >= 1 cell departs per round per
            raise RuntimeError(            # active seed, so N+1 is unreachable
                "departure_fill failed to converge (rate degeneracy?)")
        sel = np.flatnonzero(alive)
        sub_ids = ids[:, sel]
        if rounds == 1 and initial_rates is not None and alive.all():
            rates = np.asarray(initial_rates, np.float64)
            if rates.shape != (N, S):
                raise ValueError(
                    f"initial_rates must be ({N}, {S}), got {rates.shape}")
        else:
            rates = batched_max_min(
                sub_ids, link_gbps, assume_unique=True,
                seed_block=seed_block,
                weights=None if weights is None else
                np.asarray(weights, np.float64)[sel])
        act = active[sel]
        good = rates * eff[sel]
        with np.errstate(divide="ignore", invalid="ignore"):
            fin = np.where(act, rem[sel] / good, np.inf)
        fin = np.where(np.isnan(fin), np.inf, fin)
        dt = fin.min(axis=0)               # (S,) next departure horizon
        seed_active = act.any(axis=0)
        if (seed_active & ~np.isfinite(dt)).any():
            raise RuntimeError(
                "departure_fill: active flow with zero goodput can never "
                "finish (zero-capacity bottleneck link?)")
        dt0 = np.where(seed_active, dt, 0.0)
        # everything within float tolerance of the horizon departs together
        depart = act & (fin <= dt[None, :] * (1.0 + 1e-12))
        comp_sel = completion[sel]
        comp_sel[depart] = (t[None, :] + fin)[depart]
        completion[sel] = comp_sel
        drain = np.where(act & np.isfinite(good), good, 0.0) * dt0[None, :]
        rem_sel = np.maximum(rem[sel] - drain, 0.0)
        rem_sel[depart] = 0.0
        rem[sel] = rem_sel
        t += dt0
        active[sel] = act & ~depart
        sub_ids[:, depart] = -1            # departed cells leave the wire
        ids[:, sel] = sub_ids
    return DepartureFill(completion=completion,
                         duration=completion.max(axis=0, initial=0.0),
                         rounds=rounds)


@dataclasses.dataclass
class MonteCarloThroughput:
    """Per-flow and per-pair max-min rate distributions over a seed sweep.

    ``rates`` is the raw max-min allocation (what the fabric *delivers*);
    ``goodput`` is what the transport can *use* after paying the flowlet
    reordering cost — ``rates x efficiency`` under the ``transport``
    profile (core/reordering.py).  Under the default ``"ideal"``
    transport (and for any single-path strategy, whose exposure is zero)
    ``goodput`` is bit-identical to ``rates``.
    """

    seeds: np.ndarray                    # (S,)
    flows: list[Flow]
    rates: np.ndarray                    # (N, S) Gb/s per flow per seed
    pairs: list[tuple[str, str]]         # (src, dst) in first-seen order
    per_pair: np.ndarray                 # (P, S) Gb/s per pair per seed
    transport: str = "ideal"             # reordering profile name
    exposure: np.ndarray | None = None   # (N, S) out-of-order exposure
    efficiency: np.ndarray | None = None  # (N, S) goodput multiplier
    goodput: np.ndarray | None = None    # (N, S) effective Gb/s per flow

    def __post_init__(self):
        if self.exposure is None:
            self.exposure = np.zeros_like(self.rates)
        if self.efficiency is None:
            self.efficiency = np.ones_like(self.rates)
        if self.goodput is None:
            # a copy, not an alias: in-place edits of one must never
            # leak into the other
            self.goodput = self.rates.copy()

    @property
    def num_seeds(self) -> int:
        return len(self.seeds)

    def pair_throughput_for_seed(
        self, seed_index: int
    ) -> dict[tuple[str, str], float]:
        """One seed's pair throughputs in ``per_pair_throughput`` format."""
        return {p: float(self.per_pair[i, seed_index])
                for i, p in enumerate(self.pairs)}

    def summary(self) -> dict[str, dict[str, float]]:
        rows = {
            "flow_rate": self.rates,
            "flow_goodput": self.goodput,
            "pair_total": self.per_pair,
            "pair_min": self.per_pair.min(axis=0),
            "pair_median": np.median(self.per_pair, axis=0),
        }
        out = {}
        for name, v in rows.items():
            v = np.asarray(v, np.float64).ravel()
            out[name] = {
                "mean": float(v.mean()),
                "std": float(v.std()),
                "min": float(v.min()),
                "p50": float(np.percentile(v, 50)),
                "p95": float(np.percentile(v, 95)),
                "max": float(v.max()),
            }
        return out


def pair_rate_matrix(
    flows: Sequence[Flow], rates: np.ndarray
) -> tuple[list[tuple[str, str]], np.ndarray]:
    """Aggregate ``(N, S)`` flow rates into ``(P, S)`` per-pair totals.

    Pairs are ordered by first appearance in ``flows``, matching the dict
    insertion order of the scalar ``per_pair_throughput``.
    """
    pair_index: dict[tuple[str, str], int] = {}
    idx = np.empty(len(flows), np.int64)
    for j, f in enumerate(flows):
        idx[j] = pair_index.setdefault((f.src, f.dst), len(pair_index))
    if len(flows) and (np.diff(idx) >= 0).all():
        # flows grouped by pair (synthesize_flows order): segment-sum
        starts = np.flatnonzero(np.diff(idx, prepend=-1) > 0)
        per_pair = np.add.reduceat(rates, starts, axis=0)
        per_pair = np.ascontiguousarray(per_pair, dtype=np.float64)
    else:
        per_pair = np.zeros((len(pair_index), rates.shape[1]))
        np.add.at(per_pair, idx, rates)
    return list(pair_index), per_pair


def throughput_from_result(
    result: VectorTraceResult,
    *,
    transport=None,
    flowlet_rates: np.ndarray | None = None,
    engine: str = ENGINE_NUMPY,
) -> MonteCarloThroughput:
    """Rate distributions for an already-simulated ``VectorTraceResult``
    (lets callers share one ``simulate_paths`` pass between FIM and
    throughput, as ``benchmarks/fig3a_routing_comparison.py`` does).

    Multi-path results run the weighted fill over flowlet columns and
    aggregate rates per parent flow, so ``rates`` is always ``(N, S)``
    over ``result.flows`` regardless of strategy.

    ``transport`` selects the reordering cost model (a
    ``TransportProfile``, a registered name like ``"roce-nack"`` /
    ``"strack"``, or ``None`` for the free ``"ideal"`` model): flowlet
    out-of-order exposure is computed from the same fill
    (``flowlet_exposure`` reuses the per-flowlet rates, and folds in any
    strategy-charged ``VectorTraceResult.extra_exposure`` — adaptive
    re-spray bills its mid-flow path changes there) and
    ``goodput = rates x efficiency``.  Zero-exposure flows — every flow
    of a single-path strategy, and every unsprayed flow of demand-aware
    spraying — keep ``goodput`` bit-identical to ``rates``.  A profile
    with ``alpha == 0`` or ``floor == 1`` makes every flow's efficiency
    1 regardless of exposure, so the exposure pass is skipped outright
    (``.exposure`` reads 0 — the pre-reordering behaviour at the
    pre-reordering cost); request a lossy profile to get exposure
    diagnostics.

    ``flowlet_rates`` optionally supplies a precomputed
    ``max_min_rates(result)`` tensor so callers evaluating the same
    routed result under several transports run the progressive fill —
    the dominant cost — once.

    ``engine="jax"`` runs the fill and the exposure segment reductions
    on the device engine (``jax_engine``); the pair aggregation and the
    efficiency map are output-sized and stay host-side."""
    from .reordering import (
        flowlet_exposure, reordering_efficiency, resolve_transport,
    )
    profile = resolve_transport(transport)
    if flowlet_rates is None:
        flowlet_rates = max_min_rates(result, engine=engine)
    rates = flow_rates_from_flowlets(result, flowlet_rates)
    pairs, per_pair = pair_rate_matrix(result.flows, rates)
    if profile.alpha == 0.0 or profile.floor == 1.0:
        tp = MonteCarloThroughput(seeds=result.seeds, flows=result.flows,
                                  rates=rates, pairs=pairs,
                                  per_pair=per_pair,
                                  transport=profile.name)
    else:
        exposure = flowlet_exposure(result, flowlet_rates, engine=engine)
        efficiency = reordering_efficiency(exposure, profile)
        tp = MonteCarloThroughput(seeds=result.seeds, flows=result.flows,
                                  rates=rates, pairs=pairs,
                                  per_pair=per_pair,
                                  transport=profile.name, exposure=exposure,
                                  efficiency=efficiency,
                                  goodput=rates * efficiency)
    if contracts_enabled():
        check_throughput(tp)
    return tp


def monte_carlo_throughput(
    fabric: Fabric | CompiledFabric,
    workload: WorkloadDescription | Sequence[Flow],
    seeds: Sequence[int] | np.ndarray,
    *,
    spec: SimSpec | None = None,
    fields=_UNSET,
    hash_backend=_UNSET,
    field_matrix: np.ndarray | None = None,
    strategy=_UNSET,
    demand_mode=_UNSET,
    transport=_UNSET,
    engine=_UNSET,
    max_hops=_UNSET,
) -> MonteCarloThroughput:
    """Max-min throughput distribution of a routing strategy across a
    seed sweep.

    ``workload`` may be a ``WorkloadDescription`` (flows synthesized the
    standard way, NIC count inferred from the fabric) or an explicit flow
    list — the same front-end contract as ``monte_carlo_fim``.  How to
    simulate comes from a ``SimSpec`` — pass one as ``spec=`` or the
    legacy kwargs, not both.  ``strategy`` and ``demand_mode`` follow
    the ``simulate_paths`` contract (default: per-flow ECMP, unit
    demand; ``demand_mode="bytes"`` allocates weighted max-min shares);
    ``transport`` the ``throughput_from_result`` contract (reordering
    cost model for ``goodput``; default ``"ideal"`` = reordering-free).

    ``engine="jax"`` with plain ECMP takes the fused device pipeline
    (walk + fill in one device-resident pass, ``jax_engine``); other
    strategies route on the jax walk and fill/expose on device with
    host glue in between.
    """
    s = resolve_spec(spec, dict(
        fields=fields, hash_backend=hash_backend, strategy=strategy,
        demand_mode=demand_mode, transport=transport, engine=engine,
        max_hops=max_hops))
    comp = fabric if isinstance(fabric, CompiledFabric) else compile_fabric(fabric)
    if s.engine != ENGINE_NUMPY and _is_plain_ecmp(s.strategy):
        from .jax_engine import fused_monte_carlo_throughput, resolve_engine
        resolve_engine(s.engine)
        return fused_monte_carlo_throughput(
            comp, workload, seeds, fields=s.fields,
            hash_backend=s.hash_backend,
            demand_mode=s.demand_mode, transport=s.transport,
            field_matrix=field_matrix, max_hops=s.max_hops)
    flows = resolve_flows(comp, workload)
    res = simulate_paths(comp, flows, seeds, spec=s,
                         field_matrix=field_matrix)
    return throughput_from_result(res, transport=s.transport, engine=s.engine)
