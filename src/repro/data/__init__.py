from .pipeline import SyntheticDataset, ByteDataset
