"""Data pipeline: deterministic synthetic token streams (for benchmarks,
dry-runs and smoke tests) and a byte-level text corpus reader (for the
end-to-end ~100M example).

Both are *step-indexed*: ``batch(step)`` is a pure function of (seed,
step), so a restarted job resumes with exactly the data it would have
seen — the property checkpoint/restart tests rely on, and what a
production loader must guarantee for reproducible multi-pod training
(each host slices its own shard of the global batch).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticDataset:
    """Markov-ish synthetic tokens with local structure (so loss can fall)."""

    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def _zipf(self, rng, size):
        # skewed unigram distribution (learnable in tens of steps) with
        # local 8-fold repetition (learnable copy structure)
        u = rng.random(size)
        return (self.vocab * u**3).astype(np.int32) % self.vocab

    def batch(self, step: int, *, host_index: int = 0, num_hosts: int = 1) -> dict:
        b = self.global_batch // num_hosts
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, host_index]))
        base = self._zipf(rng, (b, self.seq_len // 8 + 2))
        toks = np.repeat(base, 8, axis=1)[:, : self.seq_len + 1]
        noise = self._zipf(rng, toks.shape)
        mask = rng.random(toks.shape) < 0.1
        toks = np.where(mask, noise, toks).astype(np.int32)
        return {"tokens": toks[:, :-1].copy(), "labels": toks[:, 1:].copy()}


@dataclasses.dataclass(frozen=True)
class ByteDataset:
    """Byte-level LM corpus from a file; vocab = 256 + 1 pad."""

    path: str
    seq_len: int
    global_batch: int
    seed: int = 0

    def __post_init__(self):
        data = np.fromfile(self.path, dtype=np.uint8)
        object.__setattr__(self, "_data", data)

    @property
    def vocab(self) -> int:
        return 257

    def batch(self, step: int, *, host_index: int = 0, num_hosts: int = 1) -> dict:
        b = self.global_batch // num_hosts
        data = self._data
        n = len(data) - self.seq_len - 1
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, host_index]))
        starts = rng.integers(0, max(n, 1), size=b)
        toks = np.stack([
            data[s : s + self.seq_len + 1].astype(np.int32) for s in starts
        ])
        return {"tokens": toks[:, :-1].copy(), "labels": toks[:, 1:].copy()}
