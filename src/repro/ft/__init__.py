from .elastic import ElasticPlan, plan_elastic_mesh, HostFailure, run_with_restarts
from .straggler import StragglerDetector, StragglerReport
