"""Elastic scaling + failure handling.

On a real pod, a host failure surfaces as missing devices at restart (or
a collective timeout mid-run).  The recovery path implemented here:

  1. ``plan_elastic_mesh``: from the surviving device count, choose the
     largest usable (data, model) grid compatible with the model's TP
     requirement, and the new per-host batch slice (global batch is
     preserved by increasing per-device batch or grad-accum).
  2. restore the latest checkpoint (host-side numpy, mesh-agnostic) with
     the new shardings;
  3. resume from the step recorded in the checkpoint — the step-indexed
     data pipeline replays the exact stream.

``run_with_restarts`` wires this into a training loop and is exercised by
tests/test_ft.py with injected failures.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: tuple[int, ...]
    axis_names: tuple[str, ...]
    grad_accum_multiplier: int      # to preserve global batch
    dropped_devices: int


def plan_elastic_mesh(
    num_devices: int,
    *,
    model_parallel: int,
    prefer_data: int | None = None,
    axis_names: tuple[str, str] = ("data", "model"),
) -> ElasticPlan:
    """Largest (data, model) grid from surviving devices.

    model_parallel is fixed by the weight shardings (TP degree must match
    the checkpoint layout for cheap restarts); the data axis absorbs the
    loss.  Any remainder devices idle until the next maintenance window —
    the standard trade on real pods.
    """
    if num_devices < model_parallel:
        raise ValueError(
            f"{num_devices} devices cannot host model_parallel={model_parallel}")
    data = num_devices // model_parallel
    if prefer_data:
        data = min(data, prefer_data)
    used = data * model_parallel
    # preserve global batch: if data axis shrank by k, accumulate k more
    mult = 1
    if prefer_data and data < prefer_data:
        mult = math.ceil(prefer_data / data)
    return ElasticPlan(
        mesh_shape=(data, model_parallel),
        axis_names=axis_names,
        grad_accum_multiplier=mult,
        dropped_devices=num_devices - used,
    )


class HostFailure(RuntimeError):
    """Simulated/detected loss of a host (collective timeout, ICI error)."""


def run_with_restarts(
    train_loop: Callable[[int], int],
    *,
    max_restarts: int = 3,
    on_restart: Callable[[int], None] | None = None,
) -> int:
    """Run ``train_loop(start_step) -> final_step``; on HostFailure,
    invoke ``on_restart`` (re-mesh + restore) and continue."""
    restarts = 0
    step = 0
    while True:
        try:
            return train_loop(step)
        except HostFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
            if on_restart is not None:
                on_restart(restarts)
            # train_loop re-reads the checkpoint to find its resume step
            step = -1
