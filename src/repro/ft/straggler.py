"""Straggler detection & mitigation.

On multi-pod runs a slow host (thermal throttle, failing NIC, ECMP
collision victim — exactly what FlowTracer diagnoses) drags every
synchronous step.  This module provides the detection half and the
mitigation hooks:

  * ``StragglerDetector``: per-host EWMA of step durations; a host whose
    EWMA exceeds ``threshold`` x the fleet median is flagged.
  * mitigation hooks: (a) report the flagged host + its traffic to
    FlowTracer for path analysis (is it an ECMP collision? -> repath);
    (b) advise dropping the host (elastic re-mesh); (c) advise
    microbatch rebalancing (shrink the slow host's shard).

The detector is pure logic (unit-tested with synthetic timings); the
launcher wires it to real step timings.
"""

from __future__ import annotations

import dataclasses
import statistics
from collections import defaultdict


@dataclasses.dataclass
class StragglerReport:
    host: str
    ewma_s: float
    median_s: float
    ratio: float
    advice: str


class StragglerDetector:
    def __init__(self, *, alpha: float = 0.3, threshold: float = 1.5,
                 min_samples: int = 3):
        self.alpha = alpha
        self.threshold = threshold
        self.min_samples = min_samples
        self._ewma: dict[str, float] = {}
        self._count: dict[str, int] = defaultdict(int)

    def record(self, host: str, step_seconds: float) -> None:
        prev = self._ewma.get(host)
        self._ewma[host] = (step_seconds if prev is None
                            else self.alpha * step_seconds + (1 - self.alpha) * prev)
        self._count[host] += 1

    def check(self) -> list[StragglerReport]:
        ready = {h: v for h, v in self._ewma.items()
                 if self._count[h] >= self.min_samples}
        if len(ready) < 2:
            return []
        med = statistics.median(ready.values())
        out = []
        for host, ewma in sorted(ready.items()):
            ratio = ewma / max(med, 1e-9)
            if ratio >= self.threshold:
                advice = ("trace-paths" if ratio < 2.0 else
                          "rebalance" if ratio < 3.0 else "evict")
                out.append(StragglerReport(host, ewma, med, ratio, advice))
        return out
