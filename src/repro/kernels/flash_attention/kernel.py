"""Pallas TPU flash-attention forward kernel.

Canonical TPU pattern: grid (batch*heads, num_q_blocks, num_k_blocks) with
("parallel", "parallel", "arbitrary") semantics; the k axis is the inner
sequential loop.  Running (max, sumexp, acc) live in VMEM scratch across k
steps; the output tile is written on the last k step.  Block shapes are
MXU-aligned (block_q x head_dim and block_k x head_dim tiles, head_dim
padded to >= 128 by the wrapper when needed).

Causal masking skips fully-masked k blocks via pl.when on the block
index, so the kernel does ~S^2/2 work like the XLA twin
(models/attention.chunked_attention, which is also the test oracle).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                      causal: bool, block_q: int, block_k: int,
                      num_k_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _body():
        q = q_ref[0]                                   # (block_q, hd)
        k = k_ref[0]                                   # (block_k, hd)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)        # (block_q, block_k)
        s *= 1.0 / math.sqrt(q.shape[-1])
        if causal:
            q_pos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 0)
            k_pos = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        scale = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * scale + p.sum(axis=-1, keepdims=True)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * scale + pv

    if causal:
        # skip blocks strictly above the diagonal
        pl.when(ki * block_k <= qi * block_q + block_q - 1)(_body)
    else:
        _body()

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)) \
            .astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, block_q: int = 128, block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """q, k, v: (BH, S, hd) with matching S.  Returns (BH, S, hd)."""
    BH, S, hd = q.shape
    assert k.shape == (BH, S, hd) and v.shape == (BH, S, hd)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    nq, nk = S // block_q, S // block_k

    kernel = functools.partial(
        _flash_fwd_kernel, causal=causal, block_q=block_q, block_k=block_k,
        num_k_blocks=nk)
    return pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            # running max / sumexp (block_q, 1) and f32 accumulator in VMEM
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ) if not interpret else None,
        interpret=interpret,
    )(q, k, v)
