"""Jitted public wrapper for flash attention.

On TPU this dispatches to the Pallas kernel; elsewhere (this CPU
container) it falls back to the XLA reference so models remain runnable
everywhere.  Tests call the kernel explicitly with interpret=True.
"""

from __future__ import annotations

import functools

import jax

from .kernel import flash_attention_fwd
from .ref import attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "force_kernel", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, force_kernel: bool = False,
                    interpret: bool = False):
    """q, k, v: (BH, S, hd) -> (BH, S, hd)."""
    if force_kernel or _on_tpu():
        return flash_attention_fwd(
            q, k, v, causal=causal, block_q=block_q, block_k=block_k,
            interpret=interpret or not _on_tpu())
    return attention_ref(q, k, v, causal=causal)
