"""Pallas TPU kernel for bulk ECMP hashing — the paper's hot loop made
massively parallel.

FlowTracer's fabric simulator must evaluate per-switch hash decisions for
every flow; at datacenter scale (millions of flows x 4 hash decisions)
the Python loop is the bottleneck the paper's Fig. 4 measures.  On TPU
the whole flow table hashes in one VMEM-tiled elementwise pass: a
murmur3-style 32-bit avalanche folded over the 5-tuple columns.  All ops
are uint32 multiplies/xors/shifts — VPU-native, no MXU involvement.

The hash differs from core/ecmp.py's host-side splitmix64 (64-bit int
multiplies are not TPU-friendly); both are uniform avalanche hashes, and
FIM statistics are hash-agnostic (benchmarks/fig3a shows both).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# numpy scalars inline as HLO literals (jnp scalars would be captured
# consts, which pallas kernels reject)
_C1 = np.uint32(0xCC9E2D51)
_C2 = np.uint32(0x1B873593)
_F1 = np.uint32(0x85EBCA6B)
_F2 = np.uint32(0xC2B2AE35)


def _rotl(x, r):
    return (x << np.uint32(r)) | (x >> np.uint32(32 - r))


def murmur_fold(h, k):
    k = k * _C1
    k = _rotl(k, 15)
    k = k * _C2
    h = h ^ k
    h = _rotl(h, 13)
    return h * np.uint32(5) + np.uint32(0xE6546B64)


def murmur_fmix(h):
    h = h ^ (h >> np.uint32(16))
    h = h * _F1
    h = h ^ (h >> np.uint32(13))
    h = h * _F2
    return h ^ (h >> np.uint32(16))


def _hash_kernel(fields_ref, seed_ref, out_ref, *, n_fields: int):
    seed = seed_ref[0, 0]
    h = jnp.full(out_ref.shape, seed, jnp.uint32)
    for f in range(n_fields):
        h = murmur_fold(h, fields_ref[:, f : f + 1])
    out_ref[...] = murmur_fmix(h)


def _hash_kernel_seeded(fields_ref, seeds_ref, out_ref, *, n_fields: int):
    h = seeds_ref[...]                    # (block, 1) per-row hash init
    for f in range(n_fields):
        h = murmur_fold(h, fields_ref[:, f : f + 1])
    out_ref[...] = murmur_fmix(h)


def bulk_hash_kernel(fields: jax.Array, seed: jax.Array, *,
                     block: int = 4096, interpret: bool = False) -> jax.Array:
    """fields: (N, F) uint32; seed: () uint32 -> (N, 1) uint32 hashes.
    N must be a multiple of ``block`` (ops.py pads)."""
    N, F = fields.shape
    assert N % block == 0, (N, block)
    kernel = functools.partial(_hash_kernel, n_fields=F)
    return pl.pallas_call(
        kernel,
        grid=(N // block,),
        in_specs=[
            pl.BlockSpec((block, F), lambda i: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((block, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, 1), jnp.uint32),
        interpret=interpret,
    )(fields, seed.reshape(1, 1))


def bulk_hash_seeded_kernel(fields: jax.Array, seeds: jax.Array, *,
                            block: int = 4096,
                            interpret: bool = False) -> jax.Array:
    """fields: (N, F) uint32; seeds: (N, 1) uint32 per-row hash init ->
    (N, 1) uint32 hashes — the seed-as-init murmur convention shared with
    the engines' hash grids.  A broadcast ``seeds`` row reproduces
    ``bulk_hash_kernel`` exactly (same fold/fmix chain, the scalar SMEM
    seed is just the degenerate per-row case)."""
    N, F = fields.shape
    assert N % block == 0, (N, block)
    kernel = functools.partial(_hash_kernel_seeded, n_fields=F)
    return pl.pallas_call(
        kernel,
        grid=(N // block,),
        in_specs=[
            pl.BlockSpec((block, F), lambda i: (i, 0)),
            pl.BlockSpec((block, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, 1), jnp.uint32),
        interpret=interpret,
    )(fields, seeds)
