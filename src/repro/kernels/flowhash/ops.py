"""Bulk flow hashing + vectorized paper-testbed path simulation.

``simulate_paper_paths`` evaluates the four cross-rack ECMP decisions of
the paper's 2-rack fabric for N flows at once (source LAG, leaf uplink,
spine downlink, destination LAG) and returns per-stage link indices —
enough to compute link loads / FIM for millions of flows in one shot.
This is FlowTracer-at-scale: same decisions the hop-by-hop tracer makes,
evaluated as four fused hash passes instead of per-flow SSH queries.

``simulate_paper_paths`` is hard-wired to the 4-stage paper testbed; for
arbitrary fabrics (and bit-identical parity with ``EcmpRouting``) use
``repro.core.vector_sim`` / ``repro.core.jax_engine``, whose
``hash_backend="murmur"`` evaluates the SAME hash as ``bulk_hash`` here:
one murmur definition — seed-as-init, fold the field columns, fmix
(``kernel.murmur_fold``/``murmur_fmix``) — shared by the Pallas kernel,
the jnp oracle, the numpy engine grid, and the jitted device grid.
``tests/test_kernels.py`` pins the per-stage choice distribution so the
unification can never drift the paper-testbed statistics.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import bulk_hash_kernel, bulk_hash_seeded_kernel
from .ref import bulk_hash_ref, bulk_hash_seeded_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def bulk_hash(fields, seed, *, force_kernel: bool = False,
              interpret: bool = False, block: int = 4096):
    """fields: (N, F) uint32 -> (N,) uint32.  seed: any int (wrapped u32).

    The seed-as-init murmur convention: the hash starts at ``seed`` and
    folds the field columns — the same definition the engines' murmur
    grids (``vector_sim._murmur_hash_grid``, ``jax_engine``) evaluate
    per (flow, seed) cell, and ``bulk_hash_seeded`` evaluates per row.
    """
    seed = np.uint32(int(seed) & 0xFFFFFFFF)
    return _bulk_hash_impl(fields, seed, force_kernel=force_kernel,
                           interpret=interpret, block=block)


@functools.partial(jax.jit, static_argnames=("force_kernel", "interpret", "block"))
def _bulk_hash_impl(fields, seed, *, force_kernel: bool = False,
                    interpret: bool = False, block: int = 4096):
    N, F = fields.shape
    pad = (-N) % block
    if pad:
        fields = jnp.pad(fields, ((0, pad), (0, 0)))
    if force_kernel or _on_tpu():
        out = bulk_hash_kernel(fields, jnp.uint32(seed),
                               block=block, interpret=interpret or not _on_tpu())
    else:
        out = bulk_hash_ref(fields, jnp.uint32(seed))
    return out[:N, 0]


def bulk_hash_seeded(fields, seeds, *, force_kernel: bool = False,
                     interpret: bool = False, block: int = 4096):
    """fields: (N, F) uint32, seeds: (N,) uint32 per-row hash init ->
    (N,) uint32.  The per-row-seed twin of ``bulk_hash`` (same fold/fmix
    chain); ``bulk_hash(fields, s) == bulk_hash_seeded(fields, full(N, s))``
    bit-for-bit, which is what pins all murmur consumers to one
    definition."""
    return _bulk_hash_seeded_impl(
        fields, seeds, force_kernel=force_kernel, interpret=interpret,
        block=block)


@functools.partial(jax.jit, static_argnames=("force_kernel", "interpret", "block"))
def _bulk_hash_seeded_impl(fields, seeds, *, force_kernel: bool = False,
                           interpret: bool = False, block: int = 4096):
    N, F = fields.shape
    pad = (-N) % block
    if pad:
        fields = jnp.pad(fields, ((0, pad), (0, 0)))
        seeds = jnp.pad(seeds, ((0, pad),))
    seeds = seeds.astype(jnp.uint32).reshape(-1, 1)
    if force_kernel or _on_tpu():
        out = bulk_hash_seeded_kernel(
            fields, seeds, block=block, interpret=interpret or not _on_tpu())
    else:
        out = bulk_hash_seeded_ref(fields, seeds)
    return out[:N, 0]


def bulk_ecmp_choice(fields, seed, n_choices: int, **kw):
    return (bulk_hash(fields, seed, **kw) % jnp.uint32(n_choices)).astype(jnp.int32)


def simulate_paper_paths(
    fields: jax.Array,            # (N, 5) uint32 flow 5-tuples
    *,
    num_spines: int = 4,
    links_per_leaf_spine: int = 4,
    ports_per_lag: int = 2,
    seeds: tuple[int, int, int, int] = (101, 202, 303, 404),
    **kw,
) -> dict[str, jax.Array]:
    """Four-stage ECMP decision vector for every flow (paper Fig. 2).

    Returns int32 arrays: src_port (LAG), uplink (leaf->spine link index
    in [0, spines*links)), spine_link (spine->dst-leaf link in [0, links)),
    dst_port (LAG).  Stage seeds model per-switch hash seeds.
    """
    return {
        "src_port": bulk_ecmp_choice(fields, seeds[0], ports_per_lag, **kw),
        "uplink": bulk_ecmp_choice(fields, seeds[1],
                                   num_spines * links_per_leaf_spine, **kw),
        "spine_link": bulk_ecmp_choice(fields, seeds[2],
                                       links_per_leaf_spine, **kw),
        "dst_port": bulk_ecmp_choice(fields, seeds[3], ports_per_lag, **kw),
    }


def link_loads_fim(choices: jax.Array, n_links: int) -> tuple[np.ndarray, float]:
    """Per-link flow counts + FIM (eq. 1) from a choice vector."""
    counts = np.bincount(np.asarray(choices), minlength=n_links)
    ideal = counts.sum() / n_links
    fim = 100.0 / n_links * float(np.abs(counts - ideal).sum() / ideal) \
        if ideal > 0 else 0.0
    return counts, fim
