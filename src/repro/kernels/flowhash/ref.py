"""Pure-jnp oracle for the bulk murmur3 hash kernels."""

from __future__ import annotations

import jax.numpy as jnp

from .kernel import murmur_fmix, murmur_fold


def bulk_hash_seeded_ref(fields, seeds):
    """fields: (N, F) uint32; seeds: (N, 1) uint32 per-row init ->
    (N, 1) uint32 — the one murmur definition (seed-as-init, fold the
    field columns, fmix) every backend shares."""
    N, F = fields.shape
    h = seeds
    for f in range(F):
        h = murmur_fold(h, fields[:, f : f + 1])
    return murmur_fmix(h)


def bulk_hash_ref(fields, seed):
    """fields: (N, F) uint32; seed: () uint32 -> (N, 1) uint32 — the
    scalar-seed entry, a broadcast row of the seeded oracle."""
    N, _ = fields.shape
    return bulk_hash_seeded_ref(fields, jnp.full((N, 1), seed, jnp.uint32))
