"""Pure-jnp oracle for the bulk murmur3 hash kernel."""

from __future__ import annotations

import jax.numpy as jnp

from .kernel import murmur_fmix, murmur_fold


def bulk_hash_ref(fields, seed):
    """fields: (N, F) uint32; seed: () uint32 -> (N, 1) uint32."""
    N, F = fields.shape
    h = jnp.full((N, 1), seed, jnp.uint32)
    for f in range(F):
        h = murmur_fold(h, fields[:, f : f + 1])
    return murmur_fmix(h)
