"""Pallas TPU kernel for the Mamba-2 SSD intra-chunk computation.

The SSD algorithm splits the sequence into chunks of Q tokens.  Within a
chunk the recurrence collapses to a masked quadratic form — two MXU
matmuls (C @ B^T and the weighted (Q,Q) @ (Q,hd)) plus cheap decay
elementwise work — which is the compute hot spot.  This kernel computes,
per (batch, head, chunk):

    y_intra = ((C B^T) .* exp(cum_i - cum_j) .* dt_j) @ x        (Q, hd)
    S_loc   = B^T @ (x .* dt .* exp(cum_last - cum))             (N, hd)
    dec     = exp(cum_last)                                      (1, 1)

The O(nc) inter-chunk state scan and the y_inter correction stay in XLA
(ops.py) — they are tiny and sequential.  Decays use exponents masked
BEFORE exp (no masked-inf gradients; mirrors models/ssm.ssd_chunked,
which is the oracle).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _ssd_chunk_kernel(a_ref, dt_ref, b_ref, c_ref, x_ref, y_ref, s_ref,
                      dec_ref):
    a = a_ref[0, 0, 0].astype(jnp.float32)       # (Q, 1) = dt * A  (negative)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)     # (Q, 1)
    Bm = b_ref[0, 0].astype(jnp.float32)         # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)         # (Q, N)
    x = x_ref[0, 0, 0]                           # (Q, hd)
    Q = a.shape[0]

    cum = jnp.cumsum(a, axis=0)                  # (Q, 1)
    dmat = cum - cum.reshape(1, Q)               # (Q, Q): cum_i - cum_j
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.exp(jnp.where(ii >= jj, dmat, NEG))  # masked BEFORE exp

    scores = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    w = scores * L * dt.reshape(1, Q)            # weight on x_j
    y = jax.lax.dot_general(
        w.astype(x.dtype), x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    cum_last = cum[Q - 1:Q, :]                   # (1, 1)
    decay_to_end = jnp.exp(cum_last - cum)       # (Q, 1)
    xw = x.astype(jnp.float32) * (dt * decay_to_end)
    s_loc = jax.lax.dot_general(
        Bm, xw, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    s_ref[0, 0, 0] = s_loc                       # (N, hd) f32
    dec_ref[0, 0, 0] = jnp.exp(cum_last)         # (1, 1)


def ssd_intra_chunk(
    a: jax.Array,    # (B, H, nc, Q, 1) f32, = dt * A  (negative)
    dt: jax.Array,   # (B, H, nc, Q, 1) f32
    Bm: jax.Array,   # (B, nc, Q, N)     shared across heads
    Cm: jax.Array,   # (B, nc, Q, N)
    x: jax.Array,    # (B, H, nc, Q, hd)
    *, interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (y_intra (B,H,nc,Q,hd), S_loc (B,H,nc,N,hd) f32,
    dec (B,H,nc,1,1) f32)."""
    B, H, nc, Q, hd = x.shape
    N = Bm.shape[-1]
    grid = (B, H, nc)
    kernel = _ssd_chunk_kernel
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, 1), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q, 1), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q, hd), lambda b, h, c: (b, h, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, Q, hd), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, N, hd), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, 1, 1), lambda b, h, c: (b, h, c, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, nc, Q, hd), x.dtype),
            jax.ShapeDtypeStruct((B, H, nc, N, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, H, nc, 1, 1), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel"),
        ) if not interpret else None,
        interpret=interpret,
    )(a, dt, Bm, Cm, x)
