"""Full SSD scan assembled from the Pallas intra-chunk kernel + the XLA
inter-chunk state recurrence.  Matches models/ssm.ssd_chunked bit-for-bit
in f32 (tests sweep shapes/dtypes against it)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import ssd_intra_chunk
from .ref import ssd_intra_chunk_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "force_kernel", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 256,
             force_kernel: bool = False, interpret: bool = False):
    """SSD over a full sequence.

    x: (B, S, H, hd); dt: (B, S, H) f32; A: (H,) negative f32;
    Bm, Cm: (B, S, N).  Returns (y (B,S,H,hd), state (B,H,N,hd) f32).
    """
    B, S, H, hd = x.shape
    N = Bm.shape[-1]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = x.shape[1]
    nc = Sp // chunk

    xk = x.reshape(B, nc, chunk, H, hd).transpose(0, 3, 1, 2, 4)
    dtk = dt.reshape(B, nc, chunk, H).transpose(0, 3, 1, 2)[..., None]
    ak = (dtk[..., 0] * A[None, :, None, None])[..., None]
    Bk = Bm.reshape(B, nc, chunk, N)
    Ck = Cm.reshape(B, nc, chunk, N)

    fn = ssd_intra_chunk if (force_kernel or _on_tpu()) else ssd_intra_chunk_ref
    if fn is ssd_intra_chunk:
        y, s_loc, dec = fn(ak.astype(jnp.float32), dtk.astype(jnp.float32),
                           Bk, Ck, xk, interpret=interpret or not _on_tpu())
    else:
        y, s_loc, dec = fn(ak.astype(jnp.float32), dtk.astype(jnp.float32),
                           Bk, Ck, xk)

    # inter-chunk state recurrence (tiny, sequential -> XLA scan)
    def step(s_carry, inp):
        s_loc_c, dec_c = inp                      # dec_c: (B,H,1,1)
        return dec_c * s_carry + s_loc_c, s_carry

    s0 = jnp.zeros((B, H, N, hd), jnp.float32)
    s_final, states_prev = jax.lax.scan(
        step, s0, (s_loc.transpose(2, 0, 1, 3, 4), dec.transpose(2, 0, 1, 3, 4)))
    states_prev = states_prev.transpose(1, 2, 0, 3, 4)        # (B,H,nc,N,hd)

    # y_inter: C_i (exp cum_i) @ state_before_chunk
    cum = jnp.cumsum(ak[..., 0], axis=-1)                     # (B,H,nc,Q)
    y_inter = jnp.einsum("bcin,bhci,bhcnd->bhcid",
                         Ck.astype(jnp.float32), jnp.exp(cum), states_prev)
    y = y.astype(jnp.float32) + y_inter
    y = y.transpose(0, 2, 3, 1, 4).reshape(B, Sp, H, hd)
    if pad:
        y = y[:, :S]
    return y.astype(x.dtype), s_final
