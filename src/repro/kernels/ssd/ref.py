"""Pure-jnp oracle for the SSD intra-chunk kernel."""

from __future__ import annotations

import jax.numpy as jnp


def ssd_intra_chunk_ref(a, dt, Bm, Cm, x):
    """Same contract as kernel.ssd_intra_chunk, materialized jnp math.

    a, dt: (B, H, nc, Q, 1); Bm, Cm: (B, nc, Q, N); x: (B, H, nc, Q, hd).
    """
    B, H, nc, Q, hd = x.shape
    af = a[..., 0].astype(jnp.float32)                       # (B,H,nc,Q)
    dtf = dt[..., 0].astype(jnp.float32)
    cum = jnp.cumsum(af, axis=-1)                            # (B,H,nc,Q)
    dmat = cum[..., :, None] - cum[..., None, :]             # (B,H,nc,Q,Q)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.exp(jnp.where(tri, dmat, -1e30))
    scores = jnp.einsum("bcin,bcjn->bcij", Cm.astype(jnp.float32),
                        Bm.astype(jnp.float32))              # (B,nc,Q,Q)
    w = scores[:, None] * L * dtf[..., None, :]              # (B,H,nc,Q,Q)
    y = jnp.einsum("bhcij,bhcjd->bhcid", w.astype(x.dtype), x)

    cum_last = cum[..., -1:]                                 # (B,H,nc,1)
    decay = jnp.exp(cum_last - cum)                          # (B,H,nc,Q)
    xw = x.astype(jnp.float32) * (dtf * decay)[..., None]
    s_loc = jnp.einsum("bcjn,bhcjd->bhcnd", Bm.astype(jnp.float32), xw)
    dec = jnp.exp(cum_last)[..., None]                       # (B,H,nc,1,1)
    return y.astype(x.dtype), s_loc, dec
