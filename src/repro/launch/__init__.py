"""Launchers: production meshes, AOT dry-run, training driver.

NOTE: importing this package is safe (no jax device-state side effects);
``repro.launch.dryrun`` as __main__ sets the 512-device XLA flag before
importing jax and must run in its own process.
"""

from .mesh import (
    CHIPS_PER_HOST, HBM_BW, ICI_LINK_BW, PEAK_FLOPS_BF16, batch_axes,
    device_coords, make_production_mesh,
)

__all__ = [
    "make_production_mesh", "device_coords", "batch_axes",
    "PEAK_FLOPS_BF16", "HBM_BW", "ICI_LINK_BW", "CHIPS_PER_HOST",
]
