import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax-importing import: jax locks the device count on
# first backend init.  Only the dry-run gets 512 placeholder devices.

"""Multi-pod dry-run: AOT-lower + compile every (arch x shape) cell on the
production meshes, prove memory fits, and extract the roofline terms +
collective traffic for FlowTracer.

    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-2b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all          # 32 cells x 2 meshes
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single

Per cell this writes results/dryrun/<mesh>/<arch>__<shape>.json with:
memory_analysis, cost_analysis (FLOPs / bytes), per-kind collective wire
bytes, ring-edge locality classes (intra-host / ICI / DCN), and the three
roofline terms (EXPERIMENTS.md §Roofline reads these).
"""

import argparse
import json
import time
import traceback

import jax

from ..configs import ARCHS, applicable_shapes, get_arch, get_shape
from ..core.hlo_flows import extract_collectives, summarize, collectives_to_flows
from ..core.placement import ring_edge_stats
from .flops import cell_cost, resident_bytes
from .mesh import (
    HBM_BW, ICI_LINK_BW, PEAK_FLOPS_BF16, device_coords, make_production_mesh,
)
from .specs import build_cell


def run_cell(arch_name: str, shape_name: str, multi_pod: bool,
             out_dir: str, *, force: bool = False, verbose: bool = True,
             **build_kw) -> dict:
    mesh_tag = "multi" if multi_pod else "single"
    os.makedirs(os.path.join(out_dir, mesh_tag), exist_ok=True)
    out_path = os.path.join(out_dir, mesh_tag, f"{arch_name}__{shape_name}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    arch = get_arch(arch_name)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size

    t0 = time.time()
    cell = build_cell(arch, shape, mesh, **build_kw)
    with jax.set_mesh(mesh):
        jitted = jax.jit(
            cell.fn,
            in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
            donate_argnums=cell.donate_argnums,
        )
        lowered = jitted.lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    ops = extract_collectives(hlo)
    summ = summarize(ops)
    coords = device_coords(mesh)
    flows, edge_stats = collectives_to_flows(ops, coords)

    edge_classes = {"intra_host": 0, "intra_pod": 0, "inter_pod": 0}
    for op in ops:
        for g in op.groups:
            if len(g) > 1:
                st = ring_edge_stats(list(g), coords)
                edge_classes["intra_host"] += st["intra_host"]
                edge_classes["intra_pod"] += st["intra_pod"]
                edge_classes["inter_pod"] += st["inter_pod"]

    flops_hlo = float(cost.get("flops", 0.0))
    bytes_hlo = float(cost.get("bytes accessed", 0.0))
    wire = summ.total_wire_bytes

    # Analytic FLOPs/bytes (XLA-CPU cost_analysis counts loop bodies once;
    # see flops.py docstring).  Collective bytes from the HLO itself with
    # while trip-count multipliers applied.
    ac = cell_cost(
        arch, shape,
        n_params=cell.meta["params"], n_chips=n_chips,
        model_shards=mesh.shape["model"],
        data_shards=n_chips // mesh.shape["model"],
        grad_accum=cell.meta.get("grad_accum", 1),
        fsdp=cell.meta.get("fsdp", False),
        opt_bytes_per_param=4 if cell.meta.get("opt_state_dtype") == "bfloat16" else 8,
    )
    flops_dev = ac.total_flops / n_chips
    compute_s = flops_dev / PEAK_FLOPS_BF16
    memory_s = ac.hbm_bytes / HBM_BW
    collective_s = wire / ICI_LINK_BW

    # MODEL_FLOPS = 6*N*D train / 2*N*D fwd-only, D = tokens this step
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6 if shape.kind == "train" else 2
    model_flops_total = mult * cell.meta["active_params"] * tokens
    model_flops_dev = model_flops_total / n_chips
    useful = model_flops_dev / flops_dev if flops_dev else 0.0

    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    res = resident_bytes(
        arch, shape, n_params=cell.meta["params"], n_chips=n_chips,
        model_shards=mesh.shape["model"],
        grad_accum=cell.meta.get("grad_accum", 1),
        fsdp=cell.meta.get("fsdp", False),
        opt_bytes_per_param=4 if cell.meta.get("opt_state_dtype") == "bfloat16" else 8,
    )

    record = {
        **cell.meta,
        "mesh_tag": mesh_tag,
        "n_chips": int(n_chips),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes": mem.argument_size_in_bytes + mem.output_size_in_bytes
            + mem.temp_size_in_bytes - mem.alias_size_in_bytes,
            "resident_analytic": res,
        },
        "cost": {
            "flops_analytic_per_dev": flops_dev,
            "hbm_bytes_analytic_per_dev": ac.hbm_bytes,
            "fwd_flops_global": ac.fwd_flops,
            "attn_flops_global": ac.attn_flops,
            # raw XLA numbers (loop bodies counted once — reference only)
            "flops_hlo_raw": flops_hlo,
            "bytes_accessed_hlo_raw": bytes_hlo,
        },
        "collectives": {
            "count_by_kind": summ.per_kind_count,
            "wire_bytes_by_kind": summ.per_kind_wire,
            "wire_bytes_total": wire,
            "operand_bytes_total": summ.total_operand_bytes,
            "edge_classes": edge_classes,
            "dcn_flows": len(flows),
            "dcn_bytes": edge_stats.dcn_bytes,
            "ici_bytes": edge_stats.ici_bytes,
        },
        "roofline": {
            **terms,
            "dominant": dominant,
            "model_flops_per_dev": model_flops_dev,
            "useful_flop_ratio": useful,
            "bound_s": max(terms.values()),
        },
    }
    with open(out_path, "w") as f:
        json.dump(record, f, indent=2)

    if verbose:
        fit = "FITS" if res["total"] < 16 << 30 else "OVER 16GiB"
        print(f"[{mesh_tag}] {arch_name} x {shape_name}: "
              f"compile {t_compile:.0f}s, "
              f"resident {res['total']/2**30:.2f} GiB ({fit}; cpu-peak "
              f"{record['memory']['peak_bytes']/2**30:.1f}), "
              f"flops/dev {flops_dev:.3g}, wire {wire/2**20:.1f} MiB, "
              f"dominant={dominant} ({terms[dominant]*1e3:.2f} ms), "
              f"useful={useful:.2f}")
        print(f"  memory_analysis: {mem}")
        ca = {k: v for k, v in sorted(cost.items()) if v}
        print(f"  cost_analysis: { {k: round(v, 1) for k, v in list(ca.items())[:8]} }")
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    cells: list[tuple[str, str]] = []
    if args.all:
        for a in ARCHS.values():
            for s in applicable_shapes(a):
                cells.append((a.name, s.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    failures = []
    for arch_name, shape_name in cells:
        for mp in meshes:
            try:
                run_cell(arch_name, shape_name, mp, args.out, force=args.force)
            except Exception as e:  # noqa: BLE001 — record and continue
                failures.append((arch_name, shape_name, mp, repr(e)))
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print(f"\nall {len(cells) * len(meshes)} dry-run cells passed")


if __name__ == "__main__":
    main()
