"""Analytic per-cell FLOPs and HBM-byte model for the roofline.

Why analytic: XLA-CPU ``cost_analysis`` counts while-loop bodies exactly
once (verified empirically — see EXPERIMENTS.md §Dry-run "cost-analysis
semantics"), so any scanned program (layers x grad-accum x attention
chunks) under-reports FLOPs/bytes by orders of magnitude, inconsistently
across cells.  Matmul-dominated transformer costs are exactly countable
from the config, so the compute/memory roofline terms use this model;
the collective term uses the HLO itself (trip-count-corrected), and raw
cost_analysis numbers are recorded alongside for reference.

Conventions:
  * matmul (m,k)x(k,n): 2*m*k*n FLOPs.
  * causal attention: 0.5 * full score/PV cost.
  * train = fwd + 2x bwd + remat_fraction * fwd (nothing_saveable -> ~1).
  * bytes: weight streaming (per microbatch, per pass), optimizer
    read/write, activation traffic ~ act_rw_factor * activation bytes,
    KV-cache read for decode.
"""

from __future__ import annotations

import dataclasses
import math

from ..configs.base import ArchConfig, ShapeConfig


@dataclasses.dataclass
class CellCost:
    fwd_flops: float          # global, one forward pass
    total_flops: float        # global, whole step (train: fwd+bwd+remat)
    attn_flops: float         # part of fwd_flops
    hbm_bytes: float          # per device
    notes: dict


def _attn_flops(cfg: ArchConfig, T: float, ctx: float, *, causal: bool,
                n_layers: int | None = None) -> float:
    """Score + PV matmuls.  T queries attending to ctx keys."""
    if cfg.mla:
        qk = cfg.mla.qk_nope_dim + cfg.mla.qk_rope_dim
        dv = cfg.mla.v_head_dim
        per = 2 * T * ctx * cfg.num_heads * (qk + dv)
    else:
        per = 2 * T * ctx * cfg.num_heads * cfg.hd * 2
    if causal and ctx == T:
        per *= 0.5
    L = n_layers if n_layers is not None else _n_attn_layers(cfg)
    return per * L


def _n_attn_layers(cfg: ArchConfig) -> int:
    if cfg.family == "ssm":
        return 0
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.hybrid.period
    return cfg.num_layers


def _layer_proj_flops(cfg: ArchConfig, T: float) -> float:
    """Per-token matmul flops x T for all layers (no attention scores)."""
    D = cfg.d_model
    total = 0.0

    def dense_mlp(F):
        return 2 * T * D * F * 3                       # gate, up, down

    def gqa_proj():
        hd, H, Hkv = cfg.hd, cfg.num_heads, cfg.num_kv_heads
        return 2 * T * D * (H * hd + 2 * Hkv * hd) + 2 * T * H * hd * D

    def mla_proj():
        m = cfg.mla
        qk = m.qk_nope_dim + m.qk_rope_dim
        f = 2 * T * D * cfg.num_heads * qk             # q
        f += 2 * T * D * (m.kv_lora_rank + m.qk_rope_dim)   # down
        f += 2 * T * m.kv_lora_rank * cfg.num_heads * (m.qk_nope_dim + m.v_head_dim)
        f += 2 * T * cfg.num_heads * m.v_head_dim * D  # o
        return f

    def moe_ffn():
        e = cfg.moe
        f = 2 * T * D * e.num_experts                  # router
        f += 2 * T * e.top_k * e.capacity_factor * D * e.d_ff_expert * 3
        if e.num_shared:
            f += 2 * T * D * e.num_shared * e.d_ff_expert * 3
        return f

    def mamba2_proj():
        s = cfg.ssm
        di = s.expand * D
        H = di // s.head_dim
        N = s.d_state
        f = 2 * T * D * (2 * di + 2 * N + H)           # z,x,B,C,dt
        f += T * di * s.d_conv * 2
        # SSD: intra-chunk (scores 2*T*Q*N + weighted 2*T*Q*hd per head)
        Q = s.chunk
        f += 2 * T * Q * N + 2 * T * Q * di
        f += 2 * T * N * di * 2                        # state outer products + C.S
        f += 2 * T * di * D                            # out_proj
        return f

    def mamba1_proj():
        s = cfg.ssm
        di = s.expand * D
        N = s.d_state
        r = math.ceil(D / 16)
        f = 2 * T * D * 2 * di                         # x, z
        f += T * di * s.d_conv * 2
        f += 2 * T * di * (r + 2 * N)                  # x_proj
        f += 2 * T * r * di                            # dt_proj
        f += 8 * T * di * N                            # recurrence
        f += 2 * T * di * D
        return f

    if cfg.family == "ssm":
        total += cfg.num_layers * mamba2_proj()
    elif cfg.family == "hybrid":
        n_attn = cfg.num_layers // cfg.hybrid.period
        n_mamba = cfg.num_layers - n_attn
        total += n_attn * gqa_proj() + n_mamba * mamba1_proj()
        n_moe = cfg.num_layers // 2          # MoE every other layer
        total += n_moe * moe_ffn() + (cfg.num_layers - n_moe) * dense_mlp(cfg.d_ff)
    elif cfg.family == "encdec":
        # decoder self + cross projections + mlp (gelu: 2 matmuls)
        hd, H = cfg.hd, cfg.num_heads
        dec = 2 * T * D * 3 * H * hd + 2 * T * H * hd * D      # self qkv+o
        dec += 2 * T * D * H * hd + 2 * T * H * hd * D         # cross q+o
        dec += 2 * T * D * cfg.d_ff * 2
        total += cfg.num_layers * dec
    elif cfg.mla:
        e = cfg.moe
        total += cfg.num_layers * mla_proj()
        total += e.first_dense_layers * dense_mlp(cfg.d_ff)
        total += (cfg.num_layers - e.first_dense_layers) * moe_ffn()
    elif cfg.moe:
        total += cfg.num_layers * (gqa_proj() + moe_ffn())
    else:
        total += cfg.num_layers * (gqa_proj() + dense_mlp(cfg.d_ff))
    return total


def _encoder_flops(cfg: ArchConfig, B: float) -> float:
    if not cfg.encdec:
        return 0.0
    ec = cfg.encdec
    Te = B * ec.encoder_seq
    D, H, hd = cfg.d_model, cfg.num_heads, cfg.hd
    per = 2 * Te * D * 3 * H * hd + 2 * Te * H * hd * D
    per += 2 * Te * D * cfg.d_ff * 2
    per += 2 * Te * ec.encoder_seq * H * hd * 2          # full bidir attn
    return per * ec.num_encoder_layers


def _cross_kv_flops(cfg: ArchConfig, B: float, T: float) -> float:
    if not cfg.encdec:
        return 0.0
    ec = cfg.encdec
    Te = B * ec.encoder_seq
    D, H, hd = cfg.d_model, cfg.num_heads, cfg.hd
    kv = 2 * Te * D * 2 * H * hd * cfg.num_layers        # k,v from memory
    scores = 2 * T * ec.encoder_seq * H * hd * 2 * cfg.num_layers
    return kv + scores


def cell_cost(
    cfg: ArchConfig, shape: ShapeConfig, *,
    n_params: int, n_chips: int, model_shards: int, data_shards: int,
    grad_accum: int = 1, fsdp: bool = False,
    opt_bytes_per_param: int = 8, remat_fraction: float = 1.0,
    act_rw_factor: float = 8.0,
) -> CellCost:
    B, S = shape.global_batch, shape.seq_len
    kind = shape.kind
    T = B * (1 if kind == "decode" else S)
    ctx = S if kind == "decode" else S

    proj = _layer_proj_flops(cfg, T)
    if kind == "decode":
        attn = _attn_flops(cfg, T, ctx, causal=False)
        if cfg.sliding_window and shape.seq_len > cfg.sliding_window:
            attn = _attn_flops(cfg, T, cfg.sliding_window, causal=False)
    else:
        attn = _attn_flops(cfg, T, S, causal=True)
    enc = _encoder_flops(cfg, B) if kind != "decode" else 0.0
    cross = _cross_kv_flops(cfg, B, T) if cfg.encdec else 0.0
    if kind == "decode" and cfg.encdec:
        cross = _cross_kv_flops(cfg, B, T)               # cross kv recomputed
    unembed = 2 * T * cfg.d_model * cfg.vocab
    if kind == "prefill":
        unembed = 2 * B * cfg.d_model * cfg.vocab        # last position only
    fwd = proj + attn + enc + cross + unembed

    if kind == "train":
        total = fwd * (3.0 + remat_fraction)
    else:
        total = fwd

    # ---- bytes (per device) ----
    w_local = n_params * 2 / model_shards                # gathered TP shard
    w_resident = n_params * 2 / (model_shards * (data_shards if fsdp else 1))
    if kind == "train":
        passes = 3 + remat_fraction                      # fwd, remat, dgrad, wgrad
        weight_bytes = grad_accum * passes * w_local
        opt_bytes = (n_params / (model_shards * (data_shards if fsdp else 1))) \
            * (opt_bytes_per_param + 2 * 2 + 4 * 2)      # m,v rw + p rw + g
        act_local = (T / (n_chips / model_shards)) * cfg.d_model * 2 \
            * cfg.num_layers
        act_bytes = act_rw_factor * act_local
        hbm = weight_bytes + opt_bytes + act_bytes
    elif kind == "prefill":
        act_local = (T / (n_chips / model_shards)) * cfg.d_model * 2 \
            * cfg.num_layers
        hbm = w_local + act_rw_factor * act_local
    else:  # decode: weights + cache read once per token
        cache_bytes = _cache_bytes(cfg, B, S) / n_chips
        hbm = w_local + cache_bytes
    return CellCost(
        fwd_flops=fwd, total_flops=total, attn_flops=attn + cross,
        hbm_bytes=hbm,
        notes={"w_local": w_local, "w_resident": w_resident,
               "remat_fraction": remat_fraction},
    )


def resident_bytes(
    cfg: ArchConfig, shape: ShapeConfig, *,
    n_params: int, n_chips: int, model_shards: int,
    grad_accum: int = 1, fsdp: bool = False, opt_bytes_per_param: int = 8,
) -> dict:
    """Analytic per-device HBM residency (TPU semantics: bf16 matmuls run
    native, no f32 conversion copies).  The XLA-CPU temp numbers include
    f32 dot-operand conversions and are an upper bound; this is the
    number to compare against the 16 GiB HBM budget."""
    data_shards = n_chips // model_shards
    pshard = model_shards * (data_shards if fsdp else 1)
    out = {"params": n_params * 2 / pshard}
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        out["opt_state"] = n_params * opt_bytes_per_param / pshard
        out["grads_accum"] = n_params * 4 / pshard
        L = cfg.num_layers
        g = int(math.isqrt(L)) or 1
        while g > 1 and L % g:
            g -= 1
        saved = (L // g + g)
        b_micro = max(1, B // grad_accum // data_shards)
        out["saved_activations"] = saved * b_micro * S * cfg.d_model * 2
        if fsdp:
            # transient gathered weights for ~2 layers (double buffered)
            out["fsdp_gather"] = 2 * (n_params / cfg.num_layers) * 2 / model_shards
        v_local = cfg.vocab / (model_shards if cfg.vocab % model_shards == 0 else 1)
        out["logits_micro"] = b_micro * S * v_local * 2 * 2
    elif shape.kind == "prefill":
        b_local = max(1, B // data_shards)
        out["activations"] = 4 * b_local * S * cfg.d_model * 2
    else:
        out["kv_cache"] = _cache_bytes(cfg, B, S) / n_chips
    out["total"] = sum(out.values())
    return out


def _cache_bytes(cfg: ArchConfig, B: int, S: int) -> float:
    if cfg.family == "ssm":
        s = cfg.ssm
        di = s.expand * cfg.d_model
        H = di // s.head_dim
        return cfg.num_layers * B * (H * s.d_state * s.head_dim * 4
                                     + (s.d_conv - 1) * (di + 2 * s.d_state) * 2)
    if cfg.family == "hybrid":
        n_p = cfg.num_layers // cfg.hybrid.period
        attn = n_p * 2 * B * S * cfg.num_kv_heads * cfg.hd * 2
        s = cfg.ssm
        di = s.expand * cfg.d_model
        mamba = (cfg.num_layers - n_p) * B * (di * s.d_state * 4
                                              + (s.d_conv - 1) * di * 2)
        return attn + mamba
    if cfg.mla:
        m = cfg.mla
        return cfg.num_layers * B * S * (m.kv_lora_rank + m.qk_rope_dim) * 2
    return cfg.num_layers * 2 * B * S * cfg.num_kv_heads * cfg.hd * 2
