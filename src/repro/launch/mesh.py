"""Production meshes.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — required because only dryrun.py runs with the
512-device XLA flag.

Topology convention (TPU v5e):
  * a pod is 256 chips = 64 hosts x 4 chips;
  * single-pod mesh (data=16, model=16);
  * multi-pod mesh (pod=2, data=16, model=16) — the 'pod' axis crosses
    the DCN leaf-spine fabric, which is where the paper's ECMP analysis
    applies (DESIGN.md section 2).
"""

from __future__ import annotations

import jax

# TPU v5e hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_LINK_BW = 50e9                # bytes/s per link
DCN_HOST_GBPS = 100.0             # per-host NIC for the DCN fabric model
CHIPS_PER_HOST = 4
HOSTS_PER_POD = 64


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
    )


def device_coords(mesh: jax.sharding.Mesh) -> dict[int, tuple[int, int, int]]:
    """device id -> (pod, global_host, chip-in-host) for hlo_flows.

    Devices are laid out C-order over the mesh axes; within a pod,
    consecutive device ids share a host in groups of CHIPS_PER_HOST.
    """
    ids = [d.id for d in mesh.devices.flat]
    npods = mesh.shape.get("pod", 1)
    per_pod = len(ids) // npods
    coords = {}
    for i, dev in enumerate(ids):
        pod = i // per_pod
        within = i % per_pod
        host = pod * (per_pod // CHIPS_PER_HOST) + within // CHIPS_PER_HOST
        coords[dev] = (pod, host, within % CHIPS_PER_HOST)
    return coords


def batch_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.shape else ("data",)
