"""Per-cell build logic: for every (arch x shape x mesh) produce the step
function, ShapeDtypeStruct inputs, and in/out shardings for AOT lowering.

``input_specs`` follows the shannon/kernels pattern: weak-type-correct,
shardable stand-ins, zero device allocation — the 398B jamba cell lowers
on a laptop.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig, ShapeConfig
from ..models.model import Model
from ..models.lm import RematPolicy
from ..parallel.sharding import (
    batch_specs, cache_partition_specs, param_specs, to_shardings,
)
from ..train.optimizer import AdamWConfig
from ..train.step import TrainConfig, make_train_step
from .mesh import batch_axes

SDS = jax.ShapeDtypeStruct

# activation budget per device used to pick grad_accum (bytes)
_ACT_BUDGET = 3 << 30


@dataclasses.dataclass
class CellBuild:
    arch: ArchConfig
    shape: ShapeConfig
    fn: Callable
    args: tuple                      # ShapeDtypeStructs
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple[int, ...]
    meta: dict


def _params_sds(model: Model) -> Any:
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def count_params_tree(tree) -> int:
    return sum(int(math.prod(l.shape)) for l in jax.tree.leaves(tree))


def active_params(cfg: ArchConfig, total: int) -> int:
    """Active params per token for the 6*N*D MODEL_FLOPS convention."""
    if not cfg.moe:
        return total
    e = cfg.moe
    expert_p = 3 * cfg.d_model * e.d_ff_expert
    n_moe_layers = (cfg.num_layers - e.first_dense_layers)
    if e.every_k_layers > 1:
        n_moe_layers = cfg.num_layers // e.every_k_layers
    inactive = n_moe_layers * (e.num_experts - e.top_k) * expert_p
    return total - inactive


def pick_grad_accum(cfg: ArchConfig, shape: ShapeConfig, data_shards: int) -> int:
    """Default: microbatch of one sequence per data shard.  Combined with
    sqrt-remat grouping this keeps saved activations ~ 2*sqrt(L) * S * D
    per device for every assigned arch; cells that could afford larger
    microbatches recover throughput via the §Perf hillclimb instead."""
    return max(1, shape.global_batch // data_shards)


def _batch_sds(cfg: ArchConfig, shape: ShapeConfig, *, with_labels: bool) -> dict:
    B, S = shape.global_batch, shape.seq_len
    batch: dict[str, Any] = {}
    if cfg.family == "vlm":
        batch["embeds"] = SDS((B, S, cfg.d_model), jnp.bfloat16)
        batch["mrope_positions"] = SDS((3, B, S), jnp.int32)
    else:
        batch["tokens"] = SDS((B, S), jnp.int32)
    if cfg.family == "encdec":
        batch["enc_embeds"] = SDS((B, cfg.encdec.encoder_seq, cfg.d_model),
                                  jnp.bfloat16)
    if with_labels:
        batch["labels"] = SDS((B, S), jnp.int32)
    return batch


def _decode_batch_sds(cfg: ArchConfig, shape: ShapeConfig) -> dict:
    B = shape.global_batch
    batch: dict[str, Any] = {"tokens": SDS((B, 1), jnp.int32)}
    if cfg.family == "vlm":
        # decode consumes text tokens; M-RoPE positions for the new token
        batch["mrope_positions"] = SDS((3, B, 1), jnp.int32)
    if cfg.family == "encdec":
        batch["enc_memory"] = SDS((B, cfg.encdec.encoder_seq, cfg.d_model),
                                  jnp.bfloat16)
    return batch


def _spec_tree_for_batch(batch: dict, baxes: tuple[str, ...]) -> dict:
    table = batch_specs(baxes)
    return {k: table[k] for k in batch}


def build_cell(
    arch: ArchConfig, shape: ShapeConfig, mesh: Mesh, *,
    fsdp_threshold_params: int = 10_000_000_000,
    remat_policy: str = "nothing_saveable",
    opt_state_dtype: str | None = None,
    grad_accum_override: int | None = None,
    moe_impl: str | None = None,
    pin_activations: bool | None = None,
) -> CellBuild:
    # §Perf-derived per-family defaults: dense archs pin the residual
    # stream batch-sharded (3x collective reduction on qwen2-72b); MoE
    # archs run expert-parallel with XLA-chosen activation layouts
    # (pinning regresses the dispatch path 5x; see EXPERIMENTS.md §Perf).
    if pin_activations is None:
        pin_activations = arch.moe is None
    if moe_impl is None and arch.moe:
        moe_impl = "ep"
    if moe_impl and arch.moe:
        arch = dataclasses.replace(
            arch, moe=dataclasses.replace(arch.moe, impl=moe_impl))
    model_size = mesh.shape["model"]
    data_shards = math.prod(
        mesh.shape[a] for a in mesh.shape if a in ("pod", "data"))
    baxes = batch_axes(mesh)
    b = baxes if len(baxes) > 1 else baxes[0]

    model = Model(arch, remat=RematPolicy(enabled=shape.kind == "train",
                                          policy=remat_policy))
    p_sds = _params_sds(model)
    n_params = count_params_tree(p_sds)
    n_active = active_params(arch, n_params)
    use_fsdp = n_params >= fsdp_threshold_params and shape.kind == "train"
    attn_ok = arch.num_heads % model_size == 0
    pspec = param_specs(
        p_sds, model_size=model_size,
        fsdp_axis="data" if use_fsdp else None,
        fsdp_size=mesh.shape.get("data", 1),
        attention_shardable=attn_ok,
    )
    p_shard = to_shardings(mesh, pspec)

    if use_fsdp:
        # FSDP per-layer unshard: inside the layer scan, each SLICED
        # layer's params are re-pinned to TP-only (data dropped), so XLA
        # gathers one layer instead of the whole stack per iteration
        # (§Perf iteration 1).
        stack_key = "periods" if arch.family == "hybrid" else "layers"

        def strip(s: P) -> P:
            return P(*[None if ax == "data" else ax for ax in list(s)[1:]])

        lspecs = jax.tree.map(strip, pspec[stack_key],
                              is_leaf=lambda x: isinstance(x, P))
        model = dataclasses.replace(model, layer_specs=lspecs)

    if shape.kind == "train" and pin_activations:
        # pin the residual stream batch-sharded inside every scanned block
        model = dataclasses.replace(model, act_spec=P(b, None, None))

    meta = {
        "arch": arch.name, "shape": shape.name, "kind": shape.kind,
        "params": n_params, "active_params": n_active, "fsdp": use_fsdp,
        "mesh": dict(mesh.shape), "attention_tp": attn_ok,
    }

    if shape.kind == "train":
        if opt_state_dtype is None:
            opt_state_dtype = "bfloat16" if n_params > 100_000_000_000 else "float32"
        accum = grad_accum_override or pick_grad_accum(arch, shape, data_shards)
        meta["grad_accum"] = accum
        meta["opt_state_dtype"] = opt_state_dtype
        # ZeRO-2 accumulator: grads sharded over 'data' during accumulation
        # (reduce-scatter per microbatch, one gather at the update) — for
        # non-FSDP archs whose params are replicated over data.
        accum_specs = None
        if not use_fsdp:
            accum_specs = param_specs(
                p_sds, model_size=model_size, fsdp_axis="data",
                fsdp_size=mesh.shape.get("data", 1),
                fsdp_min_size=1 << 20,
                attention_shardable=attn_ok,
            )
        tc = TrainConfig(
            optimizer=AdamWConfig(state_dtype=opt_state_dtype),
            grad_accum=accum,
            batch_axes=baxes,
            accum_specs=accum_specs,
        )
        step = make_train_step(model, tc)
        opt_dt = jnp.bfloat16 if opt_state_dtype == "bfloat16" else jnp.float32
        o_sds = {
            "m": jax.tree.map(lambda l: SDS(l.shape, opt_dt), p_sds),
            "v": jax.tree.map(lambda l: SDS(l.shape, opt_dt), p_sds),
            "step": SDS((), jnp.int32),
        }
        o_spec = {"m": pspec, "v": pspec, "step": P()}
        o_shard = to_shardings(mesh, o_spec)
        batch = _batch_sds(arch, shape, with_labels=True)
        bspec = _spec_tree_for_batch(batch, baxes)
        b_shard = to_shardings(mesh, bspec)
        metrics_shard = NamedSharding(mesh, P())
        return CellBuild(
            arch=arch, shape=shape, fn=step,
            args=(p_sds, o_sds, batch),
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard,
                           jax.tree.map(lambda _: metrics_shard,
                                        {"loss": 0, "grad_norm": 0, "lr": 0})),
            donate_argnums=(0, 1),
            meta=meta,
        )

    if shape.kind == "prefill":
        def prefill_last(params, batch):
            return model.prefill(params, batch, last_only=True)[:, 0, :]

        batch = _batch_sds(arch, shape, with_labels=False)
        bspec = _spec_tree_for_batch(batch, baxes)
        return CellBuild(
            arch=arch, shape=shape, fn=prefill_last,
            args=(p_sds, batch),
            in_shardings=(p_shard, to_shardings(mesh, bspec)),
            out_shardings=NamedSharding(mesh, P(b, None)),
            donate_argnums=(),
            meta=meta,
        )

    # decode: one token against a cache of seq_len
    cache_spec_tree = model.cache_specs(shape.global_batch, shape.seq_len)
    c_sds = jax.tree.map(
        lambda sd: SDS(sd[0], sd[1]), cache_spec_tree,
        is_leaf=lambda x: isinstance(x, tuple) and isinstance(x[0], tuple))
    cpspec = cache_partition_specs(
        cache_spec_tree, batch_axes=baxes, model_size=model_size,
        batch_size_total=data_shards,
    )
    c_shard = to_shardings(mesh, cpspec)
    batch = _decode_batch_sds(arch, shape)
    bspec = _spec_tree_for_batch(batch, baxes)
    if shape.global_batch < data_shards:
        # long-context decode: batch of 1 cannot ride the batch axes
        bspec = jax.tree.map(lambda s: P(*(None,) * len(s)), bspec,
                             is_leaf=lambda x: isinstance(x, P))
    idx = SDS((), jnp.int32)

    def serve_step(params, caches, batch, index):
        return model.decode_step(params, caches, batch, index)

    return CellBuild(
        arch=arch, shape=shape, fn=serve_step,
        args=(p_sds, c_sds, batch, idx),
        in_shardings=(p_shard, c_shard, to_shardings(mesh, bspec),
                      NamedSharding(mesh, P())),
        out_shardings=(
            NamedSharding(mesh, P(b if shape.global_batch >= data_shards
                                  else None, None, None)),
            c_shard,
        ),
        donate_argnums=(1,),
        meta=meta,
    )
