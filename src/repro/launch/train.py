"""Production training launcher.

    python -m repro.launch.train --arch granite-3-2b --steps 100 --reduced

On a real pod this builds the production mesh, shards state with
param_specs, and runs the jitted train step with checkpoint/restart and
straggler monitoring.  On CPU (this container) use --reduced to run the
same code path on the smoke-scale config, or --dry-run to only lower.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..checkpoint import latest_step, restore, save
from ..configs import get_arch
from ..data import SyntheticDataset
from ..ft import StragglerDetector, run_with_restarts
from ..models import Model
from ..train import AdamWConfig, TrainConfig, init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    tc = TrainConfig(
        optimizer=AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                              decay_steps=args.steps),
        grad_accum=args.grad_accum,
    )
    ds = SyntheticDataset(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.global_batch, seed=0)
    step_fn = jax.jit(make_train_step(model, tc))
    detector = StragglerDetector()

    def train_loop(_s: int) -> int:
        if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
            tpl = init_train_state(model, tc, jax.random.PRNGKey(0))
            restored, s0 = restore(args.ckpt_dir, {"params": tpl[0], "opt": tpl[1]})
            params, opt = restored["params"], restored["opt"]
            print(f"[restore] step {s0}")
        else:
            params, opt = init_train_state(model, tc, jax.random.PRNGKey(0))
            s0 = 0
            n = sum(x.size for x in jax.tree.leaves(params))
            print(f"[init] {cfg.name}: {n/1e6:.1f}M params, "
                  f"devices={jax.device_count()}")
        for i in range(s0, args.steps):
            t0 = time.perf_counter()
            batch = {k: jnp.asarray(v) for k, v in ds.batch(i).items()}
            if cfg.family == "encdec":
                batch["enc_embeds"] = jnp.zeros(
                    (args.global_batch, cfg.encdec.encoder_seq, cfg.d_model),
                    jnp.bfloat16)
            params, opt, metrics = step_fn(params, opt, batch)
            detector.record("host-0", time.perf_counter() - t0)
            if args.ckpt_dir and ((i + 1) % args.ckpt_every == 0
                                  or i + 1 == args.steps):
                save(args.ckpt_dir, i + 1, {"params": params, "opt": opt})
            if (i + 1) % args.log_every == 0 or i == s0:
                print(f"step {i+1:5d}  loss={float(metrics['loss']):.4f}  "
                      f"gnorm={float(metrics['grad_norm']):.2f}")
        for rep in detector.check():
            print(f"[straggler] {rep.host}: {rep.ratio:.2f}x median -> {rep.advice}")
        return args.steps

    run_with_restarts(train_loop)


if __name__ == "__main__":
    main()
