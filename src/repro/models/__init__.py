"""Model substrate: layers, families, and the public Model API."""

from .model import Model, cross_entropy
from .lm import RematPolicy, init_lm, lm_forward, cache_specs, init_cache

__all__ = [
    "Model", "cross_entropy", "RematPolicy", "init_lm", "lm_forward",
    "cache_specs", "init_cache",
]
