"""Attention variants: GQA (RoPE / M-RoPE, optional bias), MLA
(DeepSeek-V2 latent attention, absorbed decode), cross-attention
(enc-dec), and a chunked online-softmax path for long sequences.

The chunked path is the pure-XLA twin of the Pallas flash kernel
(kernels/flash_attention): same math, scan over KV blocks with a running
(max, sum, acc) triple, so activation memory stays O(S * block) instead of
O(S^2).  It is also the oracle the kernel tests compare against.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import InitCtx, apply_mrope, apply_rope

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Core attention math (GQA-aware)
# ---------------------------------------------------------------------------


def _gqa_scores_shape(q, k):
    # q: (B, Sq, H, hd), k: (B, Sk, Hkv, hd) with H = G * Hkv
    B, Sq, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    return B, Sq, H, hd, Hkv, G


def plain_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool, q_offset, window: int = 0,
    kv_valid_len: Optional[jax.Array] = None,
) -> jax.Array:
    """Materialized-scores attention (decode / short sequences).

    q_offset: scalar (traced ok) absolute position of q[0] for causal
    masking against the kv positions 0..Sk-1.
    kv_valid_len: if given, kv positions >= this are masked (cache slots).
    """
    B, Sq, H, hd, Hkv, G = _gqa_scores_shape(q, k)
    qg = q.reshape(B, Sq, Hkv, G, hd)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    scores *= 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    k_pos = jnp.arange(k.shape[1])
    q_pos = q_offset + jnp.arange(Sq)
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window > 0:
        mask &= k_pos[None, :] > q_pos[:, None] - window
    if kv_valid_len is not None:
        mask &= k_pos[None, :] < kv_valid_len
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(B, Sq, H, v.shape[-1])


def chunked_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool, window: int = 0, chunk: int = 1024,
) -> jax.Array:
    """Online-softmax attention, scanning KV in blocks (flash-style).

    Memory: O(B * H * Sq * chunk) instead of O(B * H * Sq * Sk).
    """
    B, Sq, H, hd, Hkv, G = _gqa_scores_shape(q, k)
    Sk = k.shape[1]
    pad = (-Sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = k.shape[1] // chunk
    kc = k.reshape(B, n_chunks, chunk, Hkv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, Hkv, v.shape[-1]).transpose(1, 0, 2, 3, 4)

    qg = (q.reshape(B, Sq, Hkv, G, hd) * (1.0 / jnp.sqrt(hd))).astype(q.dtype)
    q_pos = jnp.arange(Sq)

    def step(carry, xs):
        m, l, acc = carry
        idx, kb, vb = xs
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kb).astype(jnp.float32)
        k_pos = idx * chunk + jnp.arange(chunk)
        mask = k_pos[None, :] < Sk
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window > 0:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        scale = jnp.exp(m - m_new)
        l_new = l * scale + p.sum(axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb)
        acc_new = acc * scale[..., None].astype(acc.dtype) + pv.astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Sq, v.shape[-1]), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (jnp.arange(n_chunks), kc, vc)
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, v.shape[-1])
    return out.astype(q.dtype)


def attention_any(q, k, v, *, causal, q_offset=0, window=0,
                  kv_valid_len=None, chunk_threshold: int = 2048):
    """Dispatch: chunked for long self-attention, plain otherwise."""
    if q.shape[1] > 1 and k.shape[1] > chunk_threshold and kv_valid_len is None \
            and q.shape[1] == k.shape[1]:
        return chunked_attention(q, k, v, causal=causal, window=window)
    return plain_attention(q, k, v, causal=causal, q_offset=q_offset,
                           window=window, kv_valid_len=kv_valid_len)


# ---------------------------------------------------------------------------
# GQA block (granite / glm4 / codeqwen / qwen2 / qwen2-vl / jamba-attn /
# whisper self-attn)
# ---------------------------------------------------------------------------


def init_gqa(ctx: InitCtx, cfg: ArchConfig, prefix: str) -> dict:
    hd, H, Hkv, D = cfg.hd, cfg.num_heads, cfg.num_kv_heads, cfg.d_model
    p = {
        "wq": ctx.make(f"{prefix}.wq", (D, H * hd)),
        "wk": ctx.make(f"{prefix}.wk", (D, Hkv * hd)),
        "wv": ctx.make(f"{prefix}.wv", (D, Hkv * hd)),
        "wo": ctx.make(f"{prefix}.wo", (H * hd, D)),
    }
    if cfg.qkv_bias:
        p["bq"] = ctx.make(f"{prefix}.bq", (H * hd,), zero=True)
        p["bk"] = ctx.make(f"{prefix}.bk", (Hkv * hd,), zero=True)
        p["bv"] = ctx.make(f"{prefix}.bv", (Hkv * hd,), zero=True)
    return p


def gqa_forward(
    p: dict, cfg: ArchConfig, x: jax.Array, *,
    positions: jax.Array,                     # (B, S) absolute positions
    causal: bool = True,
    window: int = 0,
    mrope_positions: Optional[jax.Array] = None,   # (3, B, S)
    cache: Optional[dict] = None,             # {"k","v"}: (B, Smax, Hkv, hd)
    cache_index: Optional[jax.Array] = None,  # scalar write slot
) -> tuple[jax.Array, Optional[dict]]:
    B, S, D = x.shape
    hd, H, Hkv = cfg.hd, cfg.num_heads, cfg.num_kv_heads
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, Hkv, hd)
    v = v.reshape(B, S, Hkv, hd)

    if cfg.mrope_sections and mrope_positions is not None:
        q = apply_mrope(q, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        assert cache_index is not None
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, cache_index, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, cache_index, 0, 0))
        new_cache = {"k": ck, "v": cv}
        # causal with q_offset doubles as the valid-length mask: slots past
        # cache_index+S-1 hold stale data and are masked by q_pos >= k_pos.
        out = plain_attention(
            q, ck, cv, causal=True, q_offset=cache_index, window=window)
    else:
        out = attention_any(q, k, v, causal=causal, window=window)
    y = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, H * hd), p["wo"])
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA block (deepseek-v2): latent-compressed KV, absorbed decode
# ---------------------------------------------------------------------------


def init_mla(ctx: InitCtx, cfg: ArchConfig, prefix: str) -> dict:
    m = cfg.mla
    H, D = cfg.num_heads, cfg.d_model
    qk = m.qk_nope_dim + m.qk_rope_dim
    return {
        "wq": ctx.make(f"{prefix}.wq", (D, H * qk)),
        "w_dkv": ctx.make(f"{prefix}.w_dkv", (D, m.kv_lora_rank + m.qk_rope_dim)),
        "kv_norm": ctx.make(f"{prefix}.kv_norm", (m.kv_lora_rank,), scale="embed"),
        "w_uk": ctx.make(f"{prefix}.w_uk", (m.kv_lora_rank, H * m.qk_nope_dim)),
        "w_uv": ctx.make(f"{prefix}.w_uv", (m.kv_lora_rank, H * m.v_head_dim)),
        "wo": ctx.make(f"{prefix}.wo", (H * m.v_head_dim, D)),
    }


def mla_forward(
    p: dict, cfg: ArchConfig, x: jax.Array, *,
    positions: jax.Array,
    cache: Optional[dict] = None,      # {"latent": (B, Smax, lora+rope)}
    cache_index: Optional[jax.Array] = None,
) -> tuple[jax.Array, Optional[dict]]:
    from .common import rms_norm

    m = cfg.mla
    B, S, D = x.shape
    H = cfg.num_heads
    nope, rope, dv, lora = m.qk_nope_dim, m.qk_rope_dim, m.v_head_dim, m.kv_lora_rank

    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(B, S, H, nope + rope)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    dkv = jnp.einsum("bsd,dh->bsh", x, p["w_dkv"])        # (B,S,lora+rope)
    latent = rms_norm(dkv[..., :lora], p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(dkv[..., None, lora:], positions, cfg.rope_theta)  # (B,S,1,rope)

    if cache is None:
        # Train / prefill: decompress per head and run standard attention.
        k_nope = jnp.einsum("bsl,lh->bsh", latent, p["w_uk"]).reshape(B, S, H, nope)
        v = jnp.einsum("bsl,lh->bsh", latent, p["w_uv"]).reshape(B, S, H, dv)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, H, rope))], -1)
        qfull = jnp.concatenate([q_nope, q_rope], -1)
        out = attention_any(qfull, k, v, causal=True)
        y = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, H * dv), p["wo"])
        return y, None

    # Absorbed decode: attend in latent space; cache holds (latent ++ k_rope).
    assert cache_index is not None
    entry = jnp.concatenate([latent, k_rope[:, :, 0, :]], -1)  # (B,S,lora+rope)
    cl = jax.lax.dynamic_update_slice(
        cache["latent"], entry.astype(cache["latent"].dtype), (0, cache_index, 0))
    new_cache = {"latent": cl}
    c_lat, c_rope = cl[..., :lora], cl[..., lora:]
    w_uk = p["w_uk"].reshape(lora, H, nope)
    # fold k up-projection into q:  q_lat (B,S,H,lora)
    q_lat = jnp.einsum("bshn,lhn->bshl", q_nope, w_uk)
    scores = (
        jnp.einsum("bshl,btl->bhst", q_lat, c_lat)
        + jnp.einsum("bshr,btr->bhst", q_rope, c_rope)
    ).astype(jnp.float32) * (1.0 / jnp.sqrt(nope + rope))
    t_pos = jnp.arange(cl.shape[1])
    valid = t_pos[None, :] <= (cache_index + jnp.arange(S))[:, None]
    scores = jnp.where(valid[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx_lat = jnp.einsum("bhst,btl->bshl", probs, c_lat)       # (B,S,H,lora)
    w_uv = p["w_uv"].reshape(lora, H, dv)
    out = jnp.einsum("bshl,lhv->bshv", ctx_lat, w_uv)
    y = jnp.einsum("bsh,hd->bsd", out.reshape(B, S, H * dv), p["wo"])
    return y, new_cache


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder)
# ---------------------------------------------------------------------------


def init_cross(ctx: InitCtx, cfg: ArchConfig, prefix: str) -> dict:
    hd, H, D = cfg.hd, cfg.num_heads, cfg.d_model
    return {
        "wq": ctx.make(f"{prefix}.wq", (D, H * hd)),
        "wk": ctx.make(f"{prefix}.wk", (D, H * hd)),
        "wv": ctx.make(f"{prefix}.wv", (D, H * hd)),
        "wo": ctx.make(f"{prefix}.wo", (H * hd, D)),
    }


def cross_forward(p: dict, cfg: ArchConfig, x: jax.Array,
                  memory: jax.Array) -> jax.Array:
    """x: (B, S, D) decoder states; memory: (B, Se, D) encoder output."""
    B, S, D = x.shape
    Se = memory.shape[1]
    hd, H = cfg.hd, cfg.num_heads
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,dh->bsh", memory, p["wk"]).reshape(B, Se, H, hd)
    v = jnp.einsum("bsd,dh->bsh", memory, p["wv"]).reshape(B, Se, H, hd)
    out = plain_attention(q, k, v, causal=False, q_offset=0)
    return jnp.einsum("bsh,hd->bsd", out.reshape(B, S, H * hd), p["wo"])
