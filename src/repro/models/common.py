"""Shared model components: norms, MLPs, rotary embeddings (1D + M-RoPE),
initializers.  Pure-functional JAX; params are nested dicts of arrays.
"""

from __future__ import annotations

import dataclasses
import math
import zlib

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with f32 statistics but NO materialized f32 copy of x.

    The f32 conversion feeds only the (fused) variance reduction; the
    normalization itself runs in x.dtype with the per-row factor cast
    down.  Materializing x.astype(f32) gets hoisted out of remat loops by
    XLA and pins an f32 copy of every saved layer input (5 GiB/device on
    granite train_4k — see EXPERIMENTS.md §Perf).
    """
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * scale.astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    # two-pass (no E[x^2]-mu^2 cancellation), f32 row stats via fused
    # reductions, normalization in x.dtype (no materialized f32 copy of x)
    mu = jnp.mean(x.astype(jnp.float32), axis=-1, keepdims=True)
    d = x - mu.astype(x.dtype)
    var = jnp.mean(jnp.square(d.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return d * inv * scale.astype(x.dtype) + bias.astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    """SwiGLU MLP: down( silu(x@gate) * (x@up) )."""
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, w_gate))
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", g * u, w_down)


def gelu_mlp(x: jax.Array, w_in: jax.Array, b_in: jax.Array,
             w_out: jax.Array, b_out: jax.Array) -> jax.Array:
    h = jax.nn.gelu(jnp.einsum("...d,df->...f", x, w_in) + b_in, approximate=True)
    return jnp.einsum("...f,fd->...d", h, w_out) + b_out


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float = 10000.0) -> jax.Array:
    """(head_dim/2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """Rotary position embedding.

    x: (..., S, H, hd); positions: broadcastable to (..., S) int32.
    Rotate-half convention (LLaMA/Qwen/GLM style).
    """
    hd = x.shape[-1]
    inv = rope_frequencies(hd, theta)                       # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                         # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array,
                sections: tuple[int, ...], theta: float = 1000000.0) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL): the rotary half-dims are split into
    ``sections`` (temporal, height, width), each rotated by its own
    position stream.

    x: (B, S, H, hd); positions: (3, B, S) int32; sum(sections) == hd/2.
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    inv = rope_frequencies(hd, theta)                        # (hd/2,)
    # per-half-dim position stream index: 0,0,..,1,1,..,2,2,..
    stream = jnp.repeat(
        jnp.arange(len(sections)), jnp.array(sections), total_repeat_length=hd // 2
    )
    pos = positions.astype(jnp.float32)                      # (3, B, S)
    pos_per_dim = pos[stream]                                # (hd/2, B, S)
    ang = jnp.moveaxis(pos_per_dim, 0, -1) * inv             # (B, S, hd/2)
    cos = jnp.cos(ang)[..., None, :]                         # (B, S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class InitCtx:
    """Threaded through init functions: splits keys deterministically by path."""

    key: jax.Array
    dtype: jnp.dtype = jnp.bfloat16

    def make(self, path: str, shape: tuple[int, ...], *, scale: str = "fan_in",
             zero: bool = False) -> jax.Array:
        if zero:
            return jnp.zeros(shape, self.dtype)
        k = jax.random.fold_in(self.key, zlib.crc32(path.encode()) & 0x7FFFFFFF)
        if scale == "fan_in":
            std = 1.0 / math.sqrt(shape[0] if len(shape) >= 2 else shape[-1])
        elif scale == "embed":
            std = 1.0
        else:
            std = float(scale)
        return (jax.random.normal(k, shape, jnp.float32) * std).astype(self.dtype)

    def const(self, path: str, value) -> jax.Array:
        """A parameter with a fixed initial value (e.g. SSM A_log).
        Stacking adapters broadcast it across the layer axis."""
        return jnp.asarray(value)


def count_params(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))
