"""Model composition: decoder-only LMs (dense / MoE / SSM / hybrid), the
enc-dec (whisper) variant, caches, and the family dispatch used by
train/serve steps.

Layers are STACKED (leading num_layers axis) and executed with
``jax.lax.scan`` so the HLO contains one layer body regardless of depth —
essential for CPU-host compile times at 512 fake devices, and standard
practice on real TPM pods.  Training wraps the block in ``jax.checkpoint``
(remat) with a configurable policy.

Caches are pytrees stacked the same way and threaded through the scan as
(xs -> ys), so decode updates every layer's cache in one pass.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .attention import (
    cross_forward, gqa_forward, init_cross, init_gqa, init_mla, mla_forward,
)
from .common import InitCtx, layer_norm, rms_norm, swiglu, gelu_mlp
from .moe import init_moe, moe_forward
from .ssm import (
    init_mamba1, init_mamba2, mamba1_cache_spec, mamba1_forward,
    mamba2_cache_spec, mamba2_forward,
)


class _Stacked:
    """InitCtx adapter: every made param gets a leading (L,) stack axis."""

    def __init__(self, ctx: InitCtx, layers: int):
        self.ctx, self.L = ctx, layers
        self.dtype = ctx.dtype

    def make(self, path, shape, **kw):
        return self.ctx.make(path, (self.L, *shape), **kw)

    def const(self, path, value):
        v = jnp.asarray(value)
        return jnp.broadcast_to(v, (self.L, *v.shape)).copy()


# ---------------------------------------------------------------------------
# Per-family layer bodies.  Signature: (params, cfg, x, aux-inputs) -> x', cache'
# ---------------------------------------------------------------------------


def _mlp_params(ctx, cfg, prefix, d_ff):
    return {
        "w_gate": ctx.make(f"{prefix}.w_gate", (cfg.d_model, d_ff)),
        "w_up": ctx.make(f"{prefix}.w_up", (cfg.d_model, d_ff)),
        "w_down": ctx.make(f"{prefix}.w_down", (d_ff, cfg.d_model)),
    }


def _dense_layer_params(ctx, cfg: ArchConfig) -> dict:
    p = {
        "ln1": ctx.make("ln1", (cfg.d_model,), scale="embed"),
        "ln2": ctx.make("ln2", (cfg.d_model,), scale="embed"),
    }
    if cfg.mla:
        p["attn"] = init_mla(ctx, cfg, "attn")
    else:
        p["attn"] = init_gqa(ctx, cfg, "attn")
    if cfg.moe and not cfg.mla:  # uniform moe (qwen2-moe)
        p["mlp"] = init_moe(ctx, cfg, "moe")
    elif cfg.moe and cfg.mla:    # deepseek moe layers
        p["mlp"] = init_moe(ctx, cfg, "moe")
    else:
        p["mlp"] = _mlp_params(ctx, cfg, "mlp", cfg.d_ff)
    return p


def _dense_layer(p, cfg: ArchConfig, x, *, positions, mrope_positions=None,
                 cache=None, cache_index=None, window=0):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.mla:
        attn_out, new_cache = mla_forward(
            p["attn"], cfg, h, positions=positions, cache=cache,
            cache_index=cache_index)
    else:
        attn_out, new_cache = gqa_forward(
            p["attn"], cfg, h, positions=positions, causal=True, window=window,
            mrope_positions=mrope_positions, cache=cache,
            cache_index=cache_index)
    x = x + attn_out
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe:
        mlp_out, aux = moe_forward(p["mlp"], cfg, h)
    else:
        mlp_out = swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
    return x + mlp_out, new_cache, aux


def _ssm_layer(p, cfg: ArchConfig, x, *, cache=None):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    out, new_cache = mamba2_forward(p["mixer"], cfg, h, cache=cache)
    return x + out, new_cache, jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# Hybrid (jamba) period: 7 mamba1 sublayers + 1 attention, MoE every other.
# ---------------------------------------------------------------------------


def _jamba_period_params(ctx_outer: InitCtx, cfg: ArchConfig, n_periods: int):
    hyb = cfg.hybrid
    per = hyb.period
    n_mamba = per - 1
    sctx = _Stacked(ctx_outer, n_periods)

    def stack2(path, shape, inner, **kw):
        return ctx_outer.make(path, (n_periods, inner, *shape), **kw)

    class _S2:
        """Stack (n_periods, inner) leading axes."""
        def __init__(self, inner):
            self.inner = inner
            self.dtype = ctx_outer.dtype
        def make(self, path, shape, **kw):
            return stack2(path, shape, self.inner, **kw)
        def const(self, path, value):
            v = jnp.asarray(value)
            return jnp.broadcast_to(v, (n_periods, self.inner, *v.shape)).copy()

    mctx = _S2(n_mamba)
    p = {
        "mamba": {
            "mixer": init_mamba1(mctx, cfg, "mamba.mixer"),
            "ln": mctx.make("mamba.ln", (cfg.d_model,), scale="embed"),
        },
        "attn": {
            "attn": init_gqa(sctx, cfg, "attn"),
            "ln": sctx.make("attn.ln", (cfg.d_model,), scale="embed"),
        },
    }
    # FFN after every sublayer: MoE on odd in-period index, dense on even.
    n_moe = per // 2
    n_dense = per - n_moe
    dctx, ectx = _S2(n_dense), _S2(n_moe)
    p["dense_ffn"] = {
        **_mlp_params(dctx, cfg, "ffn", cfg.d_ff),
        "ln": dctx.make("ffn.ln", (cfg.d_model,), scale="embed"),
    }
    p["moe_ffn"] = {
        **init_moe(ectx, cfg, "moe"),
        "ln": ectx.make("moe.ln", (cfg.d_model,), scale="embed"),
    }
    return p


def _jamba_period(p, cfg: ArchConfig, x, *, positions, caches, cache_index,
                  window):
    """One period of `period` sublayers.  ``caches`` may be None (train)."""
    hyb = cfg.hybrid
    per, attn_idx = hyb.period, hyb.attn_index
    new_attn_cache = None
    new_mamba_caches = []
    aux_total = jnp.zeros((), jnp.float32)
    mi = di = ei = 0
    for l in range(per):
        if l == attn_idx:
            ap = p["attn"]
            h = rms_norm(x, ap["ln"], cfg.norm_eps)
            cache = None if caches is None else caches["attn"]
            out, new_attn_cache = gqa_forward(
                ap["attn"], cfg, h, positions=positions, causal=True,
                window=window, cache=cache, cache_index=cache_index)
            x = x + out
        else:
            mp = jax.tree.map(lambda a: a[mi], p["mamba"])
            h = rms_norm(x, mp["ln"], cfg.norm_eps)
            cache = None if caches is None else \
                jax.tree.map(lambda a: a[mi], caches["mamba"])
            out, nc = mamba1_forward(mp["mixer"], cfg, h, cache=cache)
            if nc is not None:
                new_mamba_caches.append(nc)
            x = x + out
            mi += 1
        if l % 2 == 1:  # MoE
            fp = jax.tree.map(lambda a: a[ei], p["moe_ffn"])
            h = rms_norm(x, fp["ln"], cfg.norm_eps)
            out, aux = moe_forward({k: v for k, v in fp.items() if k != "ln"},
                                   cfg, h)
            aux_total = aux_total + aux
            x = x + out
            ei += 1
        else:
            fp = jax.tree.map(lambda a: a[di], p["dense_ffn"])
            h = rms_norm(x, fp["ln"], cfg.norm_eps)
            x = x + swiglu(h, fp["w_gate"], fp["w_up"], fp["w_down"])
            di += 1
    new_caches = None
    if caches is not None:
        new_caches = {
            "attn": new_attn_cache,
            "mamba": jax.tree.map(lambda *xs: jnp.stack(xs), *new_mamba_caches),
        }
    return x, new_caches, aux_total


# ---------------------------------------------------------------------------
# Top-level LM
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RematPolicy:
    enabled: bool = True
    policy: str = "nothing_saveable"   # or dots_with_no_batch_dims_saveable
    # sqrt-remat: scan groups of G layers inside a scan of L/G groups, both
    # checkpointed -> live saved activations ~ (L/G + G) instead of L.
    # 0 = auto (largest divisor of L <= sqrt(L)); 1 = flat scan.
    scan_group: int = 0

    def wrap(self, fn):
        if not self.enabled:
            return fn
        pol = getattr(jax.checkpoint_policies, self.policy, None)
        return jax.checkpoint(fn, policy=pol)

    def group_for(self, L: int) -> int:
        if not self.enabled:
            return 1
        if self.scan_group:
            return self.scan_group if L % self.scan_group == 0 else 1
        g = int(math.isqrt(L))
        while g > 1 and L % g:
            g -= 1
        return g


def _maybe_constrain_layer(lp, specs):
    """FSDP per-layer unshard: re-pin each SLICED layer's params to their
    TP-only sharding (the 'data' axis dropped).  Without this, XLA
    partitions scan slicing as gather-the-whole-stack-inside-the-loop:
    the 72B train cell moved 12 TiB/device/step of all-reduce+gather
    before this constraint (EXPERIMENTS.md §Perf iteration 1)."""
    if specs is None:
        return lp
    return jax.tree.map(
        lambda a, s: jax.lax.with_sharding_constraint(a, s) if s is not None else a,
        lp, specs, is_leaf=lambda q: q is None)


def scan_layers_remat(block, x, stacked, remat: "RematPolicy",
                      layer_specs=None, act_spec=None):
    """Scan ``block`` over stacked layer params with sqrt-remat grouping.
    block: (x, layer_params) -> (x, y).  Returns (x, ys) with ys flat (L, ...).
    layer_specs: optional pytree of PartitionSpecs (per SLICED layer leaf)
    applied inside the loop body (FSDP per-layer gather).
    act_spec: optional PartitionSpec pinning the residual-stream carry at
    every block entry — without it, FSDP weight shardings pull XLA into
    batch-replicated partial-sum activations (the 12 TiB/step all-reduce
    pathology, §Perf iteration 2)."""
    L = jax.tree.leaves(stacked)[0].shape[0]
    G = remat.group_for(L)

    def cblock(x, lp):
        if act_spec is not None:
            x = jax.lax.with_sharding_constraint(x, act_spec)
        return block(x, _maybe_constrain_layer(lp, layer_specs))

    if G <= 1 or L % G:
        return jax.lax.scan(remat.wrap(cblock), x, stacked)
    grouped = jax.tree.map(
        lambda a: a.reshape(L // G, G, *a.shape[1:]), stacked)

    def group_block(x, gp):
        return jax.lax.scan(remat.wrap(cblock), x, gp)

    x, ys = jax.lax.scan(remat.wrap(group_block), x, grouped)
    ys = jax.tree.map(lambda a: a.reshape(L, *a.shape[2:]), ys)
    return x, ys


def init_lm(cfg: ArchConfig, key: jax.Array) -> dict:
    ctx = InitCtx(key=key, dtype=cfg.param_dtype())
    params: dict[str, Any] = {
        "embed": ctx.make("embed", (cfg.vocab, cfg.d_model), scale=0.02),
        "final_norm": ctx.make("final_norm", (cfg.d_model,), scale="embed"),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = ctx.make("lm_head", (cfg.d_model, cfg.vocab))

    if cfg.family == "hybrid":
        n_periods = cfg.num_layers // cfg.hybrid.period
        params["periods"] = _jamba_period_params(ctx, cfg, n_periods)
    elif cfg.family == "ssm":
        sctx = _Stacked(ctx, cfg.num_layers)
        params["layers"] = {
            "mixer": init_mamba2(sctx, cfg, "mixer"),
            "ln1": sctx.make("ln1", (cfg.d_model,), scale="embed"),
        }
    elif cfg.family == "encdec":
        ec = cfg.encdec
        ectx = _Stacked(ctx, ec.num_encoder_layers)
        params["encoder"] = {
            "attn": init_gqa(ectx, cfg, "enc.attn"),
            "mlp": {
                "w_in": ectx.make("enc.w_in", (cfg.d_model, cfg.d_ff)),
                "b_in": ectx.make("enc.b_in", (cfg.d_ff,), zero=True),
                "w_out": ectx.make("enc.w_out", (cfg.d_ff, cfg.d_model)),
                "b_out": ectx.make("enc.b_out", (cfg.d_model,), zero=True),
            },
            "ln1": ectx.make("enc.ln1", (cfg.d_model,), scale="embed"),
            "ln1b": ectx.make("enc.ln1b", (cfg.d_model,), zero=True),
            "ln2": ectx.make("enc.ln2", (cfg.d_model,), scale="embed"),
            "ln2b": ectx.make("enc.ln2b", (cfg.d_model,), zero=True),
        }
        dctx = _Stacked(ctx, cfg.num_layers)
        params["layers"] = {
            "attn": init_gqa(dctx, cfg, "dec.attn"),
            "cross": init_cross(dctx, cfg, "dec.cross"),
            "mlp": {
                "w_in": dctx.make("dec.w_in", (cfg.d_model, cfg.d_ff)),
                "b_in": dctx.make("dec.b_in", (cfg.d_ff,), zero=True),
                "w_out": dctx.make("dec.w_out", (cfg.d_ff, cfg.d_model)),
                "b_out": dctx.make("dec.b_out", (cfg.d_model,), zero=True),
            },
            "ln1": dctx.make("dec.ln1", (cfg.d_model,), scale="embed"),
            "ln1b": dctx.make("dec.ln1b", (cfg.d_model,), zero=True),
            "lnx": dctx.make("dec.lnx", (cfg.d_model,), scale="embed"),
            "lnxb": dctx.make("dec.lnxb", (cfg.d_model,), zero=True),
            "ln2": dctx.make("dec.ln2", (cfg.d_model,), scale="embed"),
            "ln2b": dctx.make("dec.ln2b", (cfg.d_model,), zero=True),
        }
        params["enc_final_norm_b"] = ctx.make("efnb", (cfg.d_model,), zero=True)
        params["final_norm_b"] = ctx.make("fnb", (cfg.d_model,), zero=True)
    else:  # dense / moe / vlm — uniform layers, maybe an unrolled first layer
        first_dense = cfg.moe.first_dense_layers if cfg.moe else 0
        if first_dense:
            dense_cfg = dataclasses.replace(cfg, moe=None)
            params["layer0"] = _dense_layer_params(ctx, dense_cfg)
        sctx = _Stacked(ctx, cfg.num_layers - first_dense)
        params["layers"] = _dense_layer_params(sctx, cfg)
    return params


def _embed(params, cfg, batch):
    if "embeds" in batch:
        x = batch["embeds"].astype(cfg.param_dtype())
    else:
        x = params["embed"][batch["tokens"]]
    return x


def _unembed(params, cfg, x):
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, params["embed"])
    return jnp.einsum("bsd,dv->bsv", x, params["lm_head"])


def _run_encoder(params, cfg, enc_embeds, remat: "RematPolicy"):
    """Whisper encoder over stub frame embeddings (B, Se, D)."""
    x = enc_embeds.astype(cfg.param_dtype())
    Se = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(Se), x.shape[:2])

    def block(x, lp):
        h = layer_norm(x, lp["ln1"], lp["ln1b"], cfg.norm_eps)
        out, _ = gqa_forward(lp["attn"], cfg, h, positions=positions,
                             causal=False)
        x = x + out
        h = layer_norm(x, lp["ln2"], lp["ln2b"], cfg.norm_eps)
        x = x + gelu_mlp(h, lp["mlp"]["w_in"], lp["mlp"]["b_in"],
                         lp["mlp"]["w_out"], lp["mlp"]["b_out"])
        return x, jnp.zeros((), jnp.float32)

    x, _ = scan_layers_remat(block, x, params["encoder"], remat)
    return layer_norm(x, params["final_norm"], params["enc_final_norm_b"],
                      cfg.norm_eps)


def lm_forward(
    params: dict, cfg: ArchConfig, batch: dict, *,
    remat: RematPolicy = RematPolicy(),
    caches: Optional[dict] = None,
    cache_index: Optional[jax.Array] = None,
    window_override: Optional[int] = None,
    last_only: bool = False,
    layer_specs=None,
    act_spec=None,
) -> tuple[jax.Array, Optional[dict], jax.Array]:
    """Returns (logits (B,S,V), new_caches | None, moe_aux).
    last_only: unembed only the final position (prefill serving)."""
    x = _embed(params, cfg, batch)
    if act_spec is not None:
        x = jax.lax.with_sharding_constraint(x, act_spec)
    B, S = x.shape[:2]
    if cache_index is not None:
        positions = jnp.broadcast_to(cache_index + jnp.arange(S), (B, S))
    else:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    mrope_positions = batch.get("mrope_positions")
    window = window_override if window_override is not None else 0

    new_caches = None
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.family == "hybrid":
        if caches is None:
            def tblock(x, lp):
                x, _, aux = _jamba_period(
                    lp, cfg, x, positions=positions, caches=None,
                    cache_index=None, window=window)
                return x, aux
            x, auxs = scan_layers_remat(tblock, x, params["periods"], remat,
                                        layer_specs=layer_specs,
                                        act_spec=act_spec)
            aux_total = auxs.sum()
        else:
            def block(x, xs):
                lp, lc = xs
                x, nc, aux = _jamba_period(
                    lp, cfg, x, positions=positions, caches=lc,
                    cache_index=cache_index, window=window)
                return x, (nc, aux)
            x, (new_caches, auxs) = jax.lax.scan(
                block, x, (params["periods"], caches))
            aux_total = auxs.sum()

    elif cfg.family == "ssm":
        def block(x, xs):
            lp, lc = xs
            x, nc, aux = _ssm_layer(lp, cfg, x, cache=lc)
            return x, nc

        if caches is None:
            def tblock(x, lp):
                x, _, _ = _ssm_layer(lp, cfg, x, cache=None)
                return x, jnp.zeros((), jnp.float32)
            x, _ = scan_layers_remat(tblock, x, params["layers"], remat,
                                     layer_specs=layer_specs,
                                     act_spec=act_spec)
        else:
            x, new_caches = jax.lax.scan(block, x, (params["layers"], caches))

    elif cfg.family == "encdec":
        memory = batch.get("enc_memory")
        if memory is None:
            memory = _run_encoder(params, cfg, batch["enc_embeds"], remat)

        def block(x, xs):
            lp, lc = xs
            h = layer_norm(x, lp["ln1"], lp["ln1b"], cfg.norm_eps)
            out, nc = gqa_forward(lp["attn"], cfg, h, positions=positions,
                                  causal=True, cache=lc,
                                  cache_index=cache_index)
            x = x + out
            h = layer_norm(x, lp["lnx"], lp["lnxb"], cfg.norm_eps)
            x = x + cross_forward(lp["cross"], cfg, h, memory)
            h = layer_norm(x, lp["ln2"], lp["ln2b"], cfg.norm_eps)
            x = x + gelu_mlp(h, lp["mlp"]["w_in"], lp["mlp"]["b_in"],
                             lp["mlp"]["w_out"], lp["mlp"]["b_out"])
            return x, nc

        if caches is None:
            def tblock(x, lp):
                x, _ = block(x, (lp, None))
                return x, jnp.zeros((), jnp.float32)
            x, _ = scan_layers_remat(tblock, x, params["layers"], remat,
                                     layer_specs=layer_specs,
                                     act_spec=act_spec)
        else:
            x, new_caches = jax.lax.scan(block, x, (params["layers"], caches))

    else:  # dense / moe / vlm
        first_dense = cfg.moe.first_dense_layers if cfg.moe else 0
        layer0_cache = None
        if first_dense:
            dense_cfg = dataclasses.replace(cfg, moe=None)
            lc0 = None if caches is None else caches["layer0"]
            x, layer0_cache, _ = _dense_layer(
                params["layer0"], dense_cfg, x, positions=positions,
                mrope_positions=mrope_positions, cache=lc0,
                cache_index=cache_index, window=window)

        def block(x, xs):
            lp, lc = xs
            x, nc, aux = _dense_layer(
                lp, cfg, x, positions=positions,
                mrope_positions=mrope_positions, cache=lc,
                cache_index=cache_index, window=window)
            return x, (nc, aux)

        if caches is None:
            def tblock(x, lp):
                x, _, aux = _dense_layer(
                    lp, cfg, x, positions=positions,
                    mrope_positions=mrope_positions, cache=None,
                    cache_index=None, window=window)
                return x, aux
            x, auxs = scan_layers_remat(tblock, x, params["layers"], remat,
                                        layer_specs=layer_specs,
                                        act_spec=act_spec)
            aux_total = auxs.sum()
        else:
            stack_caches = caches["layers"] if first_dense else caches
            x, (nc, auxs) = jax.lax.scan(block, x, (params["layers"], stack_caches))
            aux_total = auxs.sum()
            new_caches = {"layer0": layer0_cache, "layers": nc} if first_dense else nc

    if act_spec is not None:
        x = jax.lax.with_sharding_constraint(x, act_spec)
    if last_only:
        x = x[:, -1:, :]
    if cfg.family == "encdec":
        x = layer_norm(x, params["final_norm"], params["final_norm_b"],
                       cfg.norm_eps)
    else:
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _unembed(params, cfg, x)
    return logits, new_caches, aux_total


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------


def cache_specs(cfg: ArchConfig, batch: int, max_len: int) -> Any:
    """Pytree of (shape, dtype) describing the decode cache."""
    hd, Hkv = cfg.hd, cfg.num_kv_heads

    def attn_spec():
        dt = cfg.param_dtype()
        return {
            "k": ((batch, max_len, Hkv, hd), dt),
            "v": ((batch, max_len, Hkv, hd), dt),
        }

    if cfg.family == "hybrid":
        n_periods = cfg.num_layers // cfg.hybrid.period
        n_mamba = cfg.hybrid.period - 1
        m = mamba1_cache_spec(cfg, batch)
        return {
            "attn": {k: ((n_periods, *s), d) for k, (s, d) in attn_spec().items()},
            "mamba": {k: ((n_periods, n_mamba, *s), d) for k, (s, d) in m.items()},
        }
    if cfg.family == "ssm":
        m = mamba2_cache_spec(cfg, batch)
        return {k: ((cfg.num_layers, *s), d) for k, (s, d) in m.items()}
    if cfg.mla:
        lora = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim
        spec = {"latent": ((batch, max_len, lora), cfg.param_dtype())}
        first_dense = cfg.moe.first_dense_layers if cfg.moe else 0
        stacked = {k: ((cfg.num_layers - first_dense, *s), d)
                   for k, (s, d) in spec.items()}
        if first_dense:
            return {"layer0": spec, "layers": stacked}
        return stacked
    if cfg.family == "encdec":
        # cross-attn K/V are recomputed from enc_memory each step (memory is
        # an input to serve_step); only decoder self-attn KV is cached.
        return {k: ((cfg.num_layers, *s), d) for k, (s, d) in attn_spec().items()}
    return {k: ((cfg.num_layers, *s), d) for k, (s, d) in attn_spec().items()}


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> Any:
    return jax.tree.map(
        lambda sd: jnp.zeros(sd[0], sd[1]), cache_specs(cfg, batch, max_len),
        is_leaf=lambda x: isinstance(x, tuple) and isinstance(x[0], tuple),
    )
