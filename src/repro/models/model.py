"""Public model API used by train/serve steps, examples, and the dry-run."""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .lm import RematPolicy, cache_specs, init_cache, init_lm, lm_forward


@jax.custom_vjp
def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token CE (f32 math, bf16-resident).  logits: (B,S,V) bf16;
    labels: (B,S) int32.

    custom_vjp keeps the saved residual AND the logits cotangent in the
    logits dtype: the default AD path materializes 3-4 f32 copies of the
    (tokens, vocab) tensor (12 GiB/device at 49k vocab), which dominated
    the train-step memory roofline.  See EXPERIMENTS.md §Perf iteration 1.
    """
    loss, _ = _ce_fwd(logits, labels)
    return loss


def _ce_stats(logits, labels):
    lf = logits.astype(jnp.float32)
    m = jnp.max(lf, axis=-1, keepdims=True)
    sumexp = jnp.sum(jnp.exp(lf - m), axis=-1)
    lse = m[..., 0] + jnp.log(sumexp)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return lse, ll, m[..., 0], sumexp


def _ce_fwd(logits, labels):
    lse, ll, m, sumexp = _ce_stats(logits, labels)
    loss = jnp.mean(lse - ll)
    return loss, (logits, labels, m, sumexp)


def _ce_bwd(res, g):
    logits, labels, m, sumexp = res
    lf = logits.astype(jnp.float32)
    p = jnp.exp(lf - m[..., None]) / sumexp[..., None]
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    n_tokens = labels.size
    dlogits = ((g / n_tokens) * (p - onehot)).astype(logits.dtype)
    return dlogits, None


cross_entropy.defvjp(_ce_fwd, _ce_bwd)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    remat: RematPolicy = RematPolicy()
    moe_aux_weight: float = 0.01
    # FSDP per-layer unshard specs for the scanned stack (see
    # lm.scan_layers_remat); None = no constraint.
    layer_specs: object = None
    # PartitionSpec pinning the residual stream (batch-sharded) at every
    # scanned block entry; None = let XLA propagate.
    act_spec: object = None

    # -- parameters --------------------------------------------------------
    def init(self, key: jax.Array) -> dict:
        return init_lm(self.cfg, key)

    # -- training ----------------------------------------------------------
    def loss(self, params: dict, batch: dict) -> tuple[jax.Array, dict]:
        logits, _, aux = lm_forward(params, self.cfg, batch, remat=self.remat,
                                    layer_specs=self.layer_specs,
                                    act_spec=self.act_spec)
        ce = cross_entropy(logits, batch["labels"])
        loss = ce + self.moe_aux_weight * aux
        return loss, {"ce": ce, "moe_aux": aux}

    # -- inference ---------------------------------------------------------
    def prefill(self, params: dict, batch: dict, *, last_only: bool = False) -> jax.Array:
        """Full-sequence forward, returns logits (B, S, V) — or (B, 1, V)
        with last_only (serving: only the next-token distribution is
        needed, skipping the (tokens x vocab) unembed)."""
        logits, _, _ = lm_forward(
            params, self.cfg, batch, remat=RematPolicy(enabled=False),
            last_only=last_only)
        return logits

    def decode_step(
        self, params: dict, caches: Any, batch: dict,
        cache_index: jax.Array, *, window: Optional[int] = None,
    ) -> tuple[jax.Array, Any]:
        """One decode step.  batch["tokens"]: (B, 1).  Returns (logits
        (B, 1, V), updated caches)."""
        win = window
        if win is None and self.cfg.sliding_window:
            win = self.cfg.sliding_window
        logits, new_caches, _ = lm_forward(
            params, self.cfg, batch, caches=caches, cache_index=cache_index,
            remat=RematPolicy(enabled=False), window_override=win or 0)
        return logits, new_caches

    # -- caches --------------------------------------------------------
    def cache_specs(self, batch: int, max_len: int):
        return cache_specs(self.cfg, batch, max_len)

    def init_cache(self, batch: int, max_len: int):
        return init_cache(self.cfg, batch, max_len)
