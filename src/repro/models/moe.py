"""Mixture-of-Experts: softmax top-k router + sort-based dispatch.

Dispatch is *batch-local*: routing/sorting/scatter happen independently
per batch row (vmapped), so under pjit with batch sharded over
('pod','data') the entire dispatch partitions cleanly with zero extra
collectives — expert weights are TP-sharded over 'model' on the expert
FFN width, so the only communication is the usual TP all-reduce.
(Expert-parallel all-to-all dispatch is a hillclimb variant; see
EXPERIMENTS.md §Perf.)

FLOP profile matches a real top-k MoE: expert compute is
~ tokens * top_k * capacity_factor * 3 * 2 * D * F, not num_experts-dense.
Capacity overflow tokens are dropped (standard GShard semantics); the
router returns a load-balancing aux loss (Switch-style).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import InitCtx


def init_moe(ctx: InitCtx, cfg: ArchConfig, prefix: str) -> dict:
    e = cfg.moe
    D, F, E = cfg.d_model, e.d_ff_expert, e.num_experts
    p = {
        "router": ctx.make(f"{prefix}.router", (D, E)),
        "w_gate": ctx.make(f"{prefix}.w_gate", (E, D, F)),
        "w_up": ctx.make(f"{prefix}.w_up", (E, D, F)),
        "w_down": ctx.make(f"{prefix}.w_down", (E, F, D)),
    }
    if e.num_shared:
        Fs = e.num_shared * F
        p["shared"] = {
            "w_gate": ctx.make(f"{prefix}.shared.w_gate", (D, Fs)),
            "w_up": ctx.make(f"{prefix}.shared.w_up", (D, Fs)),
            "w_down": ctx.make(f"{prefix}.shared.w_down", (Fs, D)),
        }
    return p


def _dispatch_one_row(xf, logits, top_k: int, capacity: int, num_experts: int):
    """Sort-based dispatch for one batch row.

    xf: (T, D); logits: (T, E).  Returns (buf (E, C, D), combine closure
    inputs).  Pure gather/scatter — no (T, E, C) one-hot einsums.
    """
    T = xf.shape[0]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, idx = jax.lax.top_k(probs, top_k)              # (T, k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)

    fe = idx.reshape(-1)                                     # (T*k,) expert ids
    ft = jnp.repeat(jnp.arange(T), top_k)                    # token ids
    fw = weights.reshape(-1)
    order = jnp.argsort(fe, stable=True)
    se, st, sw = fe[order], ft[order], fw[order]
    starts = jnp.searchsorted(se, jnp.arange(num_experts), side="left")
    pos = jnp.arange(T * top_k) - starts[se]                 # rank within expert
    keep = pos < capacity

    buf = jnp.zeros((num_experts, capacity, xf.shape[1]), xf.dtype)
    buf = buf.at[se, pos].set(xf[st], mode="drop")
    return buf, (se, st, sw, pos, keep)


def _constrain_if_meshed(x, spec):
    """with_sharding_constraint only when a mesh with a 'model' axis is
    ambient (no-op in mesh-less CPU tests)."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty or "model" not in mesh.shape:
            return x
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


def _combine_one_row(out_buf, meta, T: int):
    se, st, sw, pos, keep = meta
    vals = out_buf.at[se, pos].get(mode="fill", fill_value=0)  # (T*k, D)
    w = (sw * keep.astype(sw.dtype)).astype(out_buf.dtype)
    y = jnp.zeros((T, out_buf.shape[-1]), out_buf.dtype)
    return y.at[st].add(vals * w[:, None])


def moe_forward(p: dict, cfg: ArchConfig, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y, aux_loss).  Batch-local dispatch (vmap over B).

    impl="ep" pins the (B, E, C, D) expert buffers to the 'model' axis on
    E (full-width experts, expert-parallel); the cross-shard
    gather/scatter XLA emits is the MoE all-to-all exchange."""
    e = cfg.moe
    B, S, D = x.shape
    T, E, k = S, e.num_experts, e.top_k
    capacity = max(1, math.ceil(T * k / E * e.capacity_factor))
    ep = getattr(e, "impl", "tp") == "ep"
    U = jax.sharding.PartitionSpec.UNCONSTRAINED

    logits = jnp.einsum("bsd,de->bse", x, p["router"]).astype(jnp.float32)

    def dispatch_row(xf, lg):
        return _dispatch_one_row(xf, lg, k, capacity, E)

    buf, meta = jax.vmap(dispatch_row)(x, logits)          # (B,E,C,D), metas
    if ep:
        buf = _constrain_if_meshed(
            buf, jax.sharding.PartitionSpec(U, "model", U, U))
    g = jnp.einsum("becd,edf->becf", buf, p["w_gate"])
    u = jnp.einsum("becd,edf->becf", buf, p["w_up"])
    h = jax.nn.silu(g) * u
    out = jnp.einsum("becf,efd->becd", h, p["w_down"])
    if ep:
        out = _constrain_if_meshed(
            out, jax.sharding.PartitionSpec(U, "model", U, U))

    y = jax.vmap(lambda o, *m: _combine_one_row(o, m, T))(out, *meta)

    # Switch-style load-balance aux: E * sum_e f_e * p_e
    probs = jax.nn.softmax(logits, axis=-1)                    # (B,S,E)
    top1 = jnp.argmax(logits, axis=-1)
    f = jnp.mean(jax.nn.one_hot(top1, E, dtype=jnp.float32), axis=(0, 1))
    pbar = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(f * pbar)

    if "shared" in p:
        sp = p["shared"]
        g = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, sp["w_gate"]))
        u = jnp.einsum("bsd,df->bsf", x, sp["w_up"])
        y = y + jnp.einsum("bsf,fd->bsd", g * u, sp["w_down"])
    return y.astype(x.dtype), aux
