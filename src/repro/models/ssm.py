"""State-space layers: Mamba-2 SSD (chunked, for mamba2-1.3b) and Mamba-1
selective scan (for jamba), each with a train/prefill form and an O(1)
decode step.

Projections are kept as SEPARATE weights (w_z / w_x / w_B / w_C / w_dt)
rather than one packed in_proj: the packed layout cannot be tensor-
parallel-sharded without cutting across components, while the unpacked
form shards cleanly — d_inner (and SSD heads) over 'model', B/C/dt small
and replicated, out_proj row-parallel with the usual all-reduce.

SSD chunked algorithm (Dao & Gu 2024): split the sequence into chunks of
Q tokens; within a chunk the recurrence is a masked quadratic form
(MXU-friendly); across chunks a small (H, d_state, head_dim) state is
carried by a scan.  All decays are exp(negative cumsums) so everything
stays <= 1.  kernels/ssd is the Pallas version of the intra-chunk part;
this module is the XLA twin + oracle.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .common import InitCtx, rms_norm


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along seq.  x: (B, S, C); w: (K, C); b: (C,)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    S = x.shape[1]
    out = sum(xp[:, k : k + S, :] * w[k] for k in range(K))
    return out + b


def _conv_step(state: jax.Array, x_t: jax.Array, w: jax.Array,
               b: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Single-token conv.  state: (B, K-1, C) last inputs; x_t: (B, 1, C)."""
    window = jnp.concatenate([state, x_t], axis=1)        # (B, K, C)
    y = jnp.einsum("bkc,kc->bc", window, w) + b
    return window[:, 1:, :], y[:, None, :]


# ---------------------------------------------------------------------------
# Mamba-2 (SSD)
# ---------------------------------------------------------------------------


def init_mamba2(ctx: InitCtx, cfg: ArchConfig, prefix: str) -> dict:
    s = cfg.ssm
    D = cfg.d_model
    d_inner = s.expand * D
    H = d_inner // s.head_dim
    N = s.d_state
    return {
        "w_z": ctx.make(f"{prefix}.w_z", (D, d_inner)),
        "w_x": ctx.make(f"{prefix}.w_x", (D, d_inner)),
        "w_B": ctx.make(f"{prefix}.w_B", (D, N)),
        "w_C": ctx.make(f"{prefix}.w_C", (D, N)),
        "w_dt": ctx.make(f"{prefix}.w_dt", (D, H)),
        "conv_x_w": ctx.make(f"{prefix}.conv_x_w", (s.d_conv, d_inner), scale=0.3),
        "conv_x_b": ctx.make(f"{prefix}.conv_x_b", (d_inner,), zero=True),
        "conv_B_w": ctx.make(f"{prefix}.conv_B_w", (s.d_conv, N), scale=0.3),
        "conv_B_b": ctx.make(f"{prefix}.conv_B_b", (N,), zero=True),
        "conv_C_w": ctx.make(f"{prefix}.conv_C_w", (s.d_conv, N), scale=0.3),
        "conv_C_b": ctx.make(f"{prefix}.conv_C_b", (N,), zero=True),
        "A_log": ctx.const(f"{prefix}.A_log",
                           jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32)),
        "D": ctx.const(f"{prefix}.D", jnp.ones((H,), jnp.float32)),
        "dt_bias": ctx.const(f"{prefix}.dt_bias", jnp.zeros((H,), jnp.float32)),
        "norm": ctx.make(f"{prefix}.norm", (d_inner,), scale="embed"),
        "out_proj": ctx.make(f"{prefix}.out_proj", (d_inner, D)),
    }


def ssd_chunked(x, dt, A, Bm, Cm, *, chunk: int, state_in=None):
    """SSD scan.  x: (B, S, H, hd); dt: (B, S, H); A: (H,) negative;
    Bm/Cm: (B, S, N).  Returns (y: (B,S,H,hd), state_out: (B,H,N,hd))."""
    Bsz, S, H, hd = x.shape
    N = Bm.shape[-1]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = x.shape[1]
    nc = Sp // chunk
    xc = x.reshape(Bsz, nc, chunk, H, hd)
    dtc = dt.reshape(Bsz, nc, chunk, H).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, nc, chunk, N)
    Cc = Cm.reshape(Bsz, nc, chunk, N)

    a = dtc * A  # (B,nc,Q,H), negative
    cum = jnp.cumsum(a, axis=2)
    # intra-chunk quadratic form.  Mask the exponent BEFORE exp: above the
    # diagonal cum_i - cum_j > 0 and exp overflows to inf, whose masked-out
    # cotangent is 0 * inf = NaN (see tests/test_models.py::test_ssd_grads).
    dcum = cum[:, :, :, None, :] - cum[:, :, None, :, :]           # (B,nc,i,j,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    Lmat = jnp.exp(jnp.where(tri[None, None, :, :, None], dcum, -1e30))
    scores = jnp.einsum("bcin,bcjn->bcij", Cc.astype(jnp.float32),
                        Bc.astype(jnp.float32))
    w = scores[..., None] * Lmat * dtc[:, :, None, :, :]           # (B,nc,i,j,H)
    y_intra = jnp.einsum("bcijh,bcjhd->bcihd", w.astype(x.dtype), xc)

    # chunk-local states and inter-chunk scan
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)                # (B,nc,Q,H)
    Sloc = jnp.einsum("bcjn,bcjh,bcjhd->bchnd",
                      Bc.astype(jnp.float32), (dtc * decay_to_end),
                      xc.astype(jnp.float32))                      # (B,nc,H,N,hd)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                        # (B,nc,H)

    def step(S_carry, inp):
        Sloc_c, dec_c = inp
        S_new = dec_c[..., None, None] * S_carry + Sloc_c
        return S_new, S_carry                                      # emit state BEFORE chunk

    S0 = (jnp.zeros((Bsz, H, N, hd), jnp.float32) if state_in is None
          else state_in.astype(jnp.float32))
    S_out, states_prev = jax.lax.scan(
        step, S0,
        (Sloc.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    states_prev = states_prev.transpose(1, 0, 2, 3, 4)             # (B,nc,H,N,hd)

    y_inter = jnp.einsum("bcin,bcih,bchnd->bcihd",
                         Cc.astype(jnp.float32), jnp.exp(cum), states_prev)
    y = (y_intra.astype(jnp.float32) + y_inter).reshape(Bsz, Sp, H, hd)
    if pad:
        y = y[:, :S]
    return y.astype(x.dtype), S_out


def mamba2_forward(p: dict, cfg: ArchConfig, xin: jax.Array, *,
                   cache: dict | None = None):
    """xin: (B, S, D).  cache (decode): {"conv_x","conv_B","conv_C","state"}."""
    s = cfg.ssm
    B, S, D = xin.shape
    d_inner = s.expand * D
    H = d_inner // s.head_dim

    z = jnp.einsum("bsd,di->bsi", xin, p["w_z"])
    x_raw = jnp.einsum("bsd,di->bsi", xin, p["w_x"])
    B_raw = jnp.einsum("bsd,dn->bsn", xin, p["w_B"])
    C_raw = jnp.einsum("bsd,dn->bsn", xin, p["w_C"])
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", xin, p["w_dt"]).astype(jnp.float32)
        + p["dt_bias"])                                             # (B,S,H)
    A = -jnp.exp(p["A_log"])

    new_cache = None
    if cache is None:
        xs = jax.nn.silu(_causal_conv(x_raw, p["conv_x_w"], p["conv_x_b"]))
        Bm = jax.nn.silu(_causal_conv(B_raw, p["conv_B_w"], p["conv_B_b"]))
        Cm = jax.nn.silu(_causal_conv(C_raw, p["conv_C_w"], p["conv_C_b"]))
        xh = xs.reshape(B, S, H, s.head_dim)
        y, _ = ssd_chunked(xh, dt, A, Bm, Cm, chunk=s.chunk)
    else:
        cx, yx = _conv_step(cache["conv_x"], x_raw, p["conv_x_w"], p["conv_x_b"])
        cB, yB = _conv_step(cache["conv_B"], B_raw, p["conv_B_w"], p["conv_B_b"])
        cC, yC = _conv_step(cache["conv_C"], C_raw, p["conv_C_w"], p["conv_C_b"])
        xs, Bm, Cm = jax.nn.silu(yx), jax.nn.silu(yB), jax.nn.silu(yC)
        xh = xs.reshape(B, 1, H, s.head_dim)
        dec = jnp.exp(dt[:, 0] * A)                                 # (B,H)
        upd = jnp.einsum("bh,bn,bhd->bhnd", dt[:, 0],
                         Bm[:, 0].astype(jnp.float32),
                         xh[:, 0].astype(jnp.float32))
        state = dec[..., None, None] * cache["state"] + upd
        y = jnp.einsum("bn,bhnd->bhd", Cm[:, 0].astype(jnp.float32), state)
        y = y[:, None].astype(xin.dtype)                            # (B,1,H,hd)
        new_cache = {"conv_x": cx, "conv_B": cB, "conv_C": cC, "state": state}

    y = y + p["D"].astype(y.dtype)[None, None, :, None] * xh
    y = y.reshape(B, S, d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    return out, new_cache


def mamba2_cache_spec(cfg: ArchConfig, batch: int):
    s = cfg.ssm
    dt = cfg.param_dtype()
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    return {
        "conv_x": ((batch, s.d_conv - 1, d_inner), dt),
        "conv_B": ((batch, s.d_conv - 1, s.d_state), dt),
        "conv_C": ((batch, s.d_conv - 1, s.d_state), dt),
        "state": ((batch, H, s.d_state, s.head_dim), jnp.float32),
    }


# ---------------------------------------------------------------------------
# Mamba-1 (selective scan; jamba layers)
# ---------------------------------------------------------------------------


def init_mamba1(ctx: InitCtx, cfg: ArchConfig, prefix: str) -> dict:
    s = cfg.ssm
    D = cfg.d_model
    d_inner = s.expand * D
    N = s.d_state
    dt_rank = math.ceil(D / 16)
    return {
        "w_x": ctx.make(f"{prefix}.w_x", (D, d_inner)),
        "w_z": ctx.make(f"{prefix}.w_z", (D, d_inner)),
        "conv_w": ctx.make(f"{prefix}.conv_w", (s.d_conv, d_inner), scale=0.3),
        "conv_b": ctx.make(f"{prefix}.conv_b", (d_inner,), zero=True),
        "x_proj": ctx.make(f"{prefix}.x_proj", (d_inner, dt_rank + 2 * N)),
        "dt_proj": ctx.make(f"{prefix}.dt_proj", (dt_rank, d_inner)),
        "dt_bias": ctx.const(f"{prefix}.dt_bias", jnp.zeros((d_inner,), jnp.float32)),
        "A_log": ctx.const(
            f"{prefix}.A_log",
            jnp.broadcast_to(
                jnp.log(jnp.arange(1, N + 1, dtype=jnp.float32)), (d_inner, N)
            ).copy(),
        ),
        "D": ctx.const(f"{prefix}.D", jnp.ones((d_inner,), jnp.float32)),
        "out_proj": ctx.make(f"{prefix}.out_proj", (d_inner, D)),
    }


def mamba1_forward(p: dict, cfg: ArchConfig, xin: jax.Array, *,
                   cache: dict | None = None):
    """xin: (B, S, D).  cache: {"conv": (B,K-1,d_inner), "state": (B,d_inner,N)}."""
    s = cfg.ssm
    B, S, D = xin.shape
    d_inner = s.expand * D
    N = s.d_state
    dt_rank = p["dt_proj"].shape[0]

    x = jnp.einsum("bsd,di->bsi", xin, p["w_x"])
    z = jnp.einsum("bsd,di->bsi", xin, p["w_z"])

    new_cache = None
    if cache is None:
        x = jax.nn.silu(_causal_conv(x, p["conv_w"], p["conv_b"]))
    else:
        conv_state, y_conv = _conv_step(cache["conv"], x, p["conv_w"], p["conv_b"])
        x = jax.nn.silu(y_conv)

    dbc = jnp.einsum("bsi,ik->bsk", x, p["x_proj"])
    dt_low, Bm, Cm = jnp.split(dbc, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_low, p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"]
    )                                                              # (B,S,d_inner)
    A = -jnp.exp(p["A_log"])                                       # (d_inner,N)

    if cache is None:
        # scan over time; carry h: (B, d_inner, N) f32
        def step(h, inp):
            x_t, dt_t, B_t, C_t = inp                              # (B,di),(B,di),(B,N),(B,N)
            dA = jnp.exp(dt_t[..., None] * A)                      # (B,di,N)
            dBx = dt_t[..., None] * B_t[:, None, :].astype(jnp.float32) \
                * x_t[..., None].astype(jnp.float32)
            h = dA * h + dBx
            y_t = jnp.einsum("bin,bn->bi", h, C_t.astype(jnp.float32))
            return h, y_t

        h0 = jnp.zeros((B, d_inner, N), jnp.float32)
        xs = (x.transpose(1, 0, 2), dt.transpose(1, 0, 2),
              Bm.transpose(1, 0, 2), Cm.transpose(1, 0, 2))
        _, ys = jax.lax.scan(step, h0, xs)
        y = ys.transpose(1, 0, 2)                                  # (B,S,d_inner)
    else:
        dA = jnp.exp(dt[:, 0, :, None] * A)
        dBx = dt[:, 0, :, None] * Bm[:, 0, None, :].astype(jnp.float32) \
            * x[:, 0, :, None].astype(jnp.float32)
        h_final = dA * cache["state"] + dBx
        y = jnp.einsum("bin,bn->bi", h_final, Cm[:, 0].astype(jnp.float32))[:, None]
        new_cache = {"conv": conv_state, "state": h_final}

    y = y.astype(xin.dtype) + p["D"].astype(xin.dtype) * x
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    return out, new_cache


def mamba1_cache_spec(cfg: ArchConfig, batch: int):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    return {
        "conv": ((batch, s.d_conv - 1, d_inner), cfg.param_dtype()),
        "state": ((batch, d_inner, s.d_state), jnp.float32),
    }
