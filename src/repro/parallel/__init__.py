from .sharding import param_specs, batch_specs, cache_partition_specs, to_shardings
