"""Sharding rules: parameter PartitionSpecs by leaf path, activation and
cache specs, with divisibility guards.

Baseline layout (paper-faithful "what a production mesh does"):
  * batch over ('pod', 'data')
  * tensor parallel over 'model': attention heads (packed H*hd dim), FFN
    hidden, MoE expert FFN width, SSM d_inner/heads, vocab (where divisible)
  * optional FSDP: large weight leaves additionally sharded over 'data'
    on a non-model dim (ZeRO-3 via pjit shardings; XLA inserts the
    all-gathers)

Every 'model' assignment is guarded by divisibility: if a dim does not
divide by the axis size the dim is left unsharded (e.g. whisper's 20
heads, granite's 49155 vocab).  This keeps every (arch x mesh) cell
compilable without per-arch special cases.
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# leaf-name -> (negative dim index to shard over 'model')
# indexes are from the END of the shape so stacked layer axes don't matter.
_MODEL_DIM_RULES: dict[str, int] = {
    # attention
    "wq": -1, "wk": -1, "wv": -1, "wo": -2,
    "bq": -1, "bk": -1, "bv": -1,
    # mlp
    "w_gate": -1, "w_up": -1, "w_down": -2,
    "w_in": -1, "b_in": -1, "w_out": -2,
    # mla
    "w_uk": -1, "w_uv": -1,
    # ssm (unpacked projections)
    "w_z": -1, "w_x": -1, "w_dt": -1,
    "conv_x_w": -1, "conv_x_b": -1, "conv_w": -1, "conv_b": -1,
    "x_proj": -2, "dt_proj": -1, "A_log": -1, "dt_bias": -1,
    "out_proj": -2, "norm": -1,
    # embeddings
    "embed": -2, "lm_head": -1,
}
# mamba1 A_log is (d_inner, N) -> shard -2; mamba2 A_log is (H,) -> -1.
# Disambiguated by rank at application time (see _model_dim).

_REPLICATED = {"router", "w_dkv", "kv_norm", "w_B", "w_C", "conv_B_w",
               "conv_B_b", "conv_C_w", "conv_C_b", "D",
               "ln1", "ln2", "ln", "ln1b", "ln2b", "lnx", "lnxb",
               "final_norm", "final_norm_b", "enc_final_norm_b", "efnb", "fnb",
               "b_out"}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
    return ""


def _model_dim(name: str, shape: tuple[int, ...]) -> int | None:
    if name == "A_log":
        return -2 if len(shape) >= 2 and shape[-1] <= 256 and shape[-2] > shape[-1] \
            else -1
    if name == "D" or name == "dt_bias":
        return -1
    return _MODEL_DIM_RULES.get(name)


def param_specs(
    params: Any,
    *,
    model_axis: str = "model",
    model_size: int,
    fsdp_axis: str | None = None,
    fsdp_size: int = 1,
    fsdp_min_size: int = 1 << 22,
    attention_shardable: bool = True,
) -> Any:
    """PartitionSpec pytree matching ``params``.

    attention_shardable=False replicates attention projections (whisper:
    20 heads don't divide the model axis, and sharding the packed dim
    would split heads across shards)."""

    def spec_for(path, leaf) -> P:
        name = _leaf_name(path)
        shape = leaf.shape
        ndim = len(shape)
        dims: list[Any] = [None] * ndim
        if name in _REPLICATED or ndim == 0:
            return P(*dims)
        md = _model_dim(name, shape)
        if name in ("wq", "wk", "wv", "wo", "bq", "bk", "bv") and not attention_shardable:
            md = None
        if name == "A_log" and ndim == 1:
            md = -1
        if md is not None and shape[md] % model_size == 0:
            dims[md] = model_axis
        # FSDP: shard the largest remaining dim of big leaves over data
        if fsdp_axis and leaf.size >= fsdp_min_size:
            cands = [
                d for d in range(ndim)
                if dims[d] is None and shape[d] % fsdp_size == 0 and shape[d] > 1
            ]
            if cands:
                best = max(cands, key=lambda d: shape[d])
                dims[best] = fsdp_axis
        return P(*dims)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def batch_specs(batch_axes: tuple[str, ...] = ("pod", "data")) -> dict[str, P]:
    """Input specs by batch-entry name; batch dim over pod+data."""
    b = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    return {
        "tokens": P(b, None),
        "labels": P(b, None),
        "embeds": P(b, None, None),
        "enc_embeds": P(b, None, None),
        "enc_memory": P(b, None, None),
        "mrope_positions": P(None, b, None),
    }


def cache_partition_specs(
    cache_spec_tree: Any,
    *,
    batch_axes: tuple[str, ...] = ("pod", "data"),
    model_axis: str = "model",
    model_size: int = 1,
    global_batch: int = 0,
    batch_size_total: int = 1,
    seq_axis_for_b1: bool = True,
) -> Any:
    """PartitionSpecs for decode caches.

    Layout per leaf kind (leaves carry a leading stacked-layer axis):
      * attention k/v (L, B, S, Hkv, hd): B over batch axes, Hkv over
        'model' when divisible; if B == 1 (long-context), S over batch
        axes instead (context parallelism).
      * mla latent (L, B, S, R): B over batch axes (R too small to split).
      * ssm conv/state: B over batch axes, d_inner/H over 'model' when
        divisible.
    """
    b = batch_axes if len(batch_axes) > 1 else batch_axes[0]

    def spec_for(path, leaf):
        shape, _ = leaf  # (shape, dtype) tuples
        name = _leaf_name(path)
        ndim = len(shape)
        dims: list[Any] = [None] * ndim
        # Attention caches are CONTEXT-PARALLEL: S over 'model' (softmax
        # partials reduce with tiny (B,H,1) all-reduces), B over the batch
        # axes.  Sharding Hkv over 'model' (or leaving the cache
        # replicated) makes XLA reassemble the full stacked cache per
        # step — 35 GiB/token of all-gather on granite decode_32k before
        # this layout (EXPERIMENTS.md §Perf decode iteration).
        if name in ("k", "v"):
            B_dim, S_dim = ndim - 4, ndim - 3
            if shape[B_dim] == 1 and seq_axis_for_b1:
                both = (*batch_axes, model_axis)
                if shape[S_dim] % (batch_size_total * model_size) == 0:
                    dims[S_dim] = both
                elif shape[S_dim] % model_size == 0:
                    dims[S_dim] = model_axis
            else:
                if shape[B_dim] % batch_size_total == 0:
                    dims[B_dim] = b
                if shape[S_dim] % model_size == 0:
                    dims[S_dim] = model_axis
        elif name == "latent":
            B_dim, S_dim = ndim - 3, ndim - 2
            if shape[B_dim] % batch_size_total == 0:
                dims[B_dim] = b
            if shape[S_dim] % model_size == 0:
                dims[S_dim] = model_axis
        elif name.startswith("conv"):
            B_dim, C_dim = ndim - 3, ndim - 1
            if shape[B_dim] % batch_size_total == 0:
                dims[B_dim] = b
            if shape[C_dim] % model_size == 0 and shape[C_dim] >= model_size * 16:
                dims[C_dim] = model_axis
        elif name == "state":
            keys = [str(e.key) for e in path if hasattr(e, "key")]
            if "mamba" in keys:   # jamba mamba1: (..., B, d_inner, N)
                B_dim, H_dim = ndim - 3, ndim - 2
            else:                 # mamba2 SSD: (..., B, H, N, hd)
                B_dim, H_dim = ndim - 4, ndim - 3
            if shape[B_dim] % batch_size_total == 0:
                dims[B_dim] = b
            if shape[H_dim] % model_size == 0:
                dims[H_dim] = model_axis
        return P(*dims)

    return jax.tree_util.tree_map_with_path(
        spec_for, cache_spec_tree,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
        and isinstance(x[0], tuple),
    )


def to_shardings(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
