from .engine import ServeEngine
