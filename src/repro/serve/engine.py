"""Batched serving: prefill + decode loop with a step-indexed KV cache.

The jitted ``serve_step`` is the function the decode_* dry-run cells
lower: one new token against a cache of ``seq_len`` (cache donated, so
the update is in-place on device).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..models.model import Model


@dataclasses.dataclass
class ServeEngine:
    model: Model
    batch_size: int
    max_len: int

    def __post_init__(self):
        self._decode = jax.jit(
            lambda p, c, b, i: self.model.decode_step(p, c, b, i),
            donate_argnums=(1,),
        )

    def init_cache(self):
        return self.model.init_cache(self.batch_size, self.max_len)

    def prefill_logits(self, params, batch) -> jax.Array:
        return jax.jit(self.model.prefill)(params, batch)

    def generate(self, params, prompt_tokens: jax.Array, steps: int,
                 *, extra_batch: dict | None = None,
                 temperature: float = 0.0, key=None) -> jax.Array:
        """Greedy/sampled generation.  prompt_tokens: (B, S0) int32.
        Feeds the prompt token-by-token through decode (cache-exact),
        then generates ``steps`` tokens."""
        B, S0 = prompt_tokens.shape
        cache = self.init_cache()
        out = [prompt_tokens]
        tok = None
        extra = extra_batch or {}
        for i in range(S0 + steps - 1):
            cur = prompt_tokens[:, i : i + 1] if i < S0 else tok
            logits, cache = self._decode(
                params, cache, {"tokens": cur, **extra}, jnp.int32(i))
            if temperature > 0 and key is not None:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(
                    sub, logits[:, -1] / temperature)[:, None].astype(jnp.int32)
            else:
                tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
            if i >= S0 - 1:
                out.append(tok)
        return jnp.concatenate(out, axis=1)
