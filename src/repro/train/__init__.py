from .optimizer import AdamWConfig, adamw_init, adamw_update, schedule, global_norm
from .step import TrainConfig, make_train_step, init_train_state
