"""AdamW in plain JAX pytrees, with configurable state dtype.

``state_dtype="bfloat16"`` halves optimizer memory (the 8-bit-Adam-style
trick that lets 398B jamba fit a single 256-chip pod: 2+2+2 bytes/param
instead of 2+4+4); accuracy impact is the standard documented tradeoff.
The first/second moments share the parameter sharding, so optimizer
state is fully distributed (ZeRO-1 comes for free from pjit shardings).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"      # or "bfloat16" for big models
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def adamw_init(params: Any, cfg: AdamWConfig) -> dict:
    dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)
    ))


def adamw_update(
    grads: Any, state: dict, params: Any, cfg: AdamWConfig,
) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else jnp.float32(1.0)
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    sdt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mhat = m32 / b1c
        vhat = v32 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2 and cfg.weight_decay > 0:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m32.astype(sdt), v32.astype(sdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
