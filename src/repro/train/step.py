"""Train step factory: loss + grad (+ optional microbatch accumulation),
global-norm clipping, AdamW — a single jittable function suitable for
pjit with full state sharding.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..models.model import Model
from .optimizer import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: AdamWConfig = AdamWConfig()
    grad_accum: int = 1               # microbatches per step
    # mesh axes carrying the batch dim; used to re-pin shardings after the
    # microbatch reshape (XLA drops the batch sharding across reshapes,
    # replicating activations -- a 20x memory regression without this).
    batch_axes: tuple[str, ...] | None = None
    # ZeRO-2: PartitionSpec pytree (matching params) for the gradient
    # accumulator.  Sharding the accumulator over 'data' turns the
    # per-microbatch gradient all-reduce into a reduce-scatter and defers
    # the gather to the (single) optimizer update.
    accum_specs: object = None


def make_train_step(model: Model, tc: TrainConfig) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  batch leaves have leading global-batch dim."""

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return loss, metrics, grads

    def accumulated(params, batch):
        A = tc.grad_accum

        def constrain_grads(g):
            if tc.accum_specs is None:
                return g
            return jax.tree.map(
                lambda a, s: jax.lax.with_sharding_constraint(a, s)
                if s is not None else a,
                g, tc.accum_specs, is_leaf=lambda q: q is None)

        def micro(carry, mb):
            gsum, lsum = carry
            (loss, _), grads = grad_fn(params, mb)
            gsum = constrain_grads(jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), gsum, grads))
            return (gsum, lsum + loss), None

        gz = constrain_grads(
            jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
        B_global = batch["labels"].shape[0]
        baxes = tc.batch_axes
        bspec = None
        if baxes:
            bspec = baxes if len(baxes) > 1 else baxes[0]

        def to_micro(name, x):
            # batch-major leaves split into A microbatches; leaves whose
            # batch dim is elsewhere (mrope_positions: (3, B, S)) move it.
            if x.shape[0] == B_global:
                x = x.reshape(A, x.shape[0] // A, *x.shape[1:])
                if bspec is not None:
                    x = jax.lax.with_sharding_constraint(
                        x, P(None, bspec, *([None] * (x.ndim - 2))))
                return x
            assert x.shape[1] == B_global, (name, x.shape)
            x = x.reshape(x.shape[0], A, x.shape[1] // A, *x.shape[2:]) \
                 .swapaxes(0, 1)
            if bspec is not None:
                x = jax.lax.with_sharding_constraint(
                    x, P(None, None, bspec, *([None] * (x.ndim - 3))))
            return x

        micro_batches = {k: to_micro(k, v) for k, v in batch.items()}
        (gsum, lsum), _ = jax.lax.scan(micro, (gz, jnp.float32(0)), micro_batches)
        grads = jax.tree.map(lambda g: g / A, gsum)
        return lsum / A, {}, grads

    def train_step(params, opt_state, batch):
        if tc.grad_accum > 1:
            loss, metrics, grads = accumulated(params, batch)
        else:
            loss, metrics, grads = single(params, batch)
        new_params, new_opt, opt_metrics = adamw_update(
            grads, opt_state, params, tc.optimizer)
        return new_params, new_opt, {"loss": loss, **metrics, **opt_metrics}

    return train_step


def init_train_state(model: Model, tc: TrainConfig, key: jax.Array):
    params = model.init(key)
    opt_state = adamw_init(params, tc.optimizer)
    return params, opt_state
