"""Property-testing front-end: ``hypothesis`` when installed, otherwise a
minimal deterministic fallback with the same surface.

Test modules import ``given``/``settings``/``strategies`` from here instead
of from ``hypothesis`` directly, so the suite collects and runs from a
clean environment (the container has no ``hypothesis``).  The fallback is
intentionally tiny: each strategy draws pseudo-random examples from an rng
seeded by the test name, with the range endpoints forced as the first two
examples (the cheapest form of adversarial input).  No shrinking.

``PROPCHECK_MAX_EXAMPLES`` caps the per-test example count (default 25)
so the pure-Python property tests stay fast; declared ``max_examples``
below the cap are honoured.
"""

from __future__ import annotations

import os
import random
import zlib

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    _CAP = int(os.environ.get("PROPCHECK_MAX_EXAMPLES", "25"))

    class _Strategy:
        """A generator of example values: ``draw(rng) -> value``."""

        def __init__(self, draw, edges=()):
            self._draw = draw
            self.edges = tuple(edges)  # forced first examples

        def draw(self, rng: random.Random):
            return self._draw(rng)

        def map(self, fn) -> "_Strategy":
            return _Strategy(lambda rng: fn(self._draw(rng)),
                             [fn(e) for e in self.edges])

        def filter(self, pred) -> "_Strategy":
            def draw(rng):
                for _ in range(1000):
                    v = self._draw(rng)
                    if pred(v):
                        return v
                raise RuntimeError("propcheck filter: no value accepted")
            return _Strategy(draw, [e for e in self.edges if pred(e)])

    class strategies:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(lambda rng: rng.randint(min_value, max_value),
                             [min_value, max_value])

        @staticmethod
        def booleans() -> _Strategy:
            return _Strategy(lambda rng: rng.random() < 0.5, [False, True])

        @staticmethod
        def floats(min_value: float, max_value: float) -> _Strategy:
            return _Strategy(lambda rng: rng.uniform(min_value, max_value),
                             [min_value, max_value])

        @staticmethod
        def sampled_from(seq) -> _Strategy:
            seq = list(seq)
            return _Strategy(lambda rng: rng.choice(seq), seq[:2])

        @staticmethod
        def lists(elements: _Strategy, *, min_size: int = 0,
                  max_size: int = 10) -> _Strategy:
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.draw(rng) for _ in range(n)]
            edges = []
            if min_size <= max_size:
                edge_rng = random.Random(0)
                edges = [[elements.draw(edge_rng) for _ in range(min_size)],
                         [elements.draw(edge_rng) for _ in range(max_size)]]
            return _Strategy(draw, edges)

    def settings(*, max_examples: int = 100, deadline=None, **_kw):
        def deco(fn):
            fn._pc_max_examples = max_examples
            return fn
        return deco

    def given(*strats: _Strategy):
        def deco(fn):
            n = min(getattr(fn, "_pc_max_examples", 100), _CAP)
            seed0 = zlib.crc32(fn.__qualname__.encode())

            def wrapper():
                for i in range(n):
                    if i < len(strats[0].edges) and all(
                            i < len(s.edges) for s in strats):
                        args = [s.edges[i] for s in strats]
                    else:
                        rng = random.Random(seed0 + i)
                        args = [s.draw(rng) for s in strats]
                    try:
                        fn(*args)
                    except Exception:
                        print(f"propcheck falsified {fn.__qualname__} "
                              f"with args={args!r}")
                        raise

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco
