"""Shared fixtures: the paper testbed, workloads, traces, and compiled
fabrics are session-scoped so the many modules that exercise the same
2-rack topology build it once instead of per test/module."""

import pytest

from repro.core import (
    EcmpRouting, FlowTracer, bipartite_pairs, build_multipod_fabric,
    build_paper_testbed, compile_fabric, nic_ip, server_name,
    synthesize_flows,
)


@pytest.fixture(scope="session")
def paper_fabric():
    return build_paper_testbed()


def _paper_workload(fabric, flows_per_pair):
    rack0 = [server_name(i) for i in range(8)]
    rack1 = [server_name(8 + i) for i in range(8)]
    wl = bipartite_pairs(rack0, rack1, flows_per_pair=flows_per_pair)
    flows = synthesize_flows(wl, nic_ip=nic_ip, nics_per_server=2)
    return fabric, wl, flows


@pytest.fixture(scope="session")
def paper_setup(paper_fabric):
    """(fabric, workload, flows) at the paper's 256-flow scale."""
    return _paper_workload(paper_fabric, flows_per_pair=16)


@pytest.fixture(scope="session")
def paper_setup_small(paper_fabric):
    """Same testbed, half the flows — for tests where scale is irrelevant."""
    return _paper_workload(paper_fabric, flows_per_pair=8)


@pytest.fixture(scope="session")
def paper_compiled(paper_fabric):
    return compile_fabric(paper_fabric)


@pytest.fixture(scope="session")
def paper_traced_seed7(paper_setup):
    """One ECMP trace at the reference seed, shared by the system tests."""
    fab, wl, flows = paper_setup
    return FlowTracer(fab, EcmpRouting(fab, seed=7), wl, flows).trace()


def weighted_max_min_ref(paths: dict[int, list[int]], caps: list[float],
                         w: dict[int, float]) -> dict[int, float]:
    """Readable scalar weighted progressive filling, the shared reference
    for the differential tests (test_strategies / test_demand): saturate
    the link with the smallest residual/sum-of-active-weights, freeze its
    flows at ``w_f * share``, repeat."""
    active = set(paths)
    residual = dict(enumerate(caps))
    rate: dict[int, float] = {}
    while active:
        shares = {}
        for link, res in residual.items():
            tot = sum(w[f] for f in active if link in paths[f])
            if tot > 0:
                shares[link] = res / tot
        if not shares:
            for f in active:
                rate[f] = float("inf")
            break
        bottleneck = min(shares, key=lambda link: shares[link])
        share = shares[bottleneck]
        for f in [f for f in active if bottleneck in paths[f]]:
            rate[f] = w[f] * share
            for link in paths[f]:
                residual[link] -= w[f] * share
            active.remove(f)
    return rate


@pytest.fixture(scope="session")
def multipod_small():
    """A downscaled 2-pod DCN fabric + inter-pod bipartite workload."""
    fab = build_multipod_fabric(num_pods=2, hosts_per_pod=8,
                                leaves_per_pod=2, num_spines=4)
    pod0 = [f"host-{i}" for i in range(8)]
    pod1 = [f"host-{8 + i}" for i in range(8)]
    wl = bipartite_pairs(pod0, pod1, flows_per_pair=4)
    flows = synthesize_flows(wl, nic_ip=nic_ip, nics_per_server=1)
    return fab, wl, flows
