"""Shared fixtures: the paper testbed, workloads, traces, and compiled
fabrics are session-scoped so the many modules that exercise the same
2-rack topology build it once instead of per test/module."""

import pytest

from repro.core import (
    EcmpRouting, FlowTracer, bipartite_pairs, build_multipod_fabric,
    build_paper_testbed, compile_fabric, nic_ip, server_name,
    synthesize_flows,
)


@pytest.fixture(scope="session")
def paper_fabric():
    return build_paper_testbed()


def _paper_workload(fabric, flows_per_pair):
    rack0 = [server_name(i) for i in range(8)]
    rack1 = [server_name(8 + i) for i in range(8)]
    wl = bipartite_pairs(rack0, rack1, flows_per_pair=flows_per_pair)
    flows = synthesize_flows(wl, nic_ip=nic_ip, nics_per_server=2)
    return fabric, wl, flows


@pytest.fixture(scope="session")
def paper_setup(paper_fabric):
    """(fabric, workload, flows) at the paper's 256-flow scale."""
    return _paper_workload(paper_fabric, flows_per_pair=16)


@pytest.fixture(scope="session")
def paper_setup_small(paper_fabric):
    """Same testbed, half the flows — for tests where scale is irrelevant."""
    return _paper_workload(paper_fabric, flows_per_pair=8)


@pytest.fixture(scope="session")
def paper_compiled(paper_fabric):
    return compile_fabric(paper_fabric)


@pytest.fixture(scope="session")
def paper_traced_seed7(paper_setup):
    """One ECMP trace at the reference seed, shared by the system tests."""
    fab, wl, flows = paper_setup
    return FlowTracer(fab, EcmpRouting(fab, seed=7), wl, flows).trace()


@pytest.fixture(scope="session")
def multipod_small():
    """A downscaled 2-pod DCN fabric + inter-pod bipartite workload."""
    fab = build_multipod_fabric(num_pods=2, hosts_per_pod=8,
                                leaves_per_pod=2, num_spines=4)
    pod0 = [f"host-{i}" for i in range(8)]
    pod1 = [f"host-{8 + i}" for i in range(8)]
    wl = bipartite_pairs(pod0, pod1, flows_per_pair=4)
    flows = synthesize_flows(wl, nic_ip=nic_ip, nics_per_server=1)
    return fab, wl, flows
