"""Unit tests for the CI bench-regression guard — this is the
"demonstrably fires" requirement: the comparison logic must go red on a
>2.5x slowdown of a same-shape row, stay green otherwise, never compare
rows across shapes, and honor the noisy-runner opt-out."""

import json

import pytest

from benchmarks.check_regression import (
    SKIP_ENV, compare, main, orphaned_rows, shape_key, timed_rows,
)


def _payload(rows, override=None):
    return {"schema": 1, "bench_seeds_override": override, "rows": rows}


def _row(name, us, seeds=None, flows=None, engine=None):
    metrics = {}
    if seeds is not None:
        metrics["seeds"] = seeds
    if flows is not None:
        metrics["flows"] = flows
    row = {"name": name, "us_per_call": us, "derived": "", "metrics": metrics}
    if engine is not None:
        row["engine"] = engine
    return row


def test_fires_on_slowdown_beyond_threshold():
    old = _payload([_row("fig3a_ecmp_fim_pct", 100.0, seeds=1024)])
    new = _payload([_row("fig3a_ecmp_fim_pct", 260.0, seeds=1024)])
    regressions, compared = compare(old, new)
    assert compared == 1
    assert len(regressions) == 1
    assert "fig3a_ecmp_fim_pct" in regressions[0]
    assert "2.60x" in regressions[0]


def test_passes_below_threshold():
    old = _payload([_row("fig3a_ecmp_fim_pct", 100.0, seeds=1024)])
    new = _payload([_row("fig3a_ecmp_fim_pct", 240.0, seeds=1024)])
    regressions, compared = compare(old, new)
    assert compared == 1
    assert regressions == []


def test_absolute_slack_swallows_microsecond_noise():
    """A 3x ratio on a 10us row is timer noise, not a regression."""
    old = _payload([_row("tiny_row", 10.0, seeds=8)])
    new = _payload([_row("tiny_row", 30.0, seeds=8)])
    regressions, _ = compare(old, new)
    assert regressions == []
    # but the same ratio above the slack does fire
    old = _payload([_row("big_row", 100.0, seeds=8)])
    new = _payload([_row("big_row", 300.0, seeds=8)])
    regressions, _ = compare(old, new)
    assert len(regressions) == 1


def test_shape_mismatch_is_never_compared():
    # same row name, but smoke shape vs full shape: not comparable
    old = _payload([_row("mc_paper_ecmp_5tuple", 100.0, seeds=1024)])
    new = _payload([_row("mc_paper_ecmp_5tuple", 9000.0, seeds=8)],
                   override="8")
    regressions, compared = compare(old, new)
    assert compared == 0
    assert regressions == []


def test_same_shape_same_override_compares():
    old = _payload([_row("mc_paper_ecmp_5tuple", 100.0, seeds=8)],
                   override="8")
    new = _payload([_row("mc_paper_ecmp_5tuple", 9000.0, seeds=8)],
                   override="8")
    regressions, compared = compare(old, new)
    assert compared == 1
    assert len(regressions) == 1


def test_derived_only_rows_ignored():
    old = _payload([_row("fig3a_static_fim_pct", 0.0)])
    new = _payload([_row("fig3a_static_fim_pct", 0.0)])
    regressions, compared = compare(old, new)
    assert (regressions, compared) == ([], 0)
    assert timed_rows(new) == {}


def test_new_rows_pass_without_baseline():
    old = _payload([])
    new = _payload([_row("brand_new_bench", 5000.0, seeds=1024)])
    regressions, compared = compare(old, new)
    assert (regressions, compared) == ([], 0)


def test_shape_key_fields():
    payload = _payload([], override="8")
    row = _row("x", 1.0, seeds=8, flows=256)
    assert shape_key(payload, row) == ("x", "8", 8, 256, None)
    row = _row("x", 1.0, seeds=8, flows=256, engine="jax")
    assert shape_key(payload, row) == ("x", "8", 8, 256, "jax")


def test_engine_mismatch_is_orphaned_not_compared():
    """A backend-only difference (same name, same shape) must never
    compare — a numpy->jax swap would otherwise read as a perf
    regression — and the stranded baseline row must surface as
    ORPHANED rather than silently guarding nothing."""
    old = _payload([_row("engine_fill", 100.0, seeds=1024, engine="numpy")])
    new = _payload([_row("engine_fill", 900.0, seeds=1024, engine="jax")])
    regressions, compared = compare(old, new)
    assert (regressions, compared) == ([], 0)
    orphans = orphaned_rows(old, new)
    assert len(orphans) == 1
    assert orphans[0][0] == "engine_fill"
    assert orphans[0][-1] == "numpy"


def test_same_engine_same_shape_compares():
    old = _payload([_row("engine_fill", 100.0, seeds=1024, engine="jax")])
    new = _payload([_row("engine_fill", 900.0, seeds=1024, engine="jax")])
    regressions, compared = compare(old, new)
    assert compared == 1
    assert len(regressions) == 1
    assert "engine=jax" in regressions[0]


def test_main_red_and_green(tmp_path, monkeypatch):
    monkeypatch.delenv(SKIP_ENV, raising=False)
    old = tmp_path / "old.json"
    new = tmp_path / "new.json"
    old.write_text(json.dumps(_payload([_row("b", 100.0, seeds=8)])))
    new.write_text(json.dumps(_payload([_row("b", 1000.0, seeds=8)])))
    assert main(["--old", str(old), "--new", str(new)]) == 1
    new.write_text(json.dumps(_payload([_row("b", 110.0, seeds=8)])))
    assert main(["--old", str(old), "--new", str(new)]) == 0


def test_main_fails_on_zero_comparable_timed_rows(tmp_path, monkeypatch):
    """A stale baseline (renamed rows / drifted shapes) must not let the
    guard pass green forever."""
    monkeypatch.delenv(SKIP_ENV, raising=False)
    old = tmp_path / "old.json"
    new = tmp_path / "new.json"
    old.write_text(json.dumps(_payload([_row("renamed_away", 100.0, seeds=8)])))
    new.write_text(json.dumps(_payload([_row("brand_new", 100.0, seeds=8)])))
    assert main(["--old", str(old), "--new", str(new)]) == 1
    # but an empty baseline (nothing guarded yet) stays green
    old.write_text(json.dumps(_payload([_row("derived_only", 0.0)])))
    assert main(["--old", str(old), "--new", str(new)]) == 0


def test_opt_out_env_var(tmp_path, monkeypatch):
    old = tmp_path / "old.json"
    new = tmp_path / "new.json"
    old.write_text(json.dumps(_payload([_row("b", 100.0, seeds=8)])))
    new.write_text(json.dumps(_payload([_row("b", 99999.0, seeds=8)])))
    monkeypatch.setenv(SKIP_ENV, "1")
    assert main(["--old", str(old), "--new", str(new)]) == 0


def test_custom_threshold():
    old = _payload([_row("b", 100.0, seeds=8)])
    new = _payload([_row("b", 180.0, seeds=8)])
    assert compare(old, new, threshold=1.5)[0]
    assert not compare(old, new, threshold=2.0)[0]


@pytest.mark.parametrize("ratio,fires", [(2.49, False), (2.51, True)])
def test_threshold_boundary(ratio, fires):
    old = _payload([_row("b", 1000.0, seeds=8)])
    new = _payload([_row("b", 1000.0 * ratio, seeds=8)])
    regressions, _ = compare(old, new)
    assert bool(regressions) == fires


def test_orphaned_rows_listed():
    """A baseline row whose bench was renamed or reshaped guards nothing
    — it must be surfaced, not silently skipped."""
    old = _payload([_row("kept", 100.0, seeds=8),
                    _row("renamed_away", 100.0, seeds=8),
                    _row("reshaped", 100.0, seeds=1024)])
    new = _payload([_row("kept", 110.0, seeds=8),
                    _row("reshaped", 100.0, seeds=8),
                    _row("brand_new", 50.0, seeds=8)])
    orphans = orphaned_rows(old, new)
    assert [key[0] for key in orphans] == ["renamed_away", "reshaped"]
    # derived-only baseline rows are not orphans (they never guarded)
    old_derived = _payload([_row("derived_only", 0.0)])
    assert orphaned_rows(old_derived, new) == []


def test_main_prints_orphans_without_failing(tmp_path, monkeypatch, capsys):
    monkeypatch.delenv(SKIP_ENV, raising=False)
    old = tmp_path / "old.json"
    new = tmp_path / "new.json"
    old.write_text(json.dumps(_payload([_row("kept", 100.0, seeds=8),
                                        _row("gone", 100.0, seeds=8)])))
    new.write_text(json.dumps(_payload([_row("kept", 110.0, seeds=8)])))
    assert main(["--old", str(old), "--new", str(new)]) == 0
    out = capsys.readouterr().out
    assert "ORPHANED gone" in out
    assert "refresh the baseline" in out


def test_results_path_anchored_to_repo_root():
    """benchmarks/run.py must write the perf history next to the repo
    root regardless of the CWD it is invoked from — a relative path
    silently desyncs the regression guard."""
    import os

    import benchmarks.run as run

    assert os.path.isabs(run.RESULTS_PATH)
    repo_root = os.path.dirname(
        os.path.dirname(os.path.abspath(run.__file__)))
    assert run.RESULTS_PATH == os.path.join(repo_root, "BENCH_results.json")


def test_subset_run_merges_into_existing_payload(tmp_path, monkeypatch):
    """A subset invocation must replace only its own benches' rows and
    carry every other bench's rows over — not wipe the history."""
    import json as _json
    import sys

    import benchmarks.common as common
    import benchmarks.run as run

    results_path = tmp_path / "BENCH_results.json"
    monkeypatch.setattr(run, "RESULTS_PATH", str(results_path))
    monkeypatch.setattr(
        run, "BENCHES",
        {"alpha": lambda: common.emit("alpha_row", 1.0, "v=1"),
         "beta": lambda: common.emit("beta_row", 2.0, "v=2")})

    def run_main(argv):
        monkeypatch.setattr(common, "RESULTS", [])
        monkeypatch.setattr(run, "RESULTS", common.RESULTS)
        monkeypatch.setattr(sys, "argv", ["run"] + argv)
        run.main()
        return _json.loads(results_path.read_text())

    full = run_main([])
    assert {r["name"]: r["bench"] for r in full["rows"]} == {
        "alpha_row": "alpha", "beta_row": "beta"}
    subset = run_main(["beta"])
    assert {r["name"]: r["bench"] for r in subset["rows"]} == {
        "alpha_row": "alpha", "beta_row": "beta"}   # alpha carried over
    assert subset["benches"] == ["beta"]
    # a re-run of a bench replaces, not duplicates, its rows
    assert sum(r["name"] == "beta_row" for r in subset["rows"]) == 1


def test_shape_key_prefers_row_level_override():
    """Rows carried over from an earlier run keep the shape override
    they were measured under, not the merging run's — a full-shape row
    inside a smoke payload must never match a smoke baseline."""
    payload = _payload([], override="8")
    carried = _row("x", 1.0, seeds=8, flows=256)
    carried["bench_seeds_override"] = None      # measured at full shape
    assert shape_key(payload, carried) == ("x", None, 8, 256, None)
    fresh = _row("x", 1.0, seeds=8, flows=256)  # pre-stamp fallback
    assert shape_key(payload, fresh) == ("x", "8", 8, 256, None)


def test_subset_run_carries_prior_errors(tmp_path, monkeypatch):
    """Partial rows of a previously failed bench must keep their error
    record when another bench's subset run rewrites the payload."""
    import json as _json
    import sys

    import benchmarks.common as common
    import benchmarks.run as run

    results_path = tmp_path / "BENCH_results.json"
    monkeypatch.setattr(run, "RESULTS_PATH", str(results_path))

    def boom():
        common.emit("beta_partial", 1.0, "v=1")
        raise RuntimeError("bench died midway")

    monkeypatch.setattr(
        run, "BENCHES",
        {"alpha": lambda: common.emit("alpha_row", 1.0, "v=1"),
         "beta": boom})

    def run_main(argv):
        monkeypatch.setattr(common, "RESULTS", [])
        monkeypatch.setattr(run, "RESULTS", common.RESULTS)
        monkeypatch.setattr(sys, "argv", ["run"] + argv)
        try:
            run.main()
        except SystemExit:
            pass
        return _json.loads(results_path.read_text())

    failed = run_main([])                        # beta fails, alpha lands
    assert "beta" in failed["errors"]
    clean = run_main(["alpha"])                  # re-run only alpha
    assert "beta" in clean["errors"]             # partial rows still marked
    assert {r["name"] for r in clean["rows"]} == {"alpha_row", "beta_partial"}
    fixed = run_main(["beta"])                   # but beta itself... still red
    assert "beta" in fixed["errors"]


def test_stale_bench_rows_not_carried(tmp_path, monkeypatch):
    """Rows (and errors) of a bench that no longer exists in BENCHES
    must not be carried forward — frozen timings of a renamed bench
    would satisfy the regression guard forever."""
    import json as _json
    import sys

    import benchmarks.common as common
    import benchmarks.run as run

    results_path = tmp_path / "BENCH_results.json"
    results_path.write_text(_json.dumps({
        "schema": 1, "bench_seeds_override": None,
        "rows": [{"name": "old_row", "us_per_call": 5.0, "derived": "",
                  "metrics": {}, "bench": "renamed-away"}],
        "errors": {"renamed-away": "RuntimeError: gone"},
    }))
    monkeypatch.setattr(run, "RESULTS_PATH", str(results_path))
    monkeypatch.setattr(
        run, "BENCHES", {"alpha": lambda: common.emit("alpha_row", 1.0, "v=1")})
    monkeypatch.setattr(common, "RESULTS", [])
    monkeypatch.setattr(run, "RESULTS", common.RESULTS)
    monkeypatch.setattr(sys, "argv", ["run", "alpha"])
    run.main()
    payload = _json.loads(results_path.read_text())
    assert {r["name"] for r in payload["rows"]} == {"alpha_row"}
    assert "errors" not in payload
