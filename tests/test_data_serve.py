"""Data pipeline determinism + serving engine."""

import jax
import jax.numpy as jnp

from repro.configs import ARCHS
from repro.data import SyntheticDataset
from repro.models import Model
from repro.serve import ServeEngine


def test_synthetic_determinism():
    ds = SyntheticDataset(vocab=100, seq_len=32, global_batch=8, seed=5)
    a = ds.batch(3)
    b = ds.batch(3)
    assert (a["tokens"] == b["tokens"]).all()
    c = ds.batch(4)
    assert not (a["tokens"] == c["tokens"]).all()
    # next-token alignment
    assert (a["labels"][:, :-1] == a["tokens"][:, 1:]).all()


def test_synthetic_host_sharding():
    ds = SyntheticDataset(vocab=100, seq_len=16, global_batch=8, seed=5)
    h0 = ds.batch(0, host_index=0, num_hosts=2)
    h1 = ds.batch(0, host_index=1, num_hosts=2)
    assert h0["tokens"].shape == (4, 16)
    assert not (h0["tokens"] == h1["tokens"]).all()


def test_byte_dataset(tmp_path):
    from repro.data import ByteDataset
    p = tmp_path / "corpus.txt"
    p.write_bytes(b"the quick brown fox jumps over the lazy dog " * 100)
    ds = ByteDataset(str(p), seq_len=32, global_batch=4, seed=0)
    b = ds.batch(0)
    assert b["tokens"].shape == (4, 32)
    assert b["tokens"].max() < 256


def test_serve_engine_greedy_generation():
    cfg = ARCHS["granite-3-2b"].reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, batch_size=2, max_len=16)
    prompt = jnp.array([[5, 6, 7], [9, 10, 11]], jnp.int32)
    out = eng.generate(params, prompt, steps=5)
    assert out.shape == (2, 8)
    assert (out[:, :3] == prompt).all()
    # deterministic greedy
    out2 = eng.generate(params, prompt, steps=5)
    assert (out == out2).all()


def test_serve_generation_matches_prefill_argmax():
    """The first generated token equals argmax of the prefill logits at
    the last prompt position."""
    cfg = ARCHS["granite-3-2b"].reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, batch_size=2, max_len=16)
    prompt = jnp.array([[5, 6, 7, 8], [9, 10, 11, 12]], jnp.int32)
    logits = eng.prefill_logits(params, {"tokens": prompt})
    want = jnp.argmax(logits[:, -1], -1)
    out = eng.generate(params, prompt, steps=1)
    assert (out[:, -1] == want).all()
