"""Heterogeneous flow-demand plumbing: flows -> walk -> FIM -> max-min.

The silent-correctness contract this module pins down:

* ``demand_mode="bytes"`` with all-equal bytes is **bit-identical** to
  ``demand_mode="uniform"`` for every registered strategy (K=1 spray
  included) — weighting a homogeneous workload must change nothing;
* with heterogeneous bytes, FIM and max-min rates actually move — the
  regression half that fails on the historical unit-demand assumption;
* the weighted allocation matches a scalar weighted reference on
  randomized heterogeneous workloads, end-to-end through the demand
  pipeline (not just ``batched_max_min`` in isolation);
* flowlet demand composes multiplicatively with flow demand, and the
  flowlet->flow aggregation preserves the byte-weighted shares.
"""

import numpy as np
import pytest
from _propcheck import given, settings, strategies as st
from conftest import weighted_max_min_ref

from repro.core import (
    DEMAND_BYTES, DEMAND_UNIFORM, CongestionAware, Flow, LlmJobSpec,
    PairSpec, PrimeSpraying, WorkloadDescription, available_strategies,
    bipartite_pairs, build_paper_testbed, compile_fabric, fim_vector,
    flow_demand_weights, llm_collective_ops, monte_carlo_fim,
    monte_carlo_throughput, nic_ip, paper_testbed_llm_workload,
    server_name, simulate_paths, synthesize_flows, throughput_from_result,
    workload_from_flows,
)
from repro.core.vector_sim import resolve_flows


def _hetero_flows(paper_setup, volumes):
    """paper_setup flows with per-flow bytes cycling over ``volumes``."""
    _, _, flows = paper_setup
    return [
        Flow(flow_id=f.flow_id, src=f.src, dst=f.dst, tuple5=f.tuple5,
             bytes=int(volumes[i % len(volumes)]), label=f.label)
        for i, f in enumerate(flows)
    ]


# ---------------------------------------------------------------------------
# flow_demand_weights
# ---------------------------------------------------------------------------


def test_uniform_mode_is_ones(paper_setup):
    _, _, flows = paper_setup
    np.testing.assert_array_equal(
        flow_demand_weights(flows, DEMAND_UNIFORM), 1.0)


def test_bytes_mode_equal_bytes_is_exact_ones(paper_setup):
    for volume in (0, 1, 3, 1_000_000_007):
        flows = _hetero_flows(paper_setup, [volume])
        w = flow_demand_weights(flows, DEMAND_BYTES)
        assert (w == 1.0).all(), f"volume={volume} not exactly uniform"


def test_bytes_mode_proportional_and_mean_one(paper_setup):
    flows = _hetero_flows(paper_setup, [1 << 30, 1 << 10])
    w = flow_demand_weights(flows, DEMAND_BYTES)
    assert w.mean() == pytest.approx(1.0)
    assert w[0] / w[1] == pytest.approx((1 << 30) / (1 << 10))
    assert (w > 0).all()


def test_bytes_mode_zero_byte_flows_floored(paper_setup):
    # barriers (0 bytes) inside an elephant workload must stay strictly
    # positive: the max-min fill rejects zero weights
    flows = _hetero_flows(paper_setup, [0, 1 << 30])
    w = flow_demand_weights(flows, DEMAND_BYTES)
    assert (w > 0).all()
    assert w[0] < w[1]


def test_unknown_demand_mode_raises(paper_compiled, paper_setup):
    _, _, flows = paper_setup
    with pytest.raises(ValueError, match="demand_mode"):
        flow_demand_weights(flows, "gigabytes")
    with pytest.raises(ValueError, match="demand_mode"):
        simulate_paths(paper_compiled, flows, [0], demand_mode="gigabytes")


# ---------------------------------------------------------------------------
# bit-identity: bytes mode on a homogeneous workload == uniform mode
# ---------------------------------------------------------------------------


def _all_strategy_instances():
    for name in available_strategies():
        yield name, name
    yield "prime-spray-k1", PrimeSpraying(flowlets=1)


@pytest.mark.parametrize("tag,strategy", list(_all_strategy_instances()))
def test_equal_bytes_bit_identical_to_uniform(paper_compiled, paper_setup,
                                              tag, strategy):
    """The acceptance criterion: demand_mode="bytes" with uniform volumes
    must change *nothing* — same link ids, same weights, same FIM floats,
    same rates — for every registered strategy."""
    flows = _hetero_flows(paper_setup, [1 << 20])[:64]
    seeds = np.arange(6)
    base = simulate_paths(paper_compiled, flows, seeds, strategy=strategy)
    res = simulate_paths(paper_compiled, flows, seeds, strategy=strategy,
                         demand_mode=DEMAND_BYTES)
    np.testing.assert_array_equal(res.link_ids, base.link_ids)
    np.testing.assert_array_equal(res.flow_demand, 1.0)
    np.testing.assert_array_equal(res.column_weights(), base.column_weights())
    np.testing.assert_array_equal(res.link_flow_counts(),
                                  base.link_flow_counts())
    np.testing.assert_array_equal(
        throughput_from_result(res).rates, throughput_from_result(base).rates)


def test_legacy_strategy_without_demand_mode_kwarg(paper_compiled,
                                                   paper_setup):
    """Custom strategies registered against the pre-demand route()
    signature keep working under uniform demand; asking them for byte
    weighting fails loudly instead of silently dropping the weights."""
    from repro.core import RoutingStrategy

    class Legacy(RoutingStrategy):
        name = "legacy"

        def route(self, comp, flows, seeds_u64, *, fields, hash_backend,
                  max_hops, field_matrix):
            return simulate_paths(comp, flows, seeds_u64, fields=fields,
                                  hash_backend=hash_backend,
                                  max_hops=max_hops,
                                  field_matrix=field_matrix)

    _, _, flows = paper_setup
    res = simulate_paths(paper_compiled, flows[:4], [0], strategy=Legacy())
    assert res.num_flows == 4
    with pytest.raises(TypeError, match="demand_mode"):
        simulate_paths(paper_compiled, flows[:4], [0], strategy=Legacy(),
                       demand_mode=DEMAND_BYTES)


def test_monte_carlo_fronts_bit_identical_on_equal_bytes(paper_compiled,
                                                         paper_setup):
    flows = _hetero_flows(paper_setup, [512])[:32]
    seeds = np.arange(4)
    for strategy in (None, "prime-spray", "congestion-aware"):
        a = monte_carlo_fim(paper_compiled, flows, seeds, strategy=strategy)
        b = monte_carlo_fim(paper_compiled, flows, seeds, strategy=strategy,
                            demand_mode=DEMAND_BYTES)
        np.testing.assert_array_equal(a.aggregate, b.aggregate)
        ta = monte_carlo_throughput(paper_compiled, flows, seeds,
                                    strategy=strategy)
        tb = monte_carlo_throughput(paper_compiled, flows, seeds,
                                    strategy=strategy,
                                    demand_mode=DEMAND_BYTES)
        np.testing.assert_array_equal(ta.rates, tb.rates)


# ---------------------------------------------------------------------------
# heterogeneous bytes actually move the answer (regression half)
# ---------------------------------------------------------------------------


def test_hetero_bytes_change_fim_and_rates(paper_compiled, paper_setup):
    """Fails on the historical unit-demand pipeline: a 1 GB elephant and
    a 1 KB mouse weighed identically in FIM and max-min."""
    flows = _hetero_flows(paper_setup, [1 << 30, 1 << 10])
    seeds = np.arange(8)
    uni = simulate_paths(paper_compiled, flows, seeds)
    wtd = simulate_paths(paper_compiled, flows, seeds,
                         demand_mode=DEMAND_BYTES)
    # identical paths (ECMP ignores demand) ...
    np.testing.assert_array_equal(uni.link_ids, wtd.link_ids)
    # ... but weighted FIM and weighted rates tell a different story
    assert not np.allclose(fim_vector(uni), fim_vector(wtd))
    ru = throughput_from_result(uni).rates
    rw = throughput_from_result(wtd).rates
    assert not np.allclose(ru, rw)
    # elephants claim more than mice under weighted max-min (exact
    # proportional sharing is pinned by the forced-bottleneck test and
    # the scalar-reference differential below)
    assert rw[0::2].mean() > rw[1::2].mean()


def test_throughput_aggregation_is_demand_weighted(paper_compiled,
                                                   paper_setup):
    """S1 regression: two flows sharing one bottleneck with 3:1 byte
    demand must split it 3:1 (a plain unit-demand fill gives 1:1)."""
    _, _, flows = paper_setup
    f0, f1 = flows[0], flows[1]
    pair = [
        Flow(0, f0.src, f0.dst, f0.tuple5, bytes=3 * (1 << 20)),
        Flow(1, f1.src, f1.dst, f1.tuple5, bytes=1 << 20),
    ]
    res = simulate_paths(paper_compiled, pair, [0], demand_mode=DEMAND_BYTES)
    # force a shared single-link contention: replace walked paths with one
    # common link so the split ratio is exactly the demand ratio
    res.link_ids = np.zeros((1, 2, 1), np.int32)
    tp = throughput_from_result(res)
    assert tp.rates[0, 0] == pytest.approx(3.0 * tp.rates[1, 0])
    cap = float(res.compiled.link_gbps[0])
    assert tp.rates[:, 0].sum() == pytest.approx(cap)


def test_spray_composes_flow_demand_with_flowlet_fractions(paper_compiled,
                                                           paper_setup):
    flows = _hetero_flows(paper_setup, [1 << 28, 1 << 12])[:32]
    res = simulate_paths(paper_compiled, flows, [3],
                         strategy=PrimeSpraying(flowlets=4),
                         demand_mode=DEMAND_BYTES)
    w = res.column_weights()
    # each column = parent weight / K; per-flow sums recover flow_demand
    per_flow = np.bincount(res.flow_index, weights=w, minlength=len(flows))
    np.testing.assert_allclose(per_flow, res.flow_demand, rtol=1e-12)
    # total per-layer load comparable with single-path: sum of weights
    np.testing.assert_allclose(w.sum(), res.flow_demand.sum(), rtol=1e-12)


def test_congestion_aware_places_largest_first(paper_compiled, paper_setup):
    """The heaviest flow must see an empty fabric: its path load is laid
    down before any lighter flow's, so under byte demand its first-hop
    choice equals the choice on an unloaded fabric."""
    flows = _hetero_flows(paper_setup, [1, 2, 4, 1 << 30])[:64]
    heavy = max(range(len(flows)), key=lambda i: flows[i].bytes)
    res = simulate_paths(paper_compiled, flows, [0],
                         strategy=CongestionAware(),
                         demand_mode=DEMAND_BYTES)
    alone = simulate_paths(paper_compiled, [flows[heavy]], [0],
                           strategy=CongestionAware())
    np.testing.assert_array_equal(
        res.link_ids[:, heavy, :], alone.link_ids[:, 0, :])


def test_congestion_aware_weighted_loads_change_placement(paper_compiled,
                                                          paper_setup):
    """With weighted tallies a placed elephant repels later flows; unit
    tallies would let them pile onto its links."""
    flows = _hetero_flows(paper_setup, [1 << 30, 1 << 10])
    seeds = np.arange(4)
    uni = simulate_paths(paper_compiled, flows, seeds,
                         strategy=CongestionAware())
    wtd = simulate_paths(paper_compiled, flows, seeds,
                         strategy=CongestionAware(),
                         demand_mode=DEMAND_BYTES)
    assert not np.array_equal(uni.link_ids, wtd.link_ids)
    # and the weighted placement spreads bytes better than hashing does
    ecmp = simulate_paths(paper_compiled, flows, seeds,
                          demand_mode=DEMAND_BYTES)
    assert fim_vector(wtd).mean() < fim_vector(ecmp).mean()


# ---------------------------------------------------------------------------
# differential: weighted pipeline vs scalar weighted reference
# ---------------------------------------------------------------------------


@given(st.integers(0, 2**31))
@settings(max_examples=8, deadline=None)
def test_bytes_pipeline_matches_scalar_weighted_reference(rngseed):
    """End-to-end differential: random heterogeneous volumes through
    simulate_paths(demand_mode="bytes") + throughput_from_result equal a
    readable scalar weighted progressive fill on the walked paths."""
    rng = np.random.default_rng(rngseed)
    fab = compile_fabric(build_paper_testbed(servers_per_rack=2))
    wl = bipartite_pairs([server_name(0), server_name(1)],
                         [server_name(2), server_name(3)], flows_per_pair=4)
    flows = [
        Flow(f.flow_id, f.src, f.dst, f.tuple5,
             bytes=int(rng.integers(1, 1 << 32)))
        for f in synthesize_flows(wl, nic_ip=nic_ip, nics_per_server=2)
    ]
    seeds = [int(rng.integers(0, 2**63)) for _ in range(2)]
    res = simulate_paths(fab, flows, seeds, demand_mode=DEMAND_BYTES)
    tp = throughput_from_result(res)
    w = res.flow_demand
    link_index = {link: i for i, link in enumerate(fab.links)}
    for s in range(len(seeds)):
        paths = {
            j: [link_index[link] for link in path]
            for j, (fid, path) in enumerate(
                sorted(res.paths_for_seed(s).items()))
        }
        ref = weighted_max_min_ref(paths, list(fab.link_gbps),
                                   {j: w[j] for j in paths})
        for j in paths:
            assert tp.rates[j, s] == pytest.approx(ref[j], rel=1e-9)


# ---------------------------------------------------------------------------
# PairSpec byte specs + workload accounting (S2)
# ---------------------------------------------------------------------------


def test_pairspec_bytes_override_and_total_bytes():
    wl = WorkloadDescription(pairs=[
        PairSpec("srv-0", "srv-1", 2, bytes_per_flow=100),
        PairSpec("srv-1", "srv-0", 3),
    ])
    assert wl.total_flows == 5
    assert wl.total_bytes == 200          # unspecified pairs count 0
    flows = synthesize_flows(wl, nic_ip=nic_ip, bytes_per_flow=7)
    assert [f.bytes for f in flows] == [100, 100, 7, 7, 7]


def test_bipartite_pairs_per_pair_volumes():
    a = [server_name(i) for i in range(2)]
    b = [server_name(2 + i) for i in range(2)]
    wl = bipartite_pairs(a, b, 3, bytes_per_flow=[10, 20])
    assert [p.bytes_per_flow for p in wl.pairs] == [10, 10, 20, 20]
    assert wl.total_bytes == 3 * (10 + 10 + 20 + 20)
    scalar = bipartite_pairs(a, b, 3, bytes_per_flow=5)
    assert {p.bytes_per_flow for p in scalar.pairs} == {5}
    with pytest.raises(ValueError, match="bytes_per_flow"):
        bipartite_pairs(a, b, 3, bytes_per_flow=[10])
    with pytest.raises(TypeError, match="bytes_per_flow"):
        bipartite_pairs(a, b, 3, bytes_per_flow="12")  # not char-by-char


def test_workload_description_bytes_reach_demand(paper_compiled):
    """A byte-weighted WorkloadDescription drives weighted FIM through
    the Monte-Carlo front end without an explicit flow list."""
    a = [server_name(i) for i in range(8)]
    b = [server_name(8 + i) for i in range(8)]
    wl = bipartite_pairs(a, b, 4,
                         bytes_per_flow=[1 << 30] * 2 + [1 << 10] * 6)
    flows = resolve_flows(paper_compiled, wl)
    assert sum(f.bytes for f in flows) == wl.total_bytes
    seeds = np.arange(4)
    u = monte_carlo_fim(paper_compiled, wl, seeds)
    w = monte_carlo_fim(paper_compiled, wl, seeds, demand_mode=DEMAND_BYTES)
    assert not np.allclose(u.aggregate, w.aggregate)


def test_workload_from_flows_roundtrip(paper_setup):
    flows = _hetero_flows(paper_setup, [1000])[:48]
    wl = workload_from_flows(flows)
    assert wl.total_flows == len(flows)
    assert wl.total_bytes == sum(f.bytes for f in flows)
    assert all(p.bytes_per_flow == 1000 for p in wl.pairs)
    # an all-zero pair must pin 0 explicitly, not fall back to the
    # synthesize-time default volume
    zeros = workload_from_flows(_hetero_flows(paper_setup, [0])[:8])
    assert all(p.bytes_per_flow == 0 for p in zeros.pairs)
    resyn = synthesize_flows(zeros, nic_ip=nic_ip, bytes_per_flow=999)
    assert all(f.bytes == 0 for f in resyn)


def test_bipartite_pairs_numpy_scalar_volume():
    a, b = [server_name(0)], [server_name(1)]
    wl = bipartite_pairs(a, b, 2, bytes_per_flow=np.int64(1 << 20))
    assert [p.bytes_per_flow for p in wl.pairs] == [1 << 20, 1 << 20]


# ---------------------------------------------------------------------------
# LLM workload generator
# ---------------------------------------------------------------------------


def test_llm_collective_ops_mix():
    spec = LlmJobSpec(num_hosts=16)
    ops = llm_collective_ops(spec)
    kinds = [op.kind for op in ops]
    assert kinds == ["all-reduce", "all-gather", "reduce-scatter",
                     "all-to-all", "all-reduce"]
    ar, ag, rs, a2a, barrier = ops
    assert ar.wire_bytes > a2a.wire_bytes > barrier.wire_bytes
    assert ag.multiplier == spec.num_layers
    # FSDP traffic (gather + scatter) totals ~ the gradient all-reduce
    assert (ag.total_wire_bytes + rs.total_wire_bytes
            == pytest.approx(ar.total_wire_bytes, rel=0.1))


def test_paper_testbed_llm_scenario(paper_compiled):
    wl, flows, stats = paper_testbed_llm_workload()
    assert stats.inter_pod_dcn == len(flows) > 250
    assert stats.intra_host == stats.intra_pod_ici == 0
    assert {f.src for f in flows} <= {server_name(i) for i in range(16)}
    volumes = sorted({f.bytes for f in flows})
    assert volumes[-1] / volumes[0] > 1e6      # elephants and mice
    # per-pair mean rounding: the description is pair-granular
    assert wl.total_bytes == pytest.approx(sum(f.bytes for f in flows),
                                           rel=1e-6)
    # the committed acceptance scenario: weighted FIM != unweighted FIM
    seeds = np.arange(8)
    for strategy in (None, "prime-spray", "congestion-aware"):
        u = monte_carlo_fim(paper_compiled, flows, seeds, strategy=strategy)
        w = monte_carlo_fim(paper_compiled, flows, seeds, strategy=strategy,
                            demand_mode=DEMAND_BYTES)
        assert not np.allclose(u.aggregate, w.aggregate), strategy


def test_multipod_llm_scenario_splits_ici_and_dcn(multipod_small):
    fab, _, _ = multipod_small
    from repro.core import multipod_llm_workload
    wl, flows, stats = multipod_llm_workload()
    assert stats.intra_pod_ici > 0          # FSDP rings mostly stay on ICI
    assert stats.inter_pod_dcn == len(flows) > 0
    assert stats.ici_bytes > stats.dcn_bytes
    res = simulate_paths(compile_fabric(fab), flows, [0, 1],
                         demand_mode=DEMAND_BYTES)
    assert res.link_ids.shape[1] == len(flows)
    assert (res.flow_demand > 0).all()
