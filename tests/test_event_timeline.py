"""Event-timed replay tests (timing="event"): departure fill + timeline.

Three layers of coverage, mirroring the module's anchors:

* ``departure_fill`` against hand-computed water-filling-with-departures
  scenarios (the two-flow one-link analytic case to 1e-9, weighted and
  efficiency-scaled variants, degenerate columns);
* the degenerate anchors of ``simulate_timeline(timing="event")``: a
  one-step schedule is bit-identical in rates/FIM to ``timing="static"``,
  and a tiny single-candidate fabric reproduces the analytic completion
  times under every strategy and both engines;
* the headline directional claim: on the committed multipod
  disjoint-elephant schedule, ECMP's collision-lengthened steps give a
  strictly worse per-seed job completion time than spray/wave placement
  — the metric the static FIM comparison provably cannot show.
"""

import dataclasses
import warnings

import numpy as np
import pytest

import repro.core.timeline as timeline_mod
from repro.core import (
    AdaptiveSpraying, CH_GRAD_AR, CH_MOE_A2A, DEFAULT_RTT_SECONDS, Device,
    ENGINE_JAX, FiveTuple, Flow, HOST_TO_LEAF, IDEAL, LEAF_TO_HOST, Link,
    PrimeSpraying, ROCE_NACK, SimSpec, TIMING_EVENT, TIMING_STATIC,
    TimelineStep, TransportProfile, build_multipod_fabric, compile_fabric,
    departure_fill, flow_channel, known_channels, merged_step,
    multipod_llm_schedule, nic_ip, paper_testbed_llm_schedule,
    partition_flows, rtt_round_budget, simulate_timeline, step_byte_totals,
)
from repro.core.fabric import Fabric, LEAF, SERVER

# ---------------------------------------------------------------------------
# departure_fill: hand-computed water-filling with departures
# ---------------------------------------------------------------------------


def test_departure_fill_two_flows_one_link_analytic():
    """Two flows share one 100 Gb/s link, 8 and 24 Gbit: both drain at
    50 until the small one departs at t=0.16 s, then the big one runs
    alone at 100 — 16 Gbit left, so it completes at exactly 0.32 s."""
    ids = np.zeros((1, 2, 3), np.int64)
    dep = departure_fill(ids, np.array([100.0]), np.array([8.0, 24.0]))
    np.testing.assert_allclose(
        dep.completion, [[0.16] * 3, [0.32] * 3], rtol=0, atol=1e-9)
    np.testing.assert_allclose(dep.duration, 0.32, rtol=0, atol=1e-9)
    assert dep.rounds == 2


def test_departure_fill_efficiency_scales_time():
    ids = np.zeros((1, 2, 1), np.int64)
    dep = departure_fill(ids, np.array([100.0]), np.array([8.0, 24.0]),
                         efficiency=np.full((2, 1), 0.5))
    np.testing.assert_allclose(
        dep.completion[:, 0], [0.32, 0.64], rtol=0, atol=1e-9)


def test_departure_fill_weighted_simultaneous():
    """Weights proportional to bytes: rates 25/75 for 8/24 Gbit, so both
    cells complete at the same instant in a single round."""
    ids = np.zeros((1, 2, 1), np.int64)
    dep = departure_fill(ids, np.array([100.0]), np.array([8.0, 24.0]),
                         weights=np.array([1.0, 3.0]))
    np.testing.assert_allclose(
        dep.completion[:, 0], [0.32, 0.32], rtol=0, atol=1e-9)
    assert dep.rounds == 1


def test_departure_fill_degenerate_columns():
    # zero-gigabit columns finish at t=0 and never contend: the live
    # column gets the whole link from the start
    ids = np.zeros((1, 2, 1), np.int64)
    dep = departure_fill(ids, np.array([100.0]), np.array([0.0, 10.0]))
    np.testing.assert_allclose(dep.completion[:, 0], [0.0, 0.1],
                               rtol=0, atol=1e-12)
    # a link-free column drains at infinite rate: completes at t=0
    ids2 = np.stack([np.array([[0], [-1]])])
    dep2 = departure_fill(ids2, np.array([100.0]), np.array([10.0, 10.0]))
    np.testing.assert_allclose(dep2.completion[:, 0], [0.1, 0.0],
                               rtol=0, atol=1e-12)


def test_departure_fill_per_seed_independence():
    """Seeds depart independently: seed 0 shares the link, seed 1 puts
    the flows on disjoint links — different completion schedules."""
    ids = np.zeros((1, 2, 2), np.int64)
    ids[0, 1, 1] = 1                        # seed 1: second flow alone
    dep = departure_fill(ids, np.array([100.0, 100.0]),
                         np.array([8.0, 8.0]))
    np.testing.assert_allclose(dep.completion[:, 0], [0.16, 0.16],
                               rtol=0, atol=1e-12)
    np.testing.assert_allclose(dep.completion[:, 1], [0.08, 0.08],
                               rtol=0, atol=1e-12)


def test_departure_fill_validation():
    ids = np.zeros((1, 2, 1), np.int64)
    with pytest.raises(ValueError, match="col_gbits"):
        departure_fill(ids, np.array([100.0]), np.array([1.0]))
    with pytest.raises(ValueError, match="finite"):
        departure_fill(ids, np.array([100.0]), np.array([-1.0, 1.0]))
    with pytest.raises(ValueError, match="efficiency"):
        departure_fill(ids, np.array([100.0]), np.array([1.0, 1.0]),
                       efficiency=np.zeros((2, 1)))
    with pytest.raises(RuntimeError, match="zero goodput"):
        departure_fill(ids, np.array([0.0]), np.array([1.0, 1.0]))
    with pytest.raises(ValueError, match="initial_rates"):
        departure_fill(ids, np.array([100.0]), np.array([1.0, 1.0]),
                       initial_rates=np.ones((3, 1)))


def test_departure_fill_initial_rates_reuse_is_exact():
    rng = np.random.default_rng(7)
    ids = rng.integers(0, 6, size=(3, 8, 5))
    gb = rng.uniform(0.5, 20.0, size=8)
    cap = rng.uniform(50.0, 200.0, size=6)
    base = departure_fill(ids, cap, gb, assume_unique=True)
    from repro.core import batched_max_min
    pre = batched_max_min(ids, cap, assume_unique=True)
    reused = departure_fill(ids, cap, gb, assume_unique=True,
                            initial_rates=pre)
    np.testing.assert_array_equal(base.completion, reused.completion)


# ---------------------------------------------------------------------------
# the analytic anchor fabric: one candidate per hop, every strategy equal
# ---------------------------------------------------------------------------


def _two_server_fabric() -> Fabric:
    """srv-0 -> leaf-0 -> srv-1 with exactly one candidate at every hop,
    so ECMP, spraying, and placement all route identically and the
    event-timed completion times are the analytic water-filling ones."""
    devices = [Device("srv-0", SERVER), Device("srv-1", SERVER),
               Device("leaf-0", LEAF)]
    links = []
    for i in (0, 1):
        links.append(Link(f"srv-{i}", "nic0p0", "leaf-0", f"swp{i}",
                          100.0, HOST_TO_LEAF))
        links.append(Link("leaf-0", f"dwn{i}", f"srv-{i}", "nic0p0",
                          100.0, LEAF_TO_HOST))
    return Fabric(devices, links)


def _xfer_flows(bytes_a: int, bytes_b: int) -> list[Flow]:
    flows = []
    for fid, b in enumerate((bytes_a, bytes_b)):
        flows.append(Flow(
            flow_id=fid, src="srv-0", dst="srv-1",
            tuple5=FiveTuple(nic_ip("srv-0", 0), nic_ip("srv-1", 0),
                             10000 + fid, 20000 + fid),
            bytes=b, label=f"xfer-{fid}#ch{CH_GRAD_AR}"))
    return flows


ANALYTIC_STRATEGIES = ["ecmp", "prime-spray", "adaptive-spray",
                       "congestion-aware", "wave-congestion-aware"]


@pytest.mark.parametrize("engine", ["numpy", ENGINE_JAX])
@pytest.mark.parametrize("strategy", ANALYTIC_STRATEGIES)
def test_event_analytic_completion_per_strategy(strategy, engine):
    """1 GB and 3 GB flows down one shared 100 Gb/s path: rates 50/50,
    the 8-Gbit flow departs at 0.16 s, the survivor finishes its
    remaining 16 Gbit at 100 Gb/s — job completion exactly 0.32 s,
    under every strategy and both engines (single candidate per hop)."""
    comp = compile_fabric(_two_server_fabric())
    flows = _xfer_flows(1_000_000_000, 3_000_000_000)
    sched = [TimelineStep("xfer", (CH_GRAD_AR,))]
    tl = simulate_timeline(
        comp, flows, sched, [0, 3], spec=SimSpec(
            strategy=strategy, timing=TIMING_EVENT, engine=engine))
    np.testing.assert_allclose(tl.job_completion, 0.32, rtol=1e-9)
    np.testing.assert_allclose(tl.steps[0].completion[:, 0], [0.16, 0.32],
                               rtol=1e-9)
    np.testing.assert_allclose(tl.steps[0].duration, 0.32, rtol=1e-9)
    # absolute time axis: one step starting at t=0
    np.testing.assert_array_equal(tl.step_starts, np.zeros((1, 2)))
    np.testing.assert_array_equal(tl.step_ends[0], tl.job_completion)
    np.testing.assert_array_equal(tl.flow_completion(0),
                                  tl.steps[0].completion)


# ---------------------------------------------------------------------------
# degenerate anchor: one-step schedule, event == static bit-identically
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", ["ecmp", "prime-spray-elephant"])
def test_one_step_uniform_bytes_event_matches_static(paper_compiled,
                                                     strategy):
    """The per-step FIM/rate/goodput snapshots are computed identically
    under both timings, so a one-step uniform-bytes schedule reproduces
    the static result bit for bit — event timing only *adds* the time
    axis on top."""
    _, flows, _, schedule = paper_testbed_llm_schedule()
    uniform = [dataclasses.replace(f, bytes=10_000_000) for f in flows]
    one = [merged_step(schedule)]
    seeds = [0, 7, 1234567]
    kw = dict(demand_mode="bytes", transport="roce-nack", strategy=strategy)
    static = simulate_timeline(paper_compiled, uniform, one, seeds,
                               timing=TIMING_STATIC, **kw)
    event = simulate_timeline(paper_compiled, uniform, one, seeds,
                              timing=TIMING_EVENT, **kw)
    np.testing.assert_array_equal(event.fim, static.fim)
    np.testing.assert_array_equal(event.rates, static.rates)
    np.testing.assert_array_equal(event.goodput, static.goodput)
    np.testing.assert_array_equal(event.steps[0].throughput.rates,
                                  static.steps[0].throughput.rates)
    np.testing.assert_array_equal(event.steps[0].throughput.goodput,
                                  static.steps[0].throughput.goodput)
    for layer, series in static.steps[0].fim.per_layer.items():
        np.testing.assert_array_equal(event.steps[0].fim.per_layer[layer],
                                      series)
    # and the event extras exist only on the event result
    assert static.job_completion is None and static.timing == TIMING_STATIC
    assert event.timing == TIMING_EVENT
    assert event.job_completion.shape == (len(seeds),)
    assert (event.job_completion > 0).all()
    np.testing.assert_array_equal(event.job_completion,
                                  event.steps[0].duration)


# ---------------------------------------------------------------------------
# the headline: per-strategy job completion time on disjoint elephants
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def multipod_elephants():
    comp = compile_fabric(build_multipod_fabric())
    _, flows, _, _ = multipod_llm_schedule(param_bytes=20_000_000_000)
    sub = [f for f in flows
           if flow_channel(f) in (CH_GRAD_AR, CH_MOE_A2A)]
    sched = [TimelineStep("grad-all-reduce", (CH_GRAD_AR,)),
             TimelineStep("moe-all-to-all", (CH_MOE_A2A,))]
    return comp, sub, sched


def test_event_jct_ecmp_strictly_worse_than_spray_and_wave(
        multipod_elephants):
    """The committed multipod disjoint-elephant schedule under event
    timing: ECMP's hash collisions halve elephant goodput, which now
    *lengthens* the gradient all-reduce step — so its per-seed job
    completion time is strictly worse than spraying (which splits the
    elephants across paths) and wave placement (which avoids the
    collisions outright), on every seed.  This is the degradation the
    static FIM comparison cannot show: FIM says "imbalanced", JCT says
    "slower"."""
    comp, sub, sched = multipod_elephants
    seeds = np.arange(16)
    jct = {}
    for strategy in ("ecmp", "prime-spray", "wave-congestion-aware"):
        tl = simulate_timeline(comp, sub, sched, seeds, spec=SimSpec(
            demand_mode="bytes", strategy=strategy, timing=TIMING_EVENT))
        assert tl.job_completion.shape == (16,)
        assert np.isfinite(tl.job_completion).all()
        # steps run back to back: ends - starts == durations, last end
        # is the job completion
        np.testing.assert_allclose(tl.step_ends - tl.step_starts,
                                   tl.step_durations)
        np.testing.assert_array_equal(tl.step_ends[-1], tl.job_completion)
        jct[strategy] = tl.job_completion
    assert (jct["ecmp"] > jct["prime-spray"]).all()
    assert (jct["ecmp"] > jct["wave-congestion-aware"]).all()
    # and the margin is the collision-halved elephant, not float noise
    assert jct["ecmp"].mean() > 1.2 * jct["prime-spray"].mean()
    assert jct["ecmp"].mean() > 1.5 * jct["wave-congestion-aware"].mean()


def test_event_multi_step_totals_are_per_seed_weighted(multipod_elephants):
    comp, sub, sched = multipod_elephants
    seeds = np.arange(4)
    tl = simulate_timeline(comp, sub, sched, seeds, spec=SimSpec(
        demand_mode="bytes", timing=TIMING_EVENT))
    wks = tl.step_durations / tl.step_durations.sum(axis=0)
    np.testing.assert_allclose(
        tl.fim, (wks * tl.step_fim()).sum(axis=0), rtol=0, atol=0)
    # display weights are the seed-mean duration shares, normalized
    w = tl.step_durations.mean(axis=1)
    np.testing.assert_allclose(tl.weights, w / w.sum())
    # byte totals attach through the flows' channel labels
    totals = step_byte_totals(sub, sched)
    assert totals.shape == (2,) and (totals > 0).all()
    assert totals[0] > totals[1]            # the all-reduce elephants


def test_event_timing_jax_matches_numpy(multipod_elephants):
    comp, sub, sched = multipod_elephants
    seeds = np.arange(3)
    a = simulate_timeline(comp, sub, sched, seeds, spec=SimSpec(
        demand_mode="bytes", timing=TIMING_EVENT))
    b = simulate_timeline(comp, sub, sched, seeds, spec=SimSpec(
        demand_mode="bytes", timing=TIMING_EVENT, engine=ENGINE_JAX))
    np.testing.assert_allclose(a.job_completion, b.job_completion,
                               rtol=1e-6)
    np.testing.assert_allclose(a.fim, b.fim, rtol=1e-6)


# ---------------------------------------------------------------------------
# RTT round budget: adaptation priced per unit time
# ---------------------------------------------------------------------------


def test_rtt_round_budget_math():
    assert rtt_round_budget(0.0, 25e-6, 4) == 1       # sub-RTT: no feedback
    assert rtt_round_budget(1e-9, 25e-6, 4) == 1
    assert rtt_round_budget(26e-6, 25e-6, 4) == 2
    assert rtt_round_budget(1.0, 25e-6, 4) == 4       # capped
    with pytest.raises(ValueError, match="rtt_s"):
        rtt_round_budget(1.0, 0.0, 4)
    with pytest.raises(ValueError, match="cap"):
        rtt_round_budget(1.0, 25e-6, 0)
    with pytest.raises(ValueError, match="duration_s"):
        rtt_round_budget(-1.0, 25e-6, 4)
    with pytest.raises(ValueError, match="rtt_seconds"):
        TransportProfile("bad-rtt", alpha=1.0, floor=0.5, rtt_seconds=0.0)
    assert IDEAL.rtt_seconds == DEFAULT_RTT_SECONDS


def test_with_rounds_copies_everything_else():
    s = AdaptiveSpraying(4, min_bytes=1e6, volume_k=True, rounds=4,
                         ecn_factor=1.5, respray_cost=0.1, move_prob=0.5)
    assert s.with_rounds(4) is s
    s2 = s.with_rounds(2)
    assert s2.rounds == 2
    for attr in ("flowlets", "parts", "min_bytes", "volume_k",
                 "ecn_factor", "respray_cost", "move_prob"):
        assert getattr(s2, attr) == getattr(s, attr)


def test_event_adaptive_sub_rtt_step_cannot_adapt(paper_compiled):
    """With a transport whose RTT exceeds every derived step duration,
    the budget clamps to 1 round — AdaptiveSpraying must reproduce the
    static spray result bit-identically (rounds=1 IS PrimeSpraying)."""
    _, flows, _, schedule = paper_testbed_llm_schedule()
    seeds = [0, 5]
    slow_feedback = TransportProfile(
        "slow-feedback", alpha=ROCE_NACK.alpha, floor=ROCE_NACK.floor,
        rtt_seconds=1e6)
    adaptive = simulate_timeline(
        paper_compiled, flows, schedule, seeds, spec=SimSpec(
            demand_mode="bytes", transport=slow_feedback,
            strategy=AdaptiveSpraying(8, rounds=4), timing=TIMING_EVENT))
    static = simulate_timeline(
        paper_compiled, flows, schedule, seeds, spec=SimSpec(
            demand_mode="bytes", transport=slow_feedback,
            strategy=PrimeSpraying(8), timing=TIMING_EVENT))
    np.testing.assert_array_equal(adaptive.job_completion,
                                  static.job_completion)
    np.testing.assert_array_equal(adaptive.fim, static.fim)
    np.testing.assert_array_equal(adaptive.goodput, static.goodput)


# ---------------------------------------------------------------------------
# satellite: weight alias + strict channel validation
# ---------------------------------------------------------------------------


def test_timeline_step_weight_alias_deprecated_once():
    timeline_mod._WEIGHT_ALIAS_WARNED = False
    with pytest.warns(DeprecationWarning, match="duration"):
        s = TimelineStep("x", (1,), weight=2.5)
    assert s.duration == 2.5
    assert s.weight == 2.5                  # read-side alias
    # warned once per process: the second use stays silent
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        s2 = TimelineStep("y", (2,), weight=1.5)
    assert s2.duration == 1.5
    with pytest.raises(TypeError, match="alias"):
        TimelineStep("z", (1,), duration=1.0, weight=1.0)
    # no silent behavior change: replace() round-trips the real field
    assert dataclasses.replace(s, duration=3.0).duration == 3.0


def test_unknown_channel_error_names_registered_vocabulary():
    _, flows, _, _ = paper_testbed_llm_schedule()
    with pytest.raises(ValueError) as ei:
        partition_flows(flows, [merged_step(
            [TimelineStep("all", (1, 2, 3, 4, 5))]),
            TimelineStep("ghost", (42,))])
    msg = str(ei.value)
    assert "42" in msg and "CH_MOE_A2A" in msg and "CH_GRAD_AR" in msg
    assert "1 (CH_GRAD_AR)" in known_channels()
    with pytest.raises(ValueError, match="empty"):
        partition_flows([], [TimelineStep("a", (1,))])


def test_register_channel_duplicate_raises():
    from repro.core import register_channel
    assert register_channel(1, "CH_GRAD_AR") == 1    # same pair: no-op
    with pytest.raises(ValueError, match="already registered"):
        register_channel(1, "CH_SOMETHING_ELSE")
    register_channel(93171, "CH_TEST_TMP")
    try:
        with pytest.raises(ValueError, match="replace=True"):
            register_channel(93171, "CH_TEST_TMP2")
        register_channel(93171, "CH_TEST_TMP2", replace=True)
    finally:
        timeline_mod._CHANNEL_NAMES.pop(93171, None)


# ---------------------------------------------------------------------------
# heavyweight sweep (excluded from the CI tier-1 run)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_event_timeline_sweep_slow():
    """Large event-timed sweep at benchmark scale, shape-scaled by
    FLOWTRACER_SWEEP_FLOWS / FLOWTRACER_SWEEP_SEEDS: JCT stays finite,
    reproducible, and ordered (ECMP never beats wave placement)."""
    import os
    flow_scale = int(os.environ.get("FLOWTRACER_SWEEP_FLOWS", 0))
    num_seeds = int(os.environ.get("FLOWTRACER_SWEEP_SEEDS", 64))
    param_bytes = max(20_000_000_000, flow_scale * 1_000_000)
    comp = compile_fabric(build_multipod_fabric())
    _, flows, _, sched = multipod_llm_schedule(param_bytes=param_bytes)
    seeds = np.arange(num_seeds)
    results = {}
    for strategy in ("ecmp", "prime-spray-elephant",
                     "wave-congestion-aware"):
        tl = simulate_timeline(comp, flows, sched, seeds, spec=SimSpec(
            demand_mode="bytes", transport="roce-nack", strategy=strategy,
            timing=TIMING_EVENT))
        assert np.isfinite(tl.job_completion).all()
        assert (tl.job_completion > 0).all()
        results[strategy] = tl
    again = simulate_timeline(comp, flows, sched, seeds, spec=SimSpec(
        demand_mode="bytes", transport="roce-nack", strategy="ecmp",
        timing=TIMING_EVENT))
    np.testing.assert_array_equal(results["ecmp"].job_completion,
                                  again.job_completion)
    assert (results["ecmp"].job_completion.mean()
            > results["wave-congestion-aware"].job_completion.mean())
