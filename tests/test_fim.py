"""Property tests for the Flow Imbalance Metric (paper eq. 1)."""

import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core.fabric import Device, Fabric, Link, SERVER, LEAF
from repro.core.fim import fim, max_min_throughput, per_layer_fim


def _line_fabric(n_links: int) -> Fabric:
    """One layer of n parallel links between two devices."""
    devices = [Device("a", LEAF), Device("b", SERVER)]
    links = [Link("a", f"p{i}", "b", f"q{i}", 100.0, "layer") for i in range(n_links)]
    return Fabric(devices, links)


def _paths_from_counts(fab: Fabric, counts: list[int]):
    paths = {}
    fid = 0
    for link, c in zip(fab.links, counts):
        for _ in range(c):
            paths[fid] = [link]
            fid += 1
    return paths


@given(st.lists(st.integers(0, 50), min_size=2, max_size=32))
@settings(max_examples=200, deadline=None)
def test_fim_matches_mape_formula(counts):
    if sum(counts) == 0:
        return
    fab = _line_fabric(len(counts))
    paths = _paths_from_counts(fab, counts)
    n = len(counts)
    ideal = sum(counts) / n
    expected = 100.0 / n * sum(abs(c - ideal) / ideal for c in counts)
    assert fim(paths, fab) == pytest.approx(expected, rel=1e-9)


@given(st.integers(1, 20), st.integers(2, 16))
@settings(max_examples=50, deadline=None)
def test_fim_zero_iff_balanced(per_link, n_links):
    fab = _line_fabric(n_links)
    paths = _paths_from_counts(fab, [per_link] * n_links)
    assert fim(paths, fab) == pytest.approx(0.0, abs=1e-12)


@given(st.lists(st.integers(0, 20), min_size=2, max_size=16))
@settings(max_examples=100, deadline=None)
def test_fim_nonnegative_and_permutation_invariant(counts):
    if sum(counts) == 0:
        return
    fab = _line_fabric(len(counts))
    f1 = fim(_paths_from_counts(fab, counts), fab)
    rng = np.random.default_rng(0)
    perm = list(rng.permutation(counts))
    f2 = fim(_paths_from_counts(fab, perm), fab)
    assert f1 >= 0
    assert f1 == pytest.approx(f2, rel=1e-9)


@given(st.lists(st.integers(0, 10), min_size=2, max_size=12),
       st.integers(2, 5))
@settings(max_examples=100, deadline=None)
def test_fim_scale_invariant(counts, k):
    """k x the flows on every link -> identical FIM (it is a percentage)."""
    if sum(counts) == 0:
        return
    fab = _line_fabric(len(counts))
    f1 = fim(_paths_from_counts(fab, counts), fab)
    f2 = fim(_paths_from_counts(fab, [c * k for c in counts]), fab)
    assert f1 == pytest.approx(f2, rel=1e-9)


def test_per_layer_drops_idle_layers():
    fab = _line_fabric(4)
    paths = _paths_from_counts(fab, [1, 1, 1, 1])
    layers = per_layer_fim(paths, fab, layers=["layer", "nonexistent"])
    assert list(layers) == ["layer"]


def _multi_layer_fabric(n_layers: int, n_links: int) -> Fabric:
    """A chain a -> h0 -> h1 -> ... -> b with n parallel links per stage."""
    names = ["a"] + [f"h{i}" for i in range(n_layers - 1)] + ["b"]
    devices = [Device(names[0], LEAF)] + \
        [Device(n, LEAF) for n in names[1:-1]] + [Device(names[-1], SERVER)]
    links = [
        Link(names[s], f"p{s}-{i}", names[s + 1], f"q{s}-{i}", 100.0, f"L{s}")
        for s in range(n_layers) for i in range(n_links)
    ]
    return Fabric(devices, links)


def test_only_used_leaves_filters_idle_devices():
    """Links touching devices that carried no traffic are excluded."""
    fab = _multi_layer_fabric(1, 3)
    extra = Fabric(
        list(fab.devices.values()) + [Device("idle", LEAF)],
        fab.links + [Link("a", "px", "idle", "qx", 100.0, "layer_idle")],
    )
    paths = {0: [extra.links[0]], 1: [extra.links[1]]}
    out = per_layer_fim(paths, extra, only_used_leaves=True)
    # the idle layer disappears entirely; L0 keeps only links between used
    # devices (all three a->b links qualify: both endpoints carried flows)
    assert set(out) == {"L0"}
    assert out["L0"][1] == 3


class _CountingPaths(dict):
    """Mapping that counts .values() traversals — a structural regression
    guard for the used-devices hoist in per_layer_fim (pre-fix the set was
    rebuilt from every path once per layer: O(layers * paths))."""

    def __init__(self, *a):
        super().__init__(*a)
        self.values_calls = 0

    def values(self):
        self.values_calls += 1
        return super().values()


def test_per_layer_fim_scans_paths_once_regression():
    n_layers = 6
    fab = _multi_layer_fabric(n_layers, 2)
    paths = _CountingPaths(
        {fid: [fab.links[s * 2] for s in range(n_layers)] for fid in range(4)})
    out = per_layer_fim(paths, fab, only_used_leaves=True)
    assert len(out) == n_layers
    # one scan for link counts + one for the hoisted used-device set;
    # the pre-fix implementation scanned 2x per layer (13 for 6 layers).
    assert paths.values_calls <= 3, paths.values_calls


# ---------------------------------------------------------------------------
# max-min throughput model
# ---------------------------------------------------------------------------


@given(st.integers(1, 16))
@settings(max_examples=30, deadline=None)
def test_throughput_equal_share_single_link(n_flows):
    fab = _line_fabric(1)
    paths = {i: [fab.links[0]] for i in range(n_flows)}
    rates = max_min_throughput(paths)
    for r in rates.values():
        assert r == pytest.approx(100.0 / n_flows)


@given(st.lists(st.integers(1, 8), min_size=2, max_size=8))
@settings(max_examples=50, deadline=None)
def test_throughput_conservation(counts):
    """Sum of rates on each link never exceeds its capacity."""
    fab = _line_fabric(len(counts))
    paths = _paths_from_counts(fab, counts)
    rates = max_min_throughput(paths)
    per_link = {}
    for fid, p in paths.items():
        per_link.setdefault(p[0].name, 0.0)
        per_link[p[0].name] += rates[fid]
    for name, total in per_link.items():
        assert total <= 100.0 + 1e-6
        # max-min on a dedicated link also saturates it
        assert total == pytest.approx(100.0)


# ---------------------------------------------------------------------------
# layer_load_stats: single source for per_layer_fim + report.analyze_paths
# ---------------------------------------------------------------------------


def test_layer_load_stats_consistent_with_per_layer_fim():
    from repro.core.fim import layer_load_stats

    fab = _line_fabric(4)
    paths = _paths_from_counts(fab, [5, 1, 1, 1])
    stats = layer_load_stats(paths, fab)
    assert set(stats) == set(per_layer_fim(paths, fab))
    s = stats["layer"]
    assert s.total == 8
    assert s.n_links == 4
    assert s.ideal == pytest.approx(2.0)
    assert s.fim_pct == pytest.approx(per_layer_fim(paths, fab)["layer"][0])
    assert set(s.link_counts) == {l.name for l in fab.links}  # idle included
    assert sum(s.link_counts.values()) == s.total


def test_layer_load_stats_guards_empty_and_idle_layers():
    from repro.core.fim import layer_load_stats

    fab = _line_fabric(3)
    paths = _paths_from_counts(fab, [2, 1, 0])
    # unknown / linkless layer: skipped, not a ZeroDivisionError
    assert layer_load_stats(paths, fab, layers=["no-such-layer"]) == {}
    # zero-traffic layer: dropped like per_layer_fim drops it
    assert layer_load_stats({}, fab) == {}


def test_analyze_paths_single_sourced_from_layer_stats():
    from repro.core import analyze_paths
    from repro.core.fim import layer_load_stats

    fab = _line_fabric(4)
    paths = _paths_from_counts(fab, [6, 2, 0, 0])
    rep = analyze_paths(paths, fab)
    stats = layer_load_stats(paths, fab)
    assert rep.per_layer == {k: s.link_counts for k, s in stats.items()}
    assert rep.ideal_per_layer == {k: s.ideal for k, s in stats.items()}
    assert rep.per_layer_fim == {k: s.fim_pct for k, s in stats.items()}
    # collisions: exactly the links above the layer ideal, worst first
    assert rep.collisions == [("a:p0->b:q0", 6)]
    assert rep.aggregate_fim == pytest.approx(fim(paths, fab))
