"""flowcheck analyzer + runtime contract mode (src/repro/analysis).

Fixture-driven: each rule family must trip on a known-bad snippet and
stay silent on the repo's own known-good idioms (static-shape loops,
``static_argnames`` branches, ``is not None`` structure dispatch, the
per-call-site taint that keeps ``hash_backend`` comparisons clean).
The baseline must round-trip (write -> justify -> clean), reject TODO
justifications, and still fail on findings it has never seen.  And the
real repo must be clean against its committed baseline — the same
gate CI runs.
"""

import json
import textwrap

import numpy as np
import pytest

from repro.analysis.common import Context
from repro.analysis.flowcheck import collect_findings, main

# ---------------------------------------------------------------------------
# fixture repos
# ---------------------------------------------------------------------------


def make_repo(tmp_path, files):
    """A throwaway repo tree: {relative path: dedented source}."""
    (tmp_path / "src" / "repro").mkdir(parents=True, exist_ok=True)
    (tmp_path / "tests").mkdir(exist_ok=True)
    for rel, text in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(text))
    return tmp_path


def rules_of(root):
    return [f.rule for f in collect_findings(Context(root=root))]


# ---------------------------------------------------------------------------
# FT-JIT: retrace / host-sync hazards
# ---------------------------------------------------------------------------


def test_jit_family_trips_each_rule_once(tmp_path):
    root = make_repo(tmp_path, {"src/repro/core/jax_engine.py": """\
        import functools
        import jax
        import numpy as np


        @functools.partial(jax.jit, static_argnames=("mode",))
        def bad(x, y, mode):
            if x > 0:                    # FT-JIT-BRANCH
                y = y + 1
            for v in x:                  # FT-JIT-LOOP
                y = y + v
            z = float(x[0])              # FT-JIT-HOSTSYNC
            w = np.sum(x)                # FT-JIT-NUMPY
            return y + z + w
        """})
    rules = rules_of(root)
    for rule in ("FT-JIT-BRANCH", "FT-JIT-LOOP", "FT-JIT-HOSTSYNC",
                 "FT-JIT-NUMPY"):
        assert rules.count(rule) == 1, (rule, rules)


def test_jit_known_good_idioms_stay_clean(tmp_path):
    # the repo's own jit vocabulary: static_argnames branches,
    # static-shape loops, None structure dispatch, and a helper whose
    # *string* argument is compared while its array argument is traced
    root = make_repo(tmp_path, {"src/repro/core/jax_engine.py": """\
        import functools
        import jax
        import jax.numpy as jnp

        EXACT = "exact"


        def _hash_grid(fields, dev_seed, backend):
            if backend == EXACT:         # static at every call site
                return fields + dev_seed
            return fields * dev_seed


        @functools.partial(jax.jit, static_argnames=("cool", "near"))
        def walk(fields, dev_seed, cell_salt, cool, near):
            acc = jnp.zeros(fields.shape[0], dtype=jnp.float64)
            if cool and near:            # static_argnames
                acc = acc + 1
            if cell_salt is not None:    # structure dispatch
                acc = acc + cell_salt
            for f in range(fields.shape[1]):   # static shape
                acc = acc + _hash_grid(fields[:, f], dev_seed, EXACT)
            n = len(fields)              # static: len of traced array
            return acc / n
        """})
    assert [r for r in rules_of(root) if r.startswith("FT-JIT")] == []


def test_jit_taint_reaches_same_module_helpers(tmp_path):
    root = make_repo(tmp_path, {"src/repro/core/strategies.py": """\
        import jax


        def _helper(a):
            if a.sum() > 0:              # traced via the call below
                return a * 2
            return a


        @jax.jit
        def entry(arr):
            return _helper(arr)
        """})
    assert rules_of(root).count("FT-JIT-BRANCH") == 1


# ---------------------------------------------------------------------------
# FT-DT: dtype drift
# ---------------------------------------------------------------------------


def test_dtype_family_trips_each_rule_once(tmp_path):
    root = make_repo(tmp_path, {"src/repro/core/jax_engine.py": """\
        import jax.numpy as jnp
        import numpy as np


        def build(n):
            a = np.arange(n)             # FT-DT-ARANGE
            b = np.array([1, 2, 3])      # FT-DT-LITERAL
            c = jnp.zeros(n)             # FT-DT-JNP
            return a, b, c
        """})
    rules = rules_of(root)
    for rule in ("FT-DT-ARANGE", "FT-DT-LITERAL", "FT-DT-JNP"):
        assert rules.count(rule) == 1, (rule, rules)


def test_dtype_pinned_calls_stay_clean(tmp_path):
    root = make_repo(tmp_path, {"src/repro/core/vector_sim.py": """\
        import numpy as np


        def build(n, loads, seg):
            a = np.arange(n, dtype=np.int64)
            b = np.array([1, 2, 3], dtype=np.uint64)
            c = np.zeros(n, bool)            # positional dtype
            d = np.asarray(loads)            # array passthrough: no flag
            e = np.add.reduceat(loads, seg)  # fast path untouched
            return a, b, c, d, e
        """})
    assert [r for r in rules_of(root) if r.startswith("FT-DT")] == []


# ---------------------------------------------------------------------------
# FT-REG: registry hygiene
# ---------------------------------------------------------------------------


def test_registry_family_trips_each_rule_once(tmp_path):
    root = make_repo(tmp_path, {
        "src/repro/core/strategies.py": """\
            def register_strategy(name, cls=None):
                pass


            def _lazy():
                register_strategy("inside")       # FT-REG-TOPLEVEL


            register_strategy("ecmp")
            register_strategy("ecmp")             # FT-REG-DUP
            register_strategy("orphan")           # FT-REG-UNTESTED

            import os
            register_strategy(os.environ["X"])    # FT-REG-OPAQUE
            """,
        "tests/test_strategies.py": """\
            def test_names():
                assert "ecmp" and "inside"
            """,
    })
    rules = rules_of(root)
    for rule in ("FT-REG-TOPLEVEL", "FT-REG-DUP", "FT-REG-UNTESTED",
                 "FT-REG-OPAQUE"):
        assert rules.count(rule) == 1, (rule, rules)


def test_registry_loop_and_ctor_names_resolve(tmp_path):
    # the reordering.py idiom: profiles registered from a module-level
    # for-loop over constructor-built constants
    root = make_repo(tmp_path, {
        "src/repro/core/reordering.py": """\
            class TransportProfile:
                def __init__(self, name, alpha=0.0):
                    self.name = name


            def register_transport(profile):
                pass


            IDEAL = TransportProfile(name="ideal")
            ROCE = TransportProfile("roce-nack", alpha=2.0)
            for _p in (IDEAL, ROCE):
                register_transport(_p)
            """,
        "tests/test_reordering.py": """\
            def test_profiles():
                assert "ideal" and "roce-nack"
            """,
    })
    assert [r for r in rules_of(root) if r.startswith("FT-REG")] == []


# ---------------------------------------------------------------------------
# FT-API: SimSpec surface consistency
# ---------------------------------------------------------------------------

_SPEC_PRELUDE = """\
    _UNSET = object()


    class SimSpec:
        strategy: object = None
        demand_mode: str = "uniform"
        engine: str = "numpy"
        hash_backend: object = None
        transport: object = None
        fields: str = "5tuple"
        max_hops: int = 16
        timing: str = "static"


    def resolve_spec(spec, kwargs):
        return spec
    """


def test_api_family_trips_each_rule_once(tmp_path):
    root = make_repo(tmp_path, {
        "src/repro/core/jax_engine.py": """\
            def fused_monte_carlo_fim(comp, workload, seeds, *, fields=None,
                                      hash_backend=None, demand_mode=None,
                                      max_hops=16):
                pass
            """,
        "src/repro/core/vector_sim.py": _SPEC_PRELUDE + """\


        def simulate_paths(fabric, flows, seeds, *, spec=None,
                           fields=_UNSET, hash_backend=_UNSET,
                           strategy=_UNSET, demand_mode=_UNSET,
                           engine=_UNSET, max_hops=_UNSET,
                           bogus=_UNSET):
            # bogus: FT-API-KWARGS (not a SimSpec field)
            # max_hops: FT-API-KWARGS (never forwarded to resolve_spec)
            s = resolve_spec(spec, dict(
                fields=fields, hash_backend=hash_backend,
                strategy=strategy, demand_mode=demand_mode,
                engine=engine, bogus=bogus))
            return s


        def monte_carlo_fim(fabric, workload, seeds, *, spec=None,
                            fields=_UNSET, hash_backend=_UNSET,
                            strategy=_UNSET, demand_mode=_UNSET,
                            engine=_UNSET):
            # max_hops: FT-API-MISSING (neither kwarg nor excluded)
            s = resolve_spec(spec, dict(
                fields=fields, hash_backend=hash_backend,
                strategy=strategy, demand_mode=demand_mode,
                engine=engine))
            from .jax_engine import fused_monte_carlo_fim
            # FT-API-FUSED: max_hops not forwarded
            return fused_monte_carlo_fim(
                fabric, workload, seeds, fields=s.fields,
                hash_backend=s.hash_backend, demand_mode=s.demand_mode)
        """,
    })
    rules = rules_of(root)
    assert rules.count("FT-API-KWARGS") == 2, rules
    assert rules.count("FT-API-MISSING") == 1, rules
    assert rules.count("FT-API-FUSED") == 1, rules


def test_api_consistent_surface_stays_clean(tmp_path):
    root = make_repo(tmp_path, {
        "src/repro/core/vector_sim.py": _SPEC_PRELUDE + """\


        def simulate_paths(fabric, flows, seeds, *, spec=None,
                           fields=_UNSET, hash_backend=_UNSET,
                           strategy=_UNSET, demand_mode=_UNSET,
                           engine=_UNSET, max_hops=_UNSET):
            return resolve_spec(spec, dict(
                fields=fields, hash_backend=hash_backend,
                strategy=strategy, demand_mode=demand_mode,
                engine=engine, max_hops=max_hops))
        """,
    })
    assert [r for r in rules_of(root) if r.startswith("FT-API")] == []


# ---------------------------------------------------------------------------
# FT-BENCH: bench rows vs the smoke baseline
# ---------------------------------------------------------------------------

_BENCH_BASELINE = json.dumps({"rows": [
    {"name": "walk_ecmp_64f", "us_per_call": 10.0},
    {"name": "hetero_tail_fim_pct", "us_per_call": 1.0},
]})


def test_bench_family_flags_uncovered_row(tmp_path):
    root = make_repo(tmp_path, {
        "benchmarks/BENCH_baseline_smoke.json": _BENCH_BASELINE,
        "benchmarks/walkbench.py": """\
            from common import emit


            def main():
                emit("walk_ecmp_64f", 1.0, {})
                emit("walk_new_row", 1.0, {})
            """,
    })
    assert rules_of(root).count("FT-BENCH-ROW") == 1


def test_bench_fstring_rows_and_pragma(tmp_path):
    root = make_repo(tmp_path, {
        "benchmarks/BENCH_baseline_smoke.json": _BENCH_BASELINE,
        "benchmarks/heterobench.py": """\
            from common import emit


            def main(scen):
                emit(f"hetero_{scen}_fim_pct", 1.0, {})
                emit("hetero_fresh", 1.0, {})  # flowcheck: new-bench-row
            """,
    })
    assert [r for r in rules_of(root) if r.startswith("FT-BENCH")] == []


def test_bench_uncovered_module_skipped(tmp_path):
    # a module with zero baseline presence is outside the smoke set
    root = make_repo(tmp_path, {
        "benchmarks/BENCH_baseline_smoke.json": _BENCH_BASELINE,
        "benchmarks/fig4.py": """\
            from common import emit


            def main():
                emit("fig4_everything", 1.0, {})
            """,
    })
    assert [r for r in rules_of(root) if r.startswith("FT-BENCH")] == []


# ---------------------------------------------------------------------------
# pragmas, baseline round-trip, CLI exit codes
# ---------------------------------------------------------------------------

_ONE_FINDING = {"src/repro/core/strategies.py": """\
    import numpy as np


    def build(n):
        return np.arange(n)
    """}


def test_line_pragma_suppresses(tmp_path):
    root = make_repo(tmp_path, {"src/repro/core/strategies.py": """\
        import numpy as np


        def build(n):
            return np.arange(n)  # flowcheck: disable=FT-DT-ARANGE
        """})
    assert rules_of(root) == []


def test_cli_baseline_round_trip(tmp_path, capsys):
    root = make_repo(tmp_path, _ONE_FINDING)
    base = root / "flowcheck_baseline.json"

    # no baseline: the finding is new -> exit 1
    assert main(["--root", str(root)]) == 1
    assert "FT-DT-ARANGE" in capsys.readouterr().out

    # write-baseline seeds TODO justifications -> check refuses (exit 2)
    assert main(["--root", str(root), "--write-baseline"]) == 0
    assert main(["--root", str(root)]) == 2
    assert "BROKEN BASELINE" in capsys.readouterr().out

    # justify -> clean (exit 0)
    payload = json.loads(base.read_text())
    for e in payload["entries"]:
        e["justification"] = "pre-existing; tracked in ISSUE backlog"
    base.write_text(json.dumps(payload))
    assert main(["--root", str(root)]) == 0

    # a NEW finding still fails against the old baseline
    (root / "src/repro/core/vector_sim.py").write_text(
        "import numpy as np\n\n\ndef f(n):\n    return np.arange(n)\n")
    assert main(["--root", str(root)]) == 1
    out = capsys.readouterr().out
    assert "vector_sim.py" in out and "1 new finding" in out


def test_cli_stale_baseline_is_advisory(tmp_path, capsys):
    root = make_repo(tmp_path, {"src/repro/core/empty.py": "X = 1\n"})
    (root / "flowcheck_baseline.json").write_text(json.dumps({
        "entries": [{"fingerprint": "FT-DT-ARANGE::gone.py::gone",
                     "justification": "was fixed"}]}))
    assert main(["--root", str(root)]) == 0
    assert "STALE" in capsys.readouterr().out


def test_cli_json_artifact(tmp_path):
    root = make_repo(tmp_path, _ONE_FINDING)
    out = tmp_path / "findings.json"
    assert main(["--root", str(root), "--json", str(out)]) == 1
    payload = json.loads(out.read_text())
    assert payload["new"] and payload["new"][0]["rule"] == "FT-DT-ARANGE"
    assert "FT-JIT-BRANCH" in payload["rules"]


def test_cli_rejects_non_repo_root(tmp_path):
    assert main(["--root", str(tmp_path / "nowhere")]) == 2


def test_real_repo_clean_against_committed_baseline():
    # the gate CI runs: the live tree must carry zero new findings
    assert main([]) == 0


# ---------------------------------------------------------------------------
# runtime contract mode (FLOWTRACER_CONTRACTS=1)
# ---------------------------------------------------------------------------


@pytest.fixture
def contracts_on(monkeypatch):
    monkeypatch.setenv("FLOWTRACER_CONTRACTS", "1")


def _routed(strategy=None, **kw):
    from repro.core import (
        bipartite_pairs, build_paper_testbed, compile_fabric, nic_ip,
        server_name, simulate_paths, synthesize_flows,
    )
    comp = compile_fabric(build_paper_testbed())
    wl = bipartite_pairs([server_name(i) for i in range(4)],
                         [server_name(8 + i) for i in range(4)],
                         flows_per_pair=2)
    flows = synthesize_flows(wl, nic_ip=nic_ip, nics_per_server=2)
    return simulate_paths(comp, flows, [0, 1], strategy=strategy, **kw)


def test_contracts_off_by_default(monkeypatch):
    from repro.core import contracts_enabled
    monkeypatch.delenv("FLOWTRACER_CONTRACTS", raising=False)
    assert not contracts_enabled()
    for off in ("0", "false", "off", ""):
        monkeypatch.setenv("FLOWTRACER_CONTRACTS", off)
        assert not contracts_enabled()


def test_contracts_pass_on_healthy_pipeline(contracts_on):
    from repro.core import contracts_enabled, throughput_from_result
    assert contracts_enabled()
    res = _routed(strategy="prime-spray")
    tp = throughput_from_result(res, transport="roce-nack")
    assert np.isfinite(tp.goodput).all()


def test_contract_catches_bad_trace_result(contracts_on):
    from repro.core import ContractViolation
    from repro.core.contracts import check_trace_result
    res = _routed()
    res.demand = res.demand * 2.0          # flowlet fractions must sum to 1
    with pytest.raises(ContractViolation, match="sum to 1"):
        check_trace_result(res)
    res = _routed()
    res.link_ids = res.link_ids + res.compiled.num_links   # out of range
    with pytest.raises(ContractViolation, match="link ids"):
        check_trace_result(res)


def test_contract_catches_bad_throughput(contracts_on):
    from repro.core import ContractViolation, throughput_from_result
    from repro.core.contracts import check_throughput
    tp = throughput_from_result(_routed(strategy="prime-spray"),
                                transport="roce-nack")
    tp.goodput = tp.goodput * 2.0          # goodput must be rates x eff
    with pytest.raises(ContractViolation, match="goodput"):
        check_throughput(tp)


def test_contract_checks_resolved_spec(contracts_on):
    from repro.core import ContractViolation, SimSpec
    from repro.core.contracts import check_spec
    import dataclasses
    s = SimSpec(strategy="prime-spray").resolve()
    check_spec(s)                          # healthy resolve passes
    broken = dataclasses.replace(s, strategy="prime-spray")
    with pytest.raises(ContractViolation, match="name string"):
        check_spec(broken)
