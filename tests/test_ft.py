"""Fault tolerance: elastic re-mesh planning, straggler detection,
checkpoint/restart with injected failures."""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.checkpoint import restore, save, latest_step
from repro.configs import ARCHS
from repro.data import SyntheticDataset
from repro.ft import (
    HostFailure, StragglerDetector, plan_elastic_mesh,
    run_with_restarts,
)
from repro.models import Model
from repro.train import AdamWConfig, TrainConfig, init_train_state, make_train_step


@given(st.integers(16, 4096), st.sampled_from([4, 8, 16]))
@settings(max_examples=100, deadline=None)
def test_elastic_plan_properties(devices, tp):
    if devices < tp:
        return
    plan = plan_elastic_mesh(devices, model_parallel=tp)
    used = plan.mesh_shape[0] * plan.mesh_shape[1]
    assert plan.mesh_shape[1] == tp          # TP degree preserved
    assert used + plan.dropped_devices == devices
    assert plan.dropped_devices < tp         # drop less than one TP group


def test_elastic_plan_preserves_global_batch():
    plan = plan_elastic_mesh(12 * 16, model_parallel=16, prefer_data=16)
    assert plan.mesh_shape == (12, 16)
    assert plan.grad_accum_multiplier == 2   # 16/12 -> ceil = 2


def test_elastic_rejects_undersized():
    with pytest.raises(ValueError):
        plan_elastic_mesh(8, model_parallel=16)


def test_straggler_detector():
    det = StragglerDetector(threshold=1.5, min_samples=3)
    for step in range(6):
        for h in range(8):
            t = 1.0 if h != 3 else 2.5       # host 3 is slow
            det.record(f"host-{h}", t + 0.01 * step)
    reports = det.check()
    assert len(reports) == 1
    assert reports[0].host == "host-3"
    assert reports[0].advice in ("trace-paths", "rebalance", "evict")


def test_straggler_needs_samples():
    det = StragglerDetector(min_samples=3)
    det.record("a", 1.0)
    det.record("b", 9.0)
    assert det.check() == []


@pytest.mark.slow
def test_run_with_restarts_resumes_from_checkpoint():
    """Simulated host failure mid-training: the loop restores the latest
    checkpoint and completes with the exact same final state as an
    uninterrupted run (step-indexed data pipeline)."""
    cfg = ARCHS["granite-3-2b"].reduced()
    model = Model(cfg)
    tc = TrainConfig(optimizer=AdamWConfig(lr=1e-3))
    ds = SyntheticDataset(vocab=cfg.vocab, seq_len=32, global_batch=4, seed=3)
    step = jax.jit(make_train_step(model, tc))
    total = 6

    def reference():
        params, opt = init_train_state(model, tc, KEY := jax.random.PRNGKey(0))
        for i in range(total):
            batch = {k: jnp.asarray(v) for k, v in ds.batch(i).items()}
            params, opt, _ = step(params, opt, batch)
        return params

    with tempfile.TemporaryDirectory() as d:
        state = {}

        def train_loop(start_step: int) -> int:
            if latest_step(d) is not None:
                restored, s0 = restore(d, {"params": state["params"],
                                           "opt": state["opt"]})
                params, opt = restored["params"], restored["opt"]
                start = s0
            else:
                params, opt = init_train_state(model, tc, jax.random.PRNGKey(0))
                start = 0
            for i in range(start, total):
                batch = {k: jnp.asarray(v) for k, v in ds.batch(i).items()}
                params, opt, _ = step(params, opt, batch)
                state["params"], state["opt"] = params, opt
                save(d, i + 1, {"params": params, "opt": opt})
                if i == 2 and not state.get("failed"):
                    state["failed"] = True
                    raise HostFailure("injected ICI timeout on host-7")
            state["final"] = params
            return total

        run_with_restarts(train_loop, max_restarts=2)

    ref = reference()
    for a, b in zip(jax.tree.leaves(state["final"]), jax.tree.leaves(ref)):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_restart_limit():
    calls = {"n": 0}

    def always_fails(start):
        calls["n"] += 1
        raise HostFailure("boom")

    with pytest.raises(HostFailure):
        run_with_restarts(always_fails, max_restarts=2)
    assert calls["n"] == 3
