"""Compiled-HLO collective extraction: parsing, trip counts, flow
decomposition conservation."""

from _propcheck import given, settings, strategies as st

from repro.core.hlo_flows import (
    collectives_to_flows, computation_multipliers, extract_collectives,
    shape_bytes, summarize,
)

HLO = """
HloModule jit_step

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%body (p: (s32[], bf16[128,256])) -> (s32[], bf16[128,256]) {
  %p = (s32[], bf16[128,256]) parameter(0)
  %x = bf16[128,256] get-tuple-element(%p), index=1
  %ar = bf16[128,256] all-reduce(%x), channel_id=1, replica_groups={{0,1,2,3},{4,5,6,7}}, use_global_device_ids=true, to_apply=%add
  %i = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], bf16[128,256]) tuple(%i, %ar)
}

%cond (p: (s32[], bf16[128,256])) -> pred[] {
  %p = (s32[], bf16[128,256]) parameter(0)
  ROOT %c = pred[] constant(true)
}

ENTRY %main (a: bf16[128,256]) -> bf16[128,256] {
  %a = bf16[128,256] parameter(0)
  %ag = bf16[512,256] all-gather(%a), channel_id=2, replica_groups=[2,4]<=[8], dimensions={0}
  %a2a = bf16[128,256] all-to-all(%a), channel_id=3, replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
  %cp = bf16[128,256] collective-permute(%a), channel_id=4, source_target_pairs={{0,1},{1,2},{2,3},{3,0}}
  %w = (s32[], bf16[128,256]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"12"}}
  ROOT %out = bf16[128,256] get-tuple-element(%w), index=1
}
"""

AR_BYTES = 128 * 256 * 2       # bf16[128,256]
AG_OUT = 512 * 256 * 2


def test_shape_bytes():
    assert shape_bytes("bf16[128,256]{1,0}") == AR_BYTES
    assert shape_bytes("f32[10]") == 40
    assert shape_bytes("(f32[2,2], bf16[4])") == 16 + 8
    assert shape_bytes("pred[]") == 1


def test_extract_and_trip_counts():
    ops = extract_collectives(HLO)
    kinds = sorted(o.kind for o in ops)
    assert kinds == ["all-gather", "all-reduce", "all-to-all",
                     "collective-permute"]
    by_kind = {o.kind: o for o in ops}
    ar = by_kind["all-reduce"]
    assert ar.multiplier == 12, "while body trip count must be applied"
    assert ar.groups == ((0, 1, 2, 3), (4, 5, 6, 7))
    assert ar.wire_bytes == int(2 * 3 / 4 * AR_BYTES)
    ag = by_kind["all-gather"]
    assert ag.multiplier == 1
    # iota [2,4]<=[8] -> {0..3},{4..7}
    assert ag.groups == ((0, 1, 2, 3), (4, 5, 6, 7))
    assert ag.wire_bytes == int(3 / 4 * AG_OUT)
    cp = by_kind["collective-permute"]
    assert cp.pairs == ((0, 1), (1, 2), (2, 3), (3, 0))
    assert cp.wire_bytes == AR_BYTES


def test_multipliers_fixed_point():
    mult = computation_multipliers(HLO)
    assert mult["main"] == 1
    assert mult["body"] == 12


def test_summary_scales_by_multiplier():
    ops = extract_collectives(HLO)
    s = summarize(ops)
    ar = next(o for o in ops if o.kind == "all-reduce")
    assert s.per_kind_wire["all-reduce"] == ar.wire_bytes * 12
    assert s.per_kind_count["all-reduce"] == 12


def test_iota_transpose_groups():
    txt = ("ENTRY %m (a: f32[8]) -> f32[8] {\n"
           "  ROOT %ar = f32[8] all-reduce(%a), channel_id=1, "
           "replica_groups=[2,2]<=[2,2]T(1,0), to_apply=%add\n}")
    ops = extract_collectives(txt)
    # iota(4).reshape(2,2).T.reshape(2,2) -> groups {0,2},{1,3}
    assert ops[0].groups == ((0, 2), (1, 3))


def test_flow_decomposition_classes():
    ops = extract_collectives(HLO)
    # 8 devices = 2 hosts x 4 chips, single pod -> zero DCN flows
    coords1 = {d: (0, d // 4, d % 4) for d in range(8)}
    flows, stats = collectives_to_flows(ops, coords1)
    assert len(flows) == 0 and stats.inter_pod_dcn == 0
    # 8 devices = 2 pods x 1 host x 4 chips -> pod-crossing edges become flows
    coords2 = {d: (d // 4, d // 4, d % 4) for d in range(8)}
    flows2, stats2 = collectives_to_flows(ops, coords2)
    assert stats2.inter_pod_dcn > 0
    assert len(flows2) == stats2.inter_pod_dcn
    assert all(f.bytes > 0 for f in flows2)
    assert all(f.tuple5.dst_port == 4791 for f in flows2)  # RoCEv2


@given(st.integers(2, 16), st.integers(1, 64))
@settings(max_examples=50, deadline=None)
def test_ring_conservation(n, kb):
    """Ring all-reduce: n edges x 2(n-1)/n*B bytes each; total wire over
    the group = 2(n-1)B — the textbook ring bound."""
    bytes_ = kb * 1024
    group = ",".join(str(i) for i in range(n))
    txt = (f"ENTRY %m (a: u8[{bytes_}]) -> u8[{bytes_}] {{\n"
           f"  ROOT %ar = u8[{bytes_}] all-reduce(%a), channel_id=1, "
           f"replica_groups={{{{{group}}}}}, to_apply=%add\n}}")
    ops = extract_collectives(txt)
    assert len(ops) == 1
    op = ops[0]
    coords = {i: (i, i, 0) for i in range(n)}  # every device its own pod
    flows, stats = collectives_to_flows(ops, coords)
    assert len(flows) == n                      # ring edges
    per_edge = int(2 * (n - 1) / n * bytes_)
    assert all(f.bytes == per_edge for f in flows)
    assert op.wire_bytes == per_edge
