"""Differential tests for the device-resident jax engine.

The numpy engine is the reference: under the exact splitmix64 backend
the jax walk must be **bit-identical** (same uint64 arithmetic, just
jitted), and every downstream stage — max-min fill, flowlet exposure,
transport goodput, FIM — must agree within 1e-6 (the fill's cumsum-
based segment sums round differently than numpy's bincount, nothing
more).  The sweep crosses randomized fabric shapes, all three routing
strategies, both demand modes, and the fused front-end fast paths; the
large-scale sweep rides behind the ``slow`` marker and scales via
``FLOWTRACER_SWEEP_FLOWS`` / ``FLOWTRACER_SWEEP_SEEDS`` toward the
100k-flow x 10k-seed acceptance shape on device hosts."""

import dataclasses
import os

import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core import (
    AdaptiveSpraying, PrimeSpraying, RoutingStrategy,
    TimelineStep, batched_max_min, bipartite_pairs, build_paper_testbed,
    compile_fabric, flowlet_exposure, max_min_rates, monte_carlo_fim,
    monte_carlo_throughput, nic_ip, server_name, simulate_paths,
    simulate_timeline, synthesize_flows, throughput_from_result,
)
from repro.core.jax_engine import default_hash_backend, resolve_engine
from repro.core.vector_sim import (
    ENGINE_JAX, ENGINE_NUMPY, EXACT, MURMUR, resolve_hash_backend,
)

STRATEGIES = {
    "ecmp": None,
    "prime-spray": PrimeSpraying(flowlets=4),
    "adaptive-spray": AdaptiveSpraying(flowlets=4, rounds=2),
    "congestion-aware": "congestion-aware",
}


def _workload(fab, flows_per_pair=4, servers=8, hetero=True):
    half = servers // 2
    rack0 = [server_name(i) for i in range(half)]
    rack1 = [server_name(half + i) for i in range(half)]
    wl = bipartite_pairs(rack0, rack1, flows_per_pair=flows_per_pair)
    flows = synthesize_flows(wl, nic_ip=nic_ip, nics_per_server=2)
    if hetero:
        flows = [dataclasses.replace(
            f, bytes=(256 * 1024 * 1024 if i % 3 == 0 else 1024 * 1024))
            for i, f in enumerate(flows)]
    return flows


@pytest.fixture(scope="module")
def paper8():
    fab = build_paper_testbed()
    return compile_fabric(fab), _workload(fab)


# ---------------------------------------------------------------------------
# engine selection plumbing
# ---------------------------------------------------------------------------


def test_resolve_engine_rejects_unknown():
    assert resolve_engine("jax") == "jax"
    with pytest.raises(ValueError, match="engine"):
        resolve_engine("cuda")


def test_resolve_hash_backend_defaults():
    # numpy always defaults to the tracer-identical exact hash; jax
    # defaults to the engine's natural backend (exact on CPU, where the
    # differential CI runs); an explicit choice always wins
    assert resolve_hash_backend(None, ENGINE_NUMPY) == EXACT
    assert resolve_hash_backend(None, ENGINE_JAX) == default_hash_backend()
    assert resolve_hash_backend(MURMUR, ENGINE_NUMPY) == MURMUR
    assert resolve_hash_backend(EXACT, ENGINE_JAX) == EXACT
    with pytest.raises(ValueError):
        resolve_hash_backend("sha1", ENGINE_NUMPY)


def test_legacy_strategy_rejects_engine_loudly(paper8):
    """A pre-engine custom strategy keeps working under the defaults but
    a non-default engine request against it must fail, not silently run
    on numpy."""

    class Legacy(RoutingStrategy):
        name = "legacy"

        def route(self, comp, flows, seeds, *, fields, hash_backend,
                  max_hops, field_matrix):
            return simulate_paths(comp, flows, seeds, fields=fields,
                                  hash_backend=hash_backend,
                                  max_hops=max_hops,
                                  field_matrix=field_matrix)

    comp, flows = paper8
    res = simulate_paths(comp, flows, [0, 1], strategy=Legacy())
    assert res.num_seeds == 2
    with pytest.raises(TypeError):
        simulate_paths(comp, flows, [0, 1], strategy=Legacy(),
                       engine=ENGINE_JAX)


# ---------------------------------------------------------------------------
# walk + downstream parity across strategies and demand modes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", sorted(STRATEGIES))
def test_engine_parity_per_strategy(paper8, strategy):
    comp, flows = paper8
    seeds = [0, 7, 1234567, 2**40 + 17]
    for demand_mode in ("uniform", "bytes"):
        r_np = simulate_paths(comp, flows, seeds,
                              strategy=STRATEGIES[strategy],
                              demand_mode=demand_mode)
        r_jx = simulate_paths(comp, flows, seeds,
                              strategy=STRATEGIES[strategy],
                              demand_mode=demand_mode, engine=ENGINE_JAX)
        # exact backend on both engines: the walk is bit-identical
        assert np.array_equal(r_np.link_ids, r_jx.link_ids)
        assert np.array_equal(r_np.flow_demand, r_jx.flow_demand)
        tp_np = throughput_from_result(r_np, transport="roce-nack")
        tp_jx = throughput_from_result(r_jx, transport="roce-nack",
                                       engine=ENGINE_JAX)
        for attr in ("rates", "exposure", "goodput", "per_pair"):
            a, b = getattr(tp_np, attr), getattr(tp_jx, attr)
            assert np.abs(a - b).max() < 1e-6, (strategy, demand_mode, attr)


def test_murmur_walk_bit_identical(paper8):
    """Both engines evaluate the ONE murmur definition (seed-as-init,
    fold, fmix) — same uint32 formulas, so bit-identical too."""
    comp, flows = paper8
    r_np = simulate_paths(comp, flows, [0, 3, 99], hash_backend=MURMUR)
    r_jx = simulate_paths(comp, flows, [0, 3, 99], hash_backend=MURMUR,
                          engine=ENGINE_JAX)
    assert np.array_equal(r_np.link_ids, r_jx.link_ids)
    # and murmur actually routes differently than exact (distinct hash)
    r_ex = simulate_paths(comp, flows, [0, 3, 99])
    assert not np.array_equal(r_np.link_ids, r_ex.link_ids)


@given(st.integers(1, 3), st.integers(2, 4), st.integers(0, 2**31))
@settings(max_examples=3, deadline=None)
def test_randomized_fabric_walk_parity(spines, links_per, seed):
    fab = build_paper_testbed(num_spines=spines,
                              links_per_leaf_spine=links_per,
                              servers_per_rack=4)
    comp = compile_fabric(fab)
    flows = _workload(fab, flows_per_pair=2, servers=8, hetero=False)
    seeds = [seed, seed + 1]
    r_np = simulate_paths(comp, flows, seeds)
    r_jx = simulate_paths(comp, flows, seeds, engine=ENGINE_JAX)
    assert np.array_equal(r_np.link_ids, r_jx.link_ids)
    a = max_min_rates(r_np)
    b = max_min_rates(r_jx, engine=ENGINE_JAX)
    assert np.abs(a - b).max() < 1e-6


# ---------------------------------------------------------------------------
# fill + exposure stage twins
# ---------------------------------------------------------------------------


@given(st.integers(1, 4), st.integers(1, 40), st.integers(1, 8),
       st.integers(2, 12), st.integers(0, 2**31))
@settings(max_examples=8, deadline=None)
def test_weighted_fill_parity_random(H, N, S, L, seed):
    rng = np.random.default_rng(seed)
    ids = rng.integers(-1, L, (H, N, S)).astype(np.int32)
    gbps = rng.uniform(1.0, 400.0, L)
    w = rng.uniform(0.05, 8.0, N)
    a = batched_max_min(ids, gbps, weights=w)
    b = batched_max_min(ids, gbps, weights=w, engine=ENGINE_JAX)
    assert np.allclose(a, b, rtol=1e-9, atol=1e-9)


def test_fill_edge_cases_match_numpy():
    gbps = np.array([100.0, 40.0])
    # H == 0: no hops at all -> unconstrained
    a = batched_max_min(np.empty((0, 3, 2), np.int32), gbps)
    b = batched_max_min(np.empty((0, 3, 2), np.int32), gbps,
                        engine=ENGINE_JAX)
    assert np.isinf(a).all() and np.isinf(b).all()
    # all-sentinel column (flow crossing no link) -> inf, others finite
    ids = np.array([[[0], [-1]]], np.int32)          # (1, 2, 1)
    a = batched_max_min(ids, gbps)
    b = batched_max_min(ids, gbps, engine=ENGINE_JAX)
    assert np.array_equal(np.isinf(a), np.isinf(b))
    assert np.allclose(a[np.isfinite(a)], b[np.isfinite(b)])


def test_exposure_parity_under_spray(paper8):
    comp, flows = paper8
    res = simulate_paths(comp, flows, [0, 5],
                         strategy=PrimeSpraying(flowlets=4))
    rates = max_min_rates(res)
    a = flowlet_exposure(res, rates)
    b = flowlet_exposure(res, rates, engine=ENGINE_JAX)
    assert np.abs(a - b).max() < 1e-6
    # single-path result: exposure is identically zero on both engines
    res1 = simulate_paths(comp, flows, [0, 5])
    assert (flowlet_exposure(res1, engine=ENGINE_JAX) == 0).all()


# ---------------------------------------------------------------------------
# fused front ends + timeline
# ---------------------------------------------------------------------------


def test_fused_fim_parity(paper8):
    comp, flows = paper8
    seeds = np.arange(16)
    for kw in ({}, {"demand_mode": "bytes", "only_used_leaves": True}):
        a = monte_carlo_fim(comp, flows, seeds, **kw)
        b = monte_carlo_fim(comp, flows, seeds, engine=ENGINE_JAX, **kw)
        assert np.abs(a.aggregate - b.aggregate).max() < 1e-6
        assert sorted(a.per_layer) == sorted(b.per_layer)
        for layer in a.per_layer:
            assert np.abs(a.per_layer[layer]
                          - b.per_layer[layer]).max() < 1e-6


def test_fused_throughput_parity(paper8):
    comp, flows = paper8
    seeds = np.arange(16)
    a = monte_carlo_throughput(comp, flows, seeds, demand_mode="bytes",
                               transport="strack")
    b = monte_carlo_throughput(comp, flows, seeds, demand_mode="bytes",
                               transport="strack", engine=ENGINE_JAX)
    assert np.abs(a.rates - b.rates).max() < 1e-6
    assert np.abs(a.goodput - b.goodput).max() < 1e-6
    assert np.abs(a.per_pair - b.per_pair).max() < 1e-6


def test_fused_path_only_for_plain_ecmp(paper8):
    """A *configured* EcmpStrategy subclass must not be silently routed
    through the fused plain-ECMP fast path."""
    comp, flows = paper8
    seeds = np.arange(4)
    spray = PrimeSpraying(flowlets=4)
    a = monte_carlo_throughput(comp, flows, seeds, strategy=spray,
                               transport="roce-nack")
    b = monte_carlo_throughput(comp, flows, seeds, strategy=spray,
                               transport="roce-nack", engine=ENGINE_JAX)
    assert np.abs(a.goodput - b.goodput).max() < 1e-6


def test_timeline_engine_parity(paper8):
    comp, flows = paper8
    labeled = [dataclasses.replace(f, label=f"x#ch{i % 2}")
               for i, f in enumerate(flows)]
    sched = [TimelineStep("a", (0,)), TimelineStep("b", (1,), duration=2.0)]
    a = simulate_timeline(comp, labeled, sched, [0, 1, 2],
                          demand_mode="bytes", transport="roce-nack")
    b = simulate_timeline(comp, labeled, sched, [0, 1, 2],
                          demand_mode="bytes", transport="roce-nack",
                          engine=ENGINE_JAX)
    assert np.abs(a.fim - b.fim).max() < 1e-6
    assert np.abs(a.goodput - b.goodput).max() < 1e-6
    for sa, sb in zip(a.steps, b.steps):
        assert np.abs(sa.throughput.rates - sb.throughput.rates).max() < 1e-6


# ---------------------------------------------------------------------------
# large-scale acceptance sweep (slow; env-scalable toward 100k x 10k)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_large_scale_sweep_parity():
    n_flows = int(os.environ.get("FLOWTRACER_SWEEP_FLOWS", 16384))
    n_seeds = int(os.environ.get("FLOWTRACER_SWEEP_SEEDS", 1024))
    fab = build_paper_testbed()
    rack0 = [server_name(i) for i in range(8)]
    rack1 = [server_name(8 + i) for i in range(8)]
    wl = bipartite_pairs(rack0, rack1,
                         flows_per_pair=max(1, n_flows // 16))
    comp = compile_fabric(fab)
    seeds = np.arange(n_seeds)
    jx = monte_carlo_throughput(comp, wl, seeds, transport="roce-nack",
                                engine=ENGINE_JAX)
    assert jx.rates.shape[1] == n_seeds
    # numpy reference on a seed subsample keeps the differential check
    # affordable at acceptance scale
    sub = np.arange(min(n_seeds, 64))
    ref = monte_carlo_throughput(comp, wl, sub, transport="roce-nack")
    assert np.abs(ref.goodput - jx.goodput[:, :len(sub)]).max() < 1e-6
