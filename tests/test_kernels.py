"""Pallas kernel sweeps: shapes x dtypes against the pure-jnp oracles,
executed in interpret mode on CPU."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flowhash.ops import (
    bulk_hash, bulk_hash_seeded, link_loads_fim, simulate_paper_paths,
)
from repro.kernels.ssd.ops import ssd_scan
from repro.models.ssm import ssd_chunked

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("S", [128, 256])
@pytest.mark.parametrize("hd", [64, 128])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [
    pytest.param(jnp.float32, marks=pytest.mark.slow), jnp.bfloat16])
def test_flash_attention_sweep(S, hd, causal, dtype):
    q = jax.random.normal(KEY, (2, S, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, S, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (2, S, hd), dtype)
    out = flash_attention_fwd(q, k, v, causal=causal, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref.astype(jnp.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("block_q,block_k", [(64, 128), (128, 64)])
def test_flash_attention_block_shapes(block_q, block_k):
    S, hd = 256, 64
    q = jax.random.normal(KEY, (2, S, hd))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (2, S, hd))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (2, S, hd))
    out = flash_attention_fwd(q, k, v, causal=True, block_q=block_q,
                              block_k=block_k, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, ref, atol=2e-6, rtol=2e-6)


@pytest.mark.parametrize("S,H,hd,N,Q", [
    (64, 2, 16, 8, 16),
    (128, 4, 32, 16, 32),
    (96, 1, 64, 32, 32),   # S not a multiple of Q (pad path)... 96%32==0
    (80, 2, 16, 8, 32),    # pad path: 80 % 32 != 0
])
@pytest.mark.parametrize("dtype", [
    pytest.param(jnp.float32, marks=pytest.mark.slow), jnp.bfloat16])
def test_ssd_kernel_sweep(S, H, hd, N, Q, dtype):
    Bz = 2
    x = (jax.random.normal(KEY, (Bz, S, H, hd)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(KEY, 1), (Bz, S, H)))
    A = -jnp.exp(jnp.linspace(0.0, 1.0, H))
    Bm = (jax.random.normal(jax.random.fold_in(KEY, 2), (Bz, S, N)) * 0.3).astype(dtype)
    Cm = (jax.random.normal(jax.random.fold_in(KEY, 3), (Bz, S, N)) * 0.3).astype(dtype)
    y_k, s_k = ssd_scan(x, dt, A, Bm, Cm, chunk=Q, force_kernel=True,
                        interpret=True)
    y_o, s_o = ssd_chunked(x, dt, A, Bm, Cm, chunk=Q)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(y_k.astype(jnp.float32),
                               y_o.astype(jnp.float32), atol=tol, rtol=tol)
    np.testing.assert_allclose(s_k, s_o, atol=tol, rtol=tol)


def test_flowhash_kernel_equals_ref():
    fields = jax.random.randint(KEY, (5000, 5), 0, 2**31 - 1).astype(jnp.uint32)
    hk = bulk_hash(fields, 7, force_kernel=True, interpret=True)
    hr = bulk_hash(fields, 7)
    assert (hk == hr).all()


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_flowhash_deterministic_and_seed_sensitive(seed):
    fields = jnp.arange(50, dtype=jnp.uint32).reshape(10, 5)
    h1 = bulk_hash(fields, seed)
    h2 = bulk_hash(fields, seed)
    assert (h1 == h2).all()
    h3 = bulk_hash(fields, seed ^ 0xDEADBEEF)
    assert not bool((h1 == h3).all())


def test_flowhash_uniformity():
    """Hash choices over n links approach uniform as flows grow (the
    statistical core of the paper's ECMP analysis)."""
    rng = np.random.default_rng(0)
    fields = jnp.asarray(rng.integers(0, 2**31, (200_000, 5)), jnp.uint32)
    ch = simulate_paper_paths(fields)
    _, fim_large = link_loads_fim(ch["uplink"], 16)
    _, fim_small = link_loads_fim(ch["uplink"][:256], 16)
    assert fim_large < 2.0       # ~uniform at 200k flows
    assert fim_small > 5.0       # visibly imbalanced at paper scale


def test_flowhash_seeded_kernel_equals_ref():
    fields = jax.random.randint(KEY, (5000, 5), 0, 2**31 - 1).astype(jnp.uint32)
    seeds = jax.random.randint(jax.random.fold_in(KEY, 9), (5000,),
                               0, 2**31 - 1).astype(jnp.uint32)
    hk = bulk_hash_seeded(fields, seeds, force_kernel=True, interpret=True)
    hr = bulk_hash_seeded(fields, seeds)
    assert (hk == hr).all()
    # a broadcast seed row degenerates to the scalar-seed entry point:
    # the seed-as-init convention is ONE definition, not two
    full = jnp.full((5000,), 7, jnp.uint32)
    assert (bulk_hash_seeded(fields, full) == bulk_hash(fields, 7)).all()


def test_flowhash_choice_distribution_pinned():
    """Hard-coded pre-unification values of ``simulate_paper_paths`` /
    ``bulk_hash``: the one-murmur-definition refactor (seed-as-init,
    shared with the engines' hash grids) must never drift the
    paper-testbed choice statistics by a single flow."""
    rng = np.random.default_rng(42)
    fields = jnp.asarray(rng.integers(0, 2**31, (4096, 5)), jnp.uint32)
    ch = simulate_paper_paths(fields)
    want = {
        "src_port": ([1, 1, 0, 1, 1, 0, 1, 0], 1958, [2138, 1958]),
        "uplink": ([14, 12, 13, 9, 2, 8, 1, 8], 30992,
                   [245, 268, 264, 235, 244, 247, 276, 258]),
        "spine_link": ([0, 1, 0, 3, 1, 0, 1, 1], 6196,
                       [1028, 992, 1024, 1052]),
        "dst_port": ([1, 1, 1, 1, 0, 1, 0, 1], 2086, [2010, 2086]),
    }
    for stage, (first8, total, counts) in want.items():
        got = np.asarray(ch[stage])
        assert got[:8].tolist() == first8, stage
        assert int(got.sum()) == total, stage
        assert np.bincount(got)[: len(counts)].tolist() == counts, stage
    h = np.asarray(bulk_hash(fields, 12345), np.uint64)
    assert h[:4].tolist() == [1282828036, 453300701, 462728589, 1920719609]
    assert int(h.sum()) == 8712584361707
