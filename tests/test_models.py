"""Per-architecture smoke tests (reduced configs) + decode consistency +
numerics regressions."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, applicable_shapes
from repro.models import Model
from repro.models.common import apply_mrope, apply_rope

KEY = jax.random.PRNGKey(0)
B, S = 2, 64


def make_batch(cfg, *, labels=True, key=KEY):
    batch = {"tokens": (jnp.arange(B * S).reshape(B, S) * 7 % cfg.vocab)
             .astype(jnp.int32)}
    if cfg.family == "vlm":
        batch = {
            "embeds": jax.random.normal(key, (B, S, cfg.d_model), jnp.bfloat16) * 0.1,
            "mrope_positions": jnp.broadcast_to(jnp.arange(S), (3, B, S)).astype(jnp.int32),
        }
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.random.normal(
            key, (B, cfg.encdec.encoder_seq, cfg.d_model), jnp.bfloat16)
    if labels:
        batch["labels"] = jnp.ones((B, S), jnp.int32)
    return batch


# The heaviest reduced configs dominate suite wall time (jamba alone is
# ~60 s); they run under -m slow / the full suite, while the fast default
# keeps one dense smoke per variant plus the per-family decode tests below.
SLOW_SMOKE = {"jamba-1.5-large-398b", "deepseek-v2-lite-16b",
              "whisper-large-v3", "mamba2-1.3b", "qwen2-moe-a2.7b",
              "qwen2-vl-72b", "codeqwen1.5-7b", "glm4-9b", "qwen2-72b"}


@pytest.mark.parametrize("name", [
    pytest.param(n, marks=pytest.mark.slow) if n in SLOW_SMOKE else n
    for n in sorted(ARCHS)])
def test_arch_smoke_forward_and_train_step(name):
    """One forward + one grad step per assigned architecture (reduced)."""
    cfg = ARCHS[name].reduced()
    model = Model(cfg)
    params = model.init(KEY)
    batch = make_batch(cfg)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), name
    grads = jax.jit(jax.grad(lambda p: model.loss(p, batch)[0]))(params)
    gn = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert jnp.isfinite(gn) and gn > 0, name
    logits = jax.jit(lambda p, b: model.prefill(p, b))(params, make_batch(cfg, labels=False))
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), name


@pytest.mark.parametrize("name", [
    "granite-3-2b", "deepseek-v2-lite-16b", "mamba2-1.3b",
    pytest.param("jamba-1.5-large-398b", marks=pytest.mark.slow),
    "whisper-large-v3"])
def test_decode_matches_forward(name):
    """Token-by-token decode with cache == full forward logits (the cache
    correctness property, per cache family).

    Two semantic notes (documented, not bugs):
      * MoE capacity dropping depends on sequence length (GShard
        semantics), so consistency only holds with drop-free capacity —
        we raise capacity_factor to num_experts here.  Serving configs
        should do the same (DESIGN.md §Arch-applicability).
      * SSM conv/state caches store bf16; at reduced scale the gated
        RMSNorm amplifies rounding, so the check runs in f32.
    """
    cfg = ARCHS[name].reduced()
    cfg = dataclasses.replace(cfg, dtype="float32")
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe,
                                         capacity_factor=float(cfg.moe.num_experts)))
    model = Model(cfg)
    params = model.init(KEY)
    T = 8
    toks = (jnp.arange(B * T).reshape(B, T) * 11 % cfg.vocab).astype(jnp.int32)
    batch = {"tokens": toks}
    extra = {}
    if cfg.family == "encdec":
        enc = jax.random.normal(KEY, (B, cfg.encdec.encoder_seq, cfg.d_model),
                                jnp.bfloat16)
        batch["enc_embeds"] = enc
        # decode uses the precomputed memory
        from repro.models.lm import RematPolicy, _run_encoder
        extra["enc_memory"] = _run_encoder(params, cfg, enc,
                                           RematPolicy(enabled=False))
    full = model.prefill(params, batch).astype(jnp.float32)

    cache = model.init_cache(B, T)
    step = jax.jit(lambda p, c, b, i: model.decode_step(p, c, b, i))
    outs = []
    for i in range(T):
        logits, cache = step(params, cache, {"tokens": toks[:, i:i+1], **extra},
                             jnp.int32(i))
        outs.append(logits[:, 0].astype(jnp.float32))
    dec = jnp.stack(outs, axis=1)
    err = float(jnp.max(jnp.abs(dec - full)))
    assert err < 5e-3, err
    assert bool((jnp.argmax(dec, -1) == jnp.argmax(full, -1)).all())


def test_ssd_grads_finite_regression():
    """Masked-exp overflow regression: gradients through the SSD chunk
    decays must be finite even with large dt."""
    from repro.models.ssm import ssd_chunked
    key = KEY
    Bs, Ss, H, hd, N = 1, 32, 2, 8, 4
    x = jax.random.normal(key, (Bs, Ss, H, hd))
    dt = jax.nn.softplus(jax.random.normal(key, (Bs, Ss, H)) + 3.0)  # large dt
    A = -jnp.exp(jnp.linspace(0.0, 2.0, H))
    Bm = jax.random.normal(key, (Bs, Ss, N))
    Cm = jax.random.normal(key, (Bs, Ss, N))

    def f(x):
        y, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=8)
        return jnp.sum(y.astype(jnp.float32) ** 2)

    g = jax.grad(f)(x)
    assert bool(jnp.isfinite(g).all())


def test_mrope_reduces_to_rope_on_equal_streams():
    """When the temporal/height/width position streams coincide, M-RoPE
    must equal plain RoPE (text-token behaviour of Qwen2-VL)."""
    hd, H = 64, 2
    x = jax.random.normal(KEY, (B, S, H, hd))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    mpos = jnp.broadcast_to(jnp.arange(S), (3, B, S)).astype(jnp.int32)
    theta = 1e6
    a = apply_rope(x, pos, theta)
    b = apply_mrope(x, mpos, (8, 12, 12), theta)
    assert jnp.max(jnp.abs(a - b)) < 1e-5


def test_sliding_window_masks_decode():
    """With a window, decode logits must ignore tokens beyond the window."""
    cfg = dataclasses.replace(ARCHS["granite-3-2b"].reduced(), num_layers=2)
    model = Model(cfg)
    params = model.init(KEY)
    T = 12
    toks1 = (jnp.arange(B * T).reshape(B, T) % cfg.vocab).astype(jnp.int32)
    toks2 = toks1.at[:, 0].set((toks1[:, 0] + 17) % cfg.vocab)  # differ at pos 0

    def run(toks, win):
        cache = model.init_cache(B, T)
        step = jax.jit(lambda p, c, b, i: model.decode_step(p, c, b, i, window=win))
        for i in range(T):
            logits, cache = step(params, cache, {"tokens": toks[:, i:i+1]},
                                 jnp.int32(i))
        return logits

    # window=4: position 0 is out of range at the last step -> identical
    assert jnp.allclose(run(toks1, 4), run(toks2, 4), atol=1e-6)
    # full attention: it matters
    assert not jnp.allclose(run(toks1, 0), run(toks2, 0), atol=1e-6)


def test_applicable_shapes_covers_40_cells():
    cells = [(a.name, s.name) for a in ARCHS.values()
             for s in applicable_shapes(a)]
    assert len(cells) == 32  # 40 assigned minus 8 documented long_500k skips
    long_archs = {a for a, s in cells if s == "long_500k"}
    assert long_archs == {"jamba-1.5-large-398b", "mamba2-1.3b"}
