"""Placement: automated static routing + topology-aware collective rings."""

import pytest
from _propcheck import given, settings, strategies as st

from repro.core import (
    EcmpRouting, Forwarder, bipartite_pairs, build_paper_testbed,
    fim, nic_ip, ring_edge_stats, server_name,
    static_route_assignment, synthesize_flows, topology_aware_ring,
)
from repro.core.placement import enumerate_paths


@given(st.integers(1, 4).map(lambda k: k * 4))
@settings(max_examples=10, deadline=None)
def test_static_assignment_balances_divisible_workloads(fpp):
    """Any bipartite workload whose flow count divides the link count is
    balanced to FIM == 0 by the min-max assigner."""
    fab = build_paper_testbed()
    rack0 = [server_name(i) for i in range(8)]
    rack1 = [server_name(8 + i) for i in range(8)]
    wl = bipartite_pairs(rack0, rack1, flows_per_pair=fpp)
    flows = synthesize_flows(wl, nic_ip=nic_ip)
    _, paths = static_route_assignment(fab, flows)
    assert fim(paths, fab) == pytest.approx(0.0, abs=1e-9)


def test_static_beats_ecmp_on_many_seeds():
    fab = build_paper_testbed()
    rack0 = [server_name(i) for i in range(8)]
    rack1 = [server_name(8 + i) for i in range(8)]
    wl = bipartite_pairs(rack0, rack1, flows_per_pair=16)
    flows = synthesize_flows(wl, nic_ip=nic_ip)
    _, static_paths = static_route_assignment(fab, flows)
    static_fim = fim(static_paths, fab)
    from repro.core import FlowTracer
    for seed in range(5):
        e = FlowTracer(fab, EcmpRouting(fab, seed=seed), wl, flows).trace()
        assert fim(e.paths, fab) > static_fim + 10.0


def test_enumerate_paths_counts():
    """Cross-rack equal-cost paths: 2 (src LAG) x 16 (uplinks) x 4 (spine
    downlinks) x 2 (dst LAG) = 256."""
    fab = build_paper_testbed()
    wl = bipartite_pairs([server_name(0)], [server_name(8)], 1)
    flows = synthesize_flows(wl, nic_ip=nic_ip)
    fwd = Forwarder(fab)
    paths = enumerate_paths(fab, fwd, flows[0])
    assert len(paths) == 256
    assert all(p[0].src == flows[0].src and p[-1].dst == flows[0].dst
               for p in paths)


def test_hop_greedy_mode_runs():
    fab = build_paper_testbed()
    wl = bipartite_pairs([server_name(i) for i in range(8)],
                         [server_name(8 + i) for i in range(8)], 8)
    flows = synthesize_flows(wl, nic_ip=nic_ip)
    _, paths = static_route_assignment(fab, flows, mode="hop_greedy")
    assert len(paths) == len(flows)
    # hop-greedy balances uplinks but is destination-blind: aggregate FIM
    # can be nonzero (spine->leaf layer), but must still beat typical ECMP
    assert fim(paths, fab) <= 26.0


# ---------------------------------------------------------------------------
# topology-aware collective rings (beyond-paper)
# ---------------------------------------------------------------------------


@given(st.integers(2, 4), st.integers(2, 16))
@settings(max_examples=30, deadline=None)
def test_topology_aware_ring_minimizes_pod_crossings(pods, chips_per_pod):
    devices = list(range(pods * chips_per_pod))
    coords = {d: (d % pods, d // 2, d % 2) for d in devices}  # interleaved!
    before = ring_edge_stats(devices, coords)["inter_pod"]
    ring = topology_aware_ring(devices, coords)
    after = ring_edge_stats(ring, coords)["inter_pod"]
    assert after == pods              # theoretical minimum for a ring
    assert after <= before


def test_ring_stats_classes_sum():
    devices = list(range(16))
    coords = {d: (d // 8, d // 4, d % 4) for d in devices}
    st_ = ring_edge_stats(devices, coords)
    assert sum(st_.values()) == 16    # one edge per ring hop
