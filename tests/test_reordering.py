"""Reordering-cost subsystem tests.

Contract coverage: single-path strategies have exactly zero out-of-order
exposure (so their goodput is bit-identical to their max-min rates under
ANY transport); ``K=1`` spraying and ``min_bytes=inf`` demand-aware
spraying are bit-identical to ECMP end-to-end *including*
``effective_goodput``; the efficiency model is monotone (more skew or
more rate dispersion can never raise efficiency; the ideal profile is
exactly 1.0); and on the committed LLM scenario the acceptance-criterion
regime holds directionally — full spraying keeps its byte-FIM win but
pays a measurable goodput penalty under a reordering-intolerant
transport, and elephant-only spraying recovers most of it at near-spray
byte-FIM."""

import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core import (
    ELEPHANT_MIN_BYTES, IDEAL, ROCE_NACK, STRACK, PrimeSpraying,
    TransportProfile, available_transports, fim_vector, flowlet_exposure,
    monte_carlo_throughput, paper_testbed_llm_workload,
    reordering_efficiency, resolve_strategy, resolve_transport,
    simulate_paths, throughput_from_result,
)
from repro.core.vector_sim import VectorTraceResult


# ---------------------------------------------------------------------------
# transport profile registry
# ---------------------------------------------------------------------------


def test_transport_registry():
    assert {"ideal", "roce-nack", "strack"} <= set(available_transports())
    assert resolve_transport(None) is IDEAL
    assert resolve_transport("roce-nack") is ROCE_NACK
    assert resolve_transport(STRACK) is STRACK
    with pytest.raises(ValueError, match="unknown transport"):
        resolve_transport("tcp-reno")
    with pytest.raises(TypeError):
        resolve_transport(3.5)


def test_transport_profile_validation():
    with pytest.raises(ValueError, match="alpha"):
        TransportProfile("bad", alpha=-1.0, floor=0.5)
    with pytest.raises(ValueError, match="floor"):
        TransportProfile("bad", alpha=1.0, floor=0.0)
    with pytest.raises(ValueError, match="floor"):
        TransportProfile("bad", alpha=1.0, floor=1.5)


def test_unknown_transport_error_lists_registry():
    with pytest.raises(ValueError) as exc:
        resolve_transport("tcp-reno")
    msg = str(exc.value)
    assert str(available_transports()) in msg      # sorted listing


def test_duplicate_transport_registration_raises():
    from repro.core import register_transport
    probe = TransportProfile("dup-transport", alpha=1.0, floor=0.5)
    register_transport(probe)
    try:
        with pytest.raises(ValueError, match="already registered"):
            register_transport(
                TransportProfile("dup-transport", alpha=2.0, floor=0.5))
        # the published anchors are protected, and replace=True is explicit
        with pytest.raises(ValueError, match="'roce-nack'"):
            register_transport(
                TransportProfile("roce-nack", alpha=9.0, floor=0.5))
        register_transport(probe, replace=True)
    finally:
        from repro.core.reordering import _TRANSPORTS
        _TRANSPORTS.pop("dup-transport", None)


# ---------------------------------------------------------------------------
# transport calibration against published anchor curves
# ---------------------------------------------------------------------------


def test_calibrated_profiles_reproduce_anchors():
    """``roce-nack`` / ``strack`` are no longer stylized constants: the
    committed profiles must pass through their documented anchor points
    (STrack's goodput-vs-reordering curve; IRN's go-back-N collapse)
    within a tolerance commensurate with a 2-parameter model."""
    from repro.core import ROCE_NACK_ANCHORS, STRACK_ANCHORS
    for profile, anchors, tol in ((ROCE_NACK, ROCE_NACK_ANCHORS, 0.08),
                                  (STRACK, STRACK_ANCHORS, 0.02)):
        for x, y in anchors:
            eff = float(reordering_efficiency(np.array([x]), profile)[0])
            assert abs(eff - y) <= tol, (profile.name, x, eff, y)
    # the qualitative ordering the suite's directional tests rely on
    assert ROCE_NACK.floor < STRACK.floor
    assert ROCE_NACK.alpha > STRACK.alpha


def test_calibrate_transport_exact_recovery():
    """Anchors sampled from a model instance are recovered (alpha on the
    grid, floor in closed form) — the fit is deterministic and exact up
    to grid resolution."""
    from repro.core import calibrate_transport
    truth = TransportProfile("truth", alpha=2.0, floor=0.4)
    xs = (0.3, 0.7, 1.5, 3.0)
    anchors = [(x, float(reordering_efficiency(np.array([x]), truth)[0]))
               for x in xs]
    fit = calibrate_transport("refit", anchors)
    assert abs(fit.alpha - truth.alpha) / truth.alpha < 0.01
    assert abs(fit.floor - truth.floor) < 0.01
    # identical inputs -> identical constants (no RNG anywhere)
    again = calibrate_transport("refit", anchors)
    assert (fit.alpha, fit.floor) == (again.alpha, again.floor)


def test_calibrate_transport_validation():
    from repro.core import calibrate_transport
    with pytest.raises(ValueError, match=">= 2 anchor"):
        calibrate_transport("x", [(1.0, 0.5)])
    with pytest.raises(ValueError, match="exposure must be > 0"):
        calibrate_transport("x", [(0.0, 0.5), (1.0, 0.4)])
    with pytest.raises(ValueError, match="efficiency must be in"):
        calibrate_transport("x", [(0.5, 1.0), (1.0, 0.4)])


# ---------------------------------------------------------------------------
# efficiency model: bounds + monotonicity
# ---------------------------------------------------------------------------


@given(st.floats(0.0, 50.0), st.floats(0.0, 50.0))
@settings(max_examples=50, deadline=None)
def test_efficiency_monotone_in_exposure(a, b):
    """More exposure can never mean higher efficiency, for any profile."""
    lo, hi = sorted((a, b))
    for profile in (IDEAL, ROCE_NACK, STRACK,
                    TransportProfile("custom", alpha=1.7, floor=0.4)):
        e_lo, e_hi = reordering_efficiency(
            np.array([lo, hi]), profile)
        assert e_hi <= e_lo
        assert profile.floor <= e_hi <= e_lo <= 1.0


def test_ideal_profile_is_exactly_one():
    exposure = np.array([0.0, 0.3, 2.0, 50.0])
    np.testing.assert_array_equal(
        reordering_efficiency(exposure, "ideal"), 1.0)


def test_zero_exposure_is_exactly_one_for_all_profiles():
    """expm1(-0) == 0, so unexposed flows keep bitwise-identical goodput
    under every profile — the keystone of the K=1 == ECMP guarantee."""
    z = np.zeros(4)
    for name in available_transports():
        np.testing.assert_array_equal(reordering_efficiency(z, name), 1.0)


def test_efficiency_rejects_negative_exposure():
    with pytest.raises(ValueError, match="non-negative"):
        reordering_efficiency(np.array([-0.1]), "strack")


# ---------------------------------------------------------------------------
# exposure: zero for single-path, monotone in skew and dispersion
# ---------------------------------------------------------------------------


def test_single_path_strategies_zero_exposure(paper_compiled,
                                              paper_setup_small):
    _, _, flows = paper_setup_small
    for strategy in (None, "ecmp", "congestion-aware"):
        res = simulate_paths(paper_compiled, flows, [0, 5],
                             strategy=strategy)
        np.testing.assert_array_equal(flowlet_exposure(res), 0.0)


_SMALL = {}


def _small_compiled_and_flows():
    """Tiny compiled testbed + a one-flow table for synthetic tensors
    (module-cached; property tests can't take session fixtures)."""
    if not _SMALL:
        from repro.core import (
            bipartite_pairs, build_paper_testbed, compile_fabric, nic_ip,
            server_name, synthesize_flows,
        )
        comp = compile_fabric(build_paper_testbed(servers_per_rack=2))
        wl = bipartite_pairs([server_name(0)], [server_name(2)],
                             flows_per_pair=1)
        flows = synthesize_flows(wl, nic_ip=nic_ip, nics_per_server=2)
        _SMALL["comp"], _SMALL["flows"] = comp, flows
    return _SMALL["comp"], _SMALL["flows"]


def _synthetic_two_flowlets(hops_a, hops_b):
    """One flow, two flowlets of the given path lengths; link ids are
    arbitrary — exposure reads only the -1 structure when rates are
    supplied."""
    comp, flows = _small_compiled_and_flows()
    h = max(hops_a, hops_b, 1)
    ids = np.full((h, 2, 1), -1, np.int32)
    ids[:hops_a, 0, 0] = np.arange(hops_a)
    ids[:hops_b, 1, 0] = np.arange(hops_b)
    return VectorTraceResult(
        compiled=comp, flows=flows[:1], seeds=np.zeros(1, np.uint64),
        link_ids=ids, flow_index=np.zeros(2, np.int32),
        demand=np.full(2, 0.5), strategy="prime-spray")


@given(st.integers(1, 6), st.integers(0, 6), st.integers(0, 6))
@settings(max_examples=20, deadline=None)
def test_exposure_monotone_in_path_skew(base, da, db):
    """Longer relative path-length spread between a flow's flowlets must
    never lower exposure (equal-rate flowlets isolate the skew term)."""
    lo, hi = sorted((da, db))
    rates = np.full((2, 1), 10.0)
    x_lo = flowlet_exposure(_synthetic_two_flowlets(base, base + lo),
                            rates)[0, 0]
    x_hi = flowlet_exposure(_synthetic_two_flowlets(base, base + hi),
                            rates)[0, 0]
    assert x_hi >= x_lo
    eff_lo = reordering_efficiency(np.array([x_lo]), "roce-nack")[0]
    eff_hi = reordering_efficiency(np.array([x_hi]), "roce-nack")[0]
    assert eff_hi <= eff_lo
    if hi == 0:
        assert x_hi == 0.0


@given(st.floats(0.0, 1.0), st.floats(0.0, 1.0))
@settings(max_examples=25, deadline=None)
def test_exposure_monotone_in_rate_dispersion(a, b):
    """A slower slowest-flowlet (relative to the fastest) must never
    lower exposure (equal-hop flowlets isolate the dispersion term)."""
    res = _synthetic_two_flowlets(3, 3)
    lo, hi = sorted((a, b))
    x_lo = flowlet_exposure(
        res, np.array([[10.0], [10.0 * (1.0 - lo * 0.99)]]))[0, 0]
    x_hi = flowlet_exposure(
        res, np.array([[10.0], [10.0 * (1.0 - hi * 0.99)]]))[0, 0]
    assert x_hi >= x_lo


def test_exposure_ignores_infinite_rate_flowlets():
    res = _synthetic_two_flowlets(3, 3)
    # one link-free flowlet (inf rate): dispersion must not blow up
    x = flowlet_exposure(res, np.array([[10.0], [np.inf]]))[0, 0]
    assert np.isfinite(x)
    # all flowlets link-free: nothing disperses at all
    x2 = flowlet_exposure(res, np.array([[np.inf], [np.inf]]))[0, 0]
    assert x2 == 0.0


# ---------------------------------------------------------------------------
# end-to-end bit-identity: K=1 spray / min_bytes=inf == ECMP incl. goodput
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", [
    PrimeSpraying(flowlets=1),
    PrimeSpraying(flowlets=8, min_bytes=float("inf")),
    PrimeSpraying(flowlets=8, min_bytes=float("inf"), volume_k=True),
])
def test_degenerate_spray_bit_identical_to_ecmp(paper_compiled, paper_setup,
                                                strategy):
    _, _, flows = paper_setup
    seeds = [0, 42, 2**33]
    base = simulate_paths(paper_compiled, flows, seeds)
    res = simulate_paths(paper_compiled, flows, seeds, strategy=strategy)
    np.testing.assert_array_equal(res.link_ids, base.link_ids)
    assert not res.is_multipath
    assert (res.demand == 1.0).all()
    for transport in (None, "roce-nack", "strack"):
        tp_b = throughput_from_result(base, transport=transport)
        tp_r = throughput_from_result(res, transport=transport)
        np.testing.assert_array_equal(tp_r.rates, tp_b.rates)
        np.testing.assert_array_equal(tp_r.goodput, tp_b.goodput)
        np.testing.assert_array_equal(tp_r.goodput, tp_r.rates)
        np.testing.assert_array_equal(tp_r.efficiency, 1.0)
        np.testing.assert_array_equal(tp_r.exposure, 0.0)


def test_ideal_transport_goodput_is_rates_even_when_sprayed(paper_compiled,
                                                            paper_setup_small):
    _, _, flows = paper_setup_small
    res = simulate_paths(paper_compiled, flows, [0, 3],
                         strategy=PrimeSpraying(flowlets=4))
    tp = throughput_from_result(res)            # default: ideal
    assert tp.transport == "ideal"
    np.testing.assert_array_equal(tp.goodput, tp.rates)
    assert tp.goodput is not tp.rates           # never an alias
    np.testing.assert_array_equal(tp.efficiency, 1.0)
    # the exposure pass is skipped under a free transport (pre-reordering
    # cost for pre-reordering callers); a lossy profile reports it
    np.testing.assert_array_equal(tp.exposure, 0.0)
    lossy = throughput_from_result(res, transport="strack")
    assert (lossy.exposure > 0).any()
    np.testing.assert_array_equal(lossy.rates, tp.rates)


# ---------------------------------------------------------------------------
# demand-aware (elephant-only) spraying
# ---------------------------------------------------------------------------


def test_flowlet_counts_policies():
    from repro.core.flows import FiveTuple, Flow

    def f(b):
        return Flow(0, "a", "b",
                    FiveTuple("10.0.0.0", "10.1.0.0", 1, 2, 17), bytes=b)

    flows = [f(0), f(10), f(100), f(1000)]
    np.testing.assert_array_equal(
        PrimeSpraying(flowlets=8).flowlet_counts(flows), 8)
    np.testing.assert_array_equal(
        PrimeSpraying(flowlets=8, min_bytes=100).flowlet_counts(flows),
        [1, 1, 8, 8])
    np.testing.assert_array_equal(
        PrimeSpraying(flowlets=8, min_bytes=100,
                      volume_k=True).flowlet_counts(flows),
        [1, 1, 1, 8])
    # ceil semantics: anything over one min_bytes chunk splits
    np.testing.assert_array_equal(
        PrimeSpraying(flowlets=8, min_bytes=300,
                      volume_k=True).flowlet_counts(flows),
        [1, 1, 1, 4])
    np.testing.assert_array_equal(
        PrimeSpraying(flowlets=8, min_bytes=99,
                      volume_k=True).flowlet_counts(flows),
        [1, 1, 2, 8])
    np.testing.assert_array_equal(
        PrimeSpraying(flowlets=8,
                      min_bytes=float("inf")).flowlet_counts(flows), 1)


def test_prime_spray_param_validation():
    with pytest.raises(ValueError, match="min_bytes"):
        PrimeSpraying(flowlets=8, min_bytes=0)
    with pytest.raises(ValueError, match="volume_k"):
        PrimeSpraying(flowlets=8, volume_k=True)


def test_elephant_spray_warns_on_volume_less_workload(paper_compiled,
                                                      paper_setup_small):
    """A finite min_bytes against a workload that never set Flow.bytes
    sprays nothing — that silent ECMP degenerate must be called out."""
    _, _, flows = paper_setup_small        # bipartite flows: bytes == 0
    with pytest.warns(UserWarning, match="no flow\\s+sprays"):
        res = simulate_paths(paper_compiled, flows[:8], [0],
                             strategy="prime-spray-elephant")
    assert not res.is_multipath
    # min_bytes=inf is the *intentional* ECMP degenerate: no warning
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")
        simulate_paths(paper_compiled, flows[:8], [0],
                       strategy=PrimeSpraying(flowlets=8,
                                              min_bytes=float("inf")))


def test_elephant_registry_entry():
    s = resolve_strategy("prime-spray-elephant")
    assert isinstance(s, PrimeSpraying)
    assert s.min_bytes == ELEPHANT_MIN_BYTES
    assert s.volume_k


def test_mixed_spray_demand_fractions_and_layout(paper_compiled):
    wl, flows, _ = paper_testbed_llm_workload()
    strat = PrimeSpraying(flowlets=8, min_bytes=ELEPHANT_MIN_BYTES,
                          volume_k=True)
    res = simulate_paths(paper_compiled, flows, [3], strategy=strat)
    k_f = strat.flowlet_counts(flows)
    assert res.num_flowlets == int(k_f.sum())
    assert (k_f == 1).any() and (k_f > 1).any()   # genuinely mixed
    np.testing.assert_array_equal(
        res.flow_index, np.repeat(np.arange(len(flows)), k_f))
    per_flow = np.bincount(res.flow_index, weights=res.demand,
                           minlength=len(flows))
    np.testing.assert_allclose(per_flow, 1.0)


def test_mixed_spray_mice_keep_exact_ecmp_paths(paper_compiled):
    """Unsprayed flows of a demand-aware spray walk without entropy
    columns, so they stay bit-identical to ECMP flow by flow."""
    wl, flows, _ = paper_testbed_llm_workload()
    seeds = [0, 17]
    strat = PrimeSpraying(flowlets=8, min_bytes=ELEPHANT_MIN_BYTES)
    res = simulate_paths(paper_compiled, flows, seeds, strategy=strat)
    base = simulate_paths(paper_compiled, flows, seeds)
    k_f = strat.flowlet_counts(flows)
    mice = np.flatnonzero(k_f == 1)
    assert mice.size                               # scenario has mice
    cols = np.flatnonzero(np.isin(res.flow_index, mice))
    h = base.link_ids.shape[0]
    got = res.link_ids[:, cols]
    np.testing.assert_array_equal(got[:h], base.link_ids[:, mice])
    assert (got[h:] == -1).all()


# ---------------------------------------------------------------------------
# the acceptance-criterion regime, directionally, at test scale
# ---------------------------------------------------------------------------


def test_spray_tax_and_elephant_recovery(paper_compiled):
    """Full spraying keeps its byte-FIM win but pays a measurable goodput
    penalty under roce-nack; elephant-only spraying holds near-spray
    byte-FIM while recovering most of the penalty (its mice never leave
    their ECMP paths)."""
    wl, flows, _ = paper_testbed_llm_workload()
    seeds = np.arange(8)
    elephant = PrimeSpraying(flowlets=8, min_bytes=ELEPHANT_MIN_BYTES,
                             volume_k=True)
    byte_fim = {}
    tp = {}
    for tag, strat in (("ecmp", None), ("spray", PrimeSpraying(flowlets=8)),
                       ("elephant", elephant)):
        byte_fim[tag] = fim_vector(
            simulate_paths(paper_compiled, flows, seeds, strategy=strat,
                           demand_mode="bytes")).mean()
        tp[tag] = throughput_from_result(
            simulate_paths(paper_compiled, flows, seeds, strategy=strat),
            transport="roce-nack")
    g = {tag: t.goodput.mean() for tag, t in tp.items()}
    # ECMP pays nothing; spraying keeps its byte-FIM win...
    np.testing.assert_array_equal(tp["ecmp"].goodput, tp["ecmp"].rates)
    assert byte_fim["spray"] < byte_fim["ecmp"] - 10.0
    # ...but pays a measurable goodput tax (>10% of ECMP's goodput)
    assert g["spray"] < 0.9 * g["ecmp"]
    assert tp["spray"].rates.mean() > g["spray"]
    # elephant-only: near-spray byte-FIM (well below ECMP), most of the
    # goodput recovered
    assert byte_fim["elephant"] < byte_fim["ecmp"] - 10.0
    assert byte_fim["elephant"] < byte_fim["spray"] + 10.0
    assert g["elephant"] > g["spray"] + 0.3 * (g["ecmp"] - g["spray"])


def test_monte_carlo_front_end_threads_transport(paper_compiled):
    wl, flows, _ = paper_testbed_llm_workload()
    mc = monte_carlo_throughput(paper_compiled, flows, np.arange(4),
                                strategy="prime-spray-elephant",
                                transport="strack")
    assert mc.transport == "strack"
    assert mc.goodput.shape == mc.rates.shape == (len(flows), 4)
    assert (mc.goodput <= mc.rates + 1e-12).all()
    assert "flow_goodput" in mc.summary()
