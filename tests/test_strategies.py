"""Routing-strategy subsystem tests.

Contract coverage: the ECMP strategy must reproduce ``simulate_paths``
bit-identically (it IS the baseline every comparison is anchored to);
PRIME spraying must degenerate to ECMP at K=1 and carry demand
fractions that sum to 1 per flow; the weighted max-min fill must match
a scalar weighted progressive-filling reference; and the congestion-
aware strategy must emit topologically valid paths with lower imbalance
than hashed ECMP."""

import numpy as np
import pytest
from _propcheck import given, settings, strategies as st
from conftest import weighted_max_min_ref

from repro.core import (
    CongestionAware, EcmpStrategy, PrimeSpraying, RoutingStrategy,
    available_strategies, batched_max_min, fim_vector,
    flow_rates_from_flowlets, monte_carlo_fim, monte_carlo_throughput,
    register_strategy, resolve_strategy, simulate_paths,
    throughput_from_result,
)
from repro.core.strategies import _balanced_parts

LINE_RATE = 400.0


# ---------------------------------------------------------------------------
# ECMP strategy: bit-identical to the default walk
# ---------------------------------------------------------------------------


def test_ecmp_strategy_bit_identical(paper_compiled, paper_setup):
    _, _, flows = paper_setup
    seeds = [0, 7, 1234567, 2**40 + 17]
    base = simulate_paths(paper_compiled, flows, seeds)
    for strategy in ("ecmp", EcmpStrategy()):
        res = simulate_paths(paper_compiled, flows, seeds, strategy=strategy)
        np.testing.assert_array_equal(res.link_ids, base.link_ids)
        np.testing.assert_array_equal(res.flow_index, np.arange(len(flows)))
        assert (res.demand == 1.0).all()
        assert not res.is_multipath


def test_strategy_kwarg_threads_fields_and_backend(paper_compiled,
                                                   paper_setup):
    _, _, flows = paper_setup
    base = simulate_paths(paper_compiled, flows, [3, 9], fields="ip-pair")
    res = simulate_paths(paper_compiled, flows, [3, 9], fields="ip-pair",
                         strategy="ecmp")
    np.testing.assert_array_equal(res.link_ids, base.link_ids)


# ---------------------------------------------------------------------------
# PRIME spraying
# ---------------------------------------------------------------------------


def test_prime_k1_degenerates_to_ecmp(paper_compiled, paper_setup):
    _, _, flows = paper_setup
    seeds = [0, 42, 2**33]
    base = simulate_paths(paper_compiled, flows, seeds)
    res = simulate_paths(paper_compiled, flows, seeds,
                         strategy=PrimeSpraying(flowlets=1))
    np.testing.assert_array_equal(res.link_ids, base.link_ids)
    assert (res.demand == 1.0).all()
    assert not res.is_multipath


@given(st.integers(1, 9))
@settings(max_examples=9, deadline=None)
def test_prime_demand_fractions_sum_to_one(k):
    from repro.core import (
        bipartite_pairs, build_paper_testbed, compile_fabric, nic_ip,
        server_name, synthesize_flows,
    )
    fab = compile_fabric(build_paper_testbed(servers_per_rack=2))
    wl = bipartite_pairs([server_name(0), server_name(1)],
                         [server_name(2), server_name(3)], flows_per_pair=3)
    flows = synthesize_flows(wl, nic_ip=nic_ip, nics_per_server=2)
    res = simulate_paths(fab, flows, [5], strategy=PrimeSpraying(flowlets=k))
    assert res.num_flowlets == len(flows) * k
    np.testing.assert_allclose(res.demand, 1.0 / k)
    per_flow = np.bincount(res.flow_index, weights=res.demand,
                           minlength=len(flows))
    np.testing.assert_allclose(per_flow, 1.0)
    # flowlets of one flow are contiguous and parent-ordered
    np.testing.assert_array_equal(
        res.flow_index, np.repeat(np.arange(len(flows)), k))


def test_prime_flowlet_paths_topologically_valid(paper_compiled, paper_setup):
    _, _, flows = paper_setup
    res = simulate_paths(paper_compiled, flows[:16], [0, 11],
                         strategy=PrimeSpraying(flowlets=4))
    by_id = {f.flow_id: f for f in flows[:16]}
    for seed_index in range(2):
        flowlet_paths = res.flowlet_paths_for_seed(seed_index)
        assert set(flowlet_paths) == set(by_id)
        for fid, paths in flowlet_paths.items():
            assert len(paths) == 4
            for path in paths:
                assert path[0].src == by_id[fid].src
                assert path[-1].dst == by_id[fid].dst
                for a, b in zip(path, path[1:]):
                    assert a.dst == b.src


def test_prime_lower_fim_than_ecmp(paper_compiled, paper_setup):
    """The acceptance-criterion regime at test scale: multi-part entropy
    spraying spreads each flow over K paths, so the demand-weighted link
    loads even out and FIM drops well below per-flow ECMP."""
    _, _, flows = paper_setup
    seeds = np.arange(64)
    ecmp = fim_vector(simulate_paths(paper_compiled, flows, seeds))
    spray = fim_vector(simulate_paths(paper_compiled, flows, seeds,
                                      strategy=PrimeSpraying(flowlets=8)))
    assert spray.mean() < ecmp.mean() - 10.0
    assert (spray >= 0).all()


def test_prime_parts_validation():
    assert _balanced_parts(8) == (2, 4)
    assert _balanced_parts(7) == (7,)
    assert _balanced_parts(1) == (1,)
    assert PrimeSpraying(flowlets=6, parts=(2, 3)).parts == (2, 3)
    labels = PrimeSpraying(flowlets=8).entropy_labels()
    assert labels.shape == (8, 2)
    assert len({tuple(r) for r in labels.tolist()}) == 8  # distinct per flowlet
    with pytest.raises(ValueError):
        PrimeSpraying(flowlets=0)
    with pytest.raises(ValueError):
        PrimeSpraying(flowlets=8, parts=(3, 3))
    with pytest.raises(ValueError):
        PrimeSpraying(flowlets=4, parts=(4, 0))


def test_multipath_result_guards_paths_for_seed(paper_compiled, paper_setup):
    _, _, flows = paper_setup
    res = simulate_paths(paper_compiled, flows[:4], [0],
                         strategy=PrimeSpraying(flowlets=2))
    with pytest.raises(ValueError):
        res.paths_for_seed(0)


# ---------------------------------------------------------------------------
# congestion-aware selection
# ---------------------------------------------------------------------------


def test_congestion_aware_valid_paths(paper_compiled, paper_setup):
    _, _, flows = paper_setup
    res = simulate_paths(paper_compiled, flows, [0, 3],
                         strategy=CongestionAware())
    by_id = {f.flow_id: f for f in flows}
    for fid, path in res.paths_for_seed(0).items():
        assert path[0].src == by_id[fid].src
        assert path[-1].dst == by_id[fid].dst
        for a, b in zip(path, path[1:]):
            assert a.dst == b.src


def test_congestion_aware_lower_fim_than_ecmp(paper_compiled, paper_setup):
    _, _, flows = paper_setup
    seeds = np.arange(16)
    ecmp = fim_vector(simulate_paths(paper_compiled, flows, seeds))
    cong = fim_vector(simulate_paths(paper_compiled, flows, seeds,
                                     strategy="congestion-aware"))
    assert cong.mean() < ecmp.mean() - 10.0


def test_congestion_aware_throughput_sane(paper_compiled, paper_setup):
    _, _, flows = paper_setup
    res = simulate_paths(paper_compiled, flows, np.arange(4),
                         strategy=CongestionAware())
    tp = throughput_from_result(res)
    assert tp.rates.shape == (len(flows), 4)
    assert (tp.rates > 0).all()
    assert tp.per_pair.max() <= LINE_RATE + 1e-6
    # greedy balancing beats hashed ECMP on the worst pair
    base = throughput_from_result(simulate_paths(paper_compiled, flows,
                                                 np.arange(4)))
    assert tp.per_pair.min() >= base.per_pair.min()


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_resolution():
    assert {"ecmp", "prime-spray", "congestion-aware"} <= set(
        available_strategies())
    assert isinstance(resolve_strategy("prime-spray"), PrimeSpraying)
    inst = CongestionAware()
    assert resolve_strategy(inst) is inst
    with pytest.raises(ValueError, match="unknown routing strategy"):
        resolve_strategy("no-such-scheme")
    with pytest.raises(TypeError):
        resolve_strategy(42)


def test_register_custom_strategy():
    class Probe(RoutingStrategy):
        name = "probe"

    register_strategy("probe-test", Probe)
    try:
        assert isinstance(resolve_strategy("probe-test"), Probe)
    finally:
        from repro.core.strategies import _REGISTRY
        _REGISTRY.pop("probe-test", None)


def test_unknown_strategy_error_lists_registry():
    with pytest.raises(ValueError) as exc:
        resolve_strategy("no-such-scheme")
    msg = str(exc.value)
    for name in available_strategies():
        assert name in msg
    assert str(available_strategies()) in msg      # sorted listing


def test_duplicate_registration_raises():
    class Probe(RoutingStrategy):
        name = "probe"

    register_strategy("dup-test", Probe)
    try:
        with pytest.raises(ValueError, match="already registered"):
            register_strategy("dup-test", Probe)
        # the baseline is protected too — and the error lists the registry
        with pytest.raises(ValueError, match="'ecmp'.*registered"):
            register_strategy("ecmp", Probe)
        register_strategy("dup-test", Probe, replace=True)   # explicit wins
    finally:
        from repro.core.strategies import _REGISTRY
        _REGISTRY.pop("dup-test", None)


# ---------------------------------------------------------------------------
# adaptive re-spray
# ---------------------------------------------------------------------------


def test_adaptive_validation():
    from repro.core import AdaptiveSpraying
    with pytest.raises(ValueError, match="rounds"):
        AdaptiveSpraying(rounds=0)
    with pytest.raises(ValueError, match="ecn_factor"):
        AdaptiveSpraying(ecn_factor=0.0)
    with pytest.raises(ValueError, match="respray_cost"):
        AdaptiveSpraying(respray_cost=-0.1)
    with pytest.raises(ValueError, match="move_prob"):
        AdaptiveSpraying(move_prob=0.0)


def test_adaptive_rounds1_is_static_spray(paper_compiled, paper_setup_small):
    """``rounds=1`` is PrimeSpraying wholesale — same tensor, no extra
    exposure — and ``min_bytes=inf`` still degenerates to ECMP."""
    from repro.core import AdaptiveSpraying
    _, _, flows = paper_setup_small
    seeds = [0, 7, 1234567]
    static = simulate_paths(paper_compiled, flows, seeds,
                            strategy=PrimeSpraying(8))
    deg = simulate_paths(paper_compiled, flows, seeds,
                         strategy=AdaptiveSpraying(8, rounds=1))
    np.testing.assert_array_equal(static.link_ids, deg.link_ids)
    assert deg.extra_exposure is None
    ecmp = simulate_paths(paper_compiled, flows, seeds)
    off = simulate_paths(paper_compiled, flows, seeds,
                         strategy=AdaptiveSpraying(8, min_bytes=np.inf,
                                                   rounds=4))
    np.testing.assert_array_equal(ecmp.link_ids, off.link_ids)


def test_adaptive_beats_static_spray_goodput(paper_compiled, paper_setup):
    """The acceptance criterion: per-RTT re-spray under congestion
    feedback must beat static spraying's mean goodput under the
    reordering-intolerant roce-nack transport on the committed
    saturating scenario — the balance win has to outweigh the
    re-spray reordering tax it is charged."""
    from repro.core import AdaptiveSpraying
    _, _, flows = paper_setup
    seeds = np.arange(8)
    static = throughput_from_result(
        simulate_paths(paper_compiled, flows, seeds,
                       strategy=PrimeSpraying(8)),
        transport="roce-nack")
    adaptive = throughput_from_result(
        simulate_paths(paper_compiled, flows, seeds,
                       strategy=AdaptiveSpraying(8)),
        transport="roce-nack")
    assert adaptive.goodput.mean() > static.goodput.mean()
    # the adaptation really moved flowlets and really paid for it
    res = simulate_paths(paper_compiled, flows, seeds,
                         strategy=AdaptiveSpraying(8))
    assert res.extra_exposure is not None and res.extra_exposure.max() > 0


def test_adaptive_charges_respray_exposure(paper_compiled,
                                           paper_setup_small):
    """Each accepted move costs ``respray_cost`` x flowlet demand: the
    same routed tensor under a doubled cost parameter reports exactly
    doubled extra exposure, and goodput can only go down."""
    from repro.core import AdaptiveSpraying
    _, _, flows = paper_setup_small
    seeds = np.arange(4)
    cheap = simulate_paths(paper_compiled, flows, seeds,
                           strategy=AdaptiveSpraying(8, respray_cost=0.05))
    dear = simulate_paths(paper_compiled, flows, seeds,
                          strategy=AdaptiveSpraying(8, respray_cost=0.10))
    np.testing.assert_array_equal(cheap.link_ids, dear.link_ids)
    np.testing.assert_allclose(dear.extra_exposure,
                               2.0 * cheap.extra_exposure)
    g_cheap = throughput_from_result(cheap, transport="roce-nack")
    g_dear = throughput_from_result(dear, transport="roce-nack")
    assert g_dear.goodput.mean() <= g_cheap.goodput.mean()


# ---------------------------------------------------------------------------
# weighted max-min: differential vs a scalar weighted reference
# ---------------------------------------------------------------------------


@given(st.integers(2, 6), st.integers(2, 8), st.integers(0, 2**31))
@settings(max_examples=15, deadline=None)
def test_weighted_fill_matches_scalar_reference(n_links, n_flows, rngseed):
    rng = np.random.default_rng(rngseed)
    caps = rng.uniform(10.0, 1000.0, n_links)
    n_hops = min(3, n_links)
    ids = rng.integers(0, n_links, (n_hops, n_flows, 2)).astype(np.int32)
    ids[n_hops - 1, rng.integers(0, n_flows, 2), 0] = -1   # short paths
    # weights from exact and inexact binary fractions alike
    weights = rng.choice([0.125, 0.25, 1 / 3, 0.5, 1.0, 2.0], n_flows)
    rates = batched_max_min(ids, caps, weights=weights)
    for s in range(2):
        paths = {}
        for j in range(n_flows):
            hop_ids = [int(i) for i in ids[:, j, s] if i >= 0]
            paths[j] = list(dict.fromkeys(hop_ids))
        ref = weighted_max_min_ref(paths, list(caps),
                                   {j: weights[j] for j in range(n_flows)})
        for j in range(n_flows):
            if np.isinf(ref[j]):
                assert np.isinf(rates[j, s])
            else:
                assert rates[j, s] == pytest.approx(ref[j], rel=1e-9), (
                    f"flow {j} seed {s}")


@given(st.integers(2, 8), st.integers(0, 2**31))
@settings(max_examples=10, deadline=None)
def test_coincident_flowlets_share_like_parent(k, rngseed):
    """K flowlets of one flow on the *same* path with demand 1/K must
    aggregate to exactly the unweighted single-flow allocation."""
    rng = np.random.default_rng(rngseed)
    n_links, n_flows, n_hops = 5, 6, 3
    caps = rng.uniform(50.0, 500.0, n_links)
    ids = rng.integers(0, n_links, (n_hops, n_flows, 1)).astype(np.int32)
    base = batched_max_min(ids, caps)
    split = np.repeat(ids, k, axis=1)
    weights = np.full(n_flows * k, 1.0 / k)
    flowlet = batched_max_min(split, caps, weights=weights)
    parent = flowlet.reshape(n_flows, k).sum(axis=1)
    np.testing.assert_allclose(parent, base[:, 0], rtol=1e-9)


def test_weighted_fill_validation():
    ids = np.zeros((1, 2, 1), np.int32)
    caps = np.array([100.0])
    with pytest.raises(ValueError, match="weights"):
        batched_max_min(ids, caps, weights=np.ones(3))
    with pytest.raises(ValueError, match="positive"):
        batched_max_min(ids, caps, weights=np.array([1.0, 0.0]))
    # all-ones weights take the exact unweighted path
    np.testing.assert_array_equal(
        batched_max_min(ids, caps, weights=np.ones(2)),
        batched_max_min(ids, caps))


def test_weighted_zero_link_flowlet_inf():
    ids = np.array([[[0], [-1]]], np.int32)
    rates = batched_max_min(ids, np.array([100.0]),
                            weights=np.array([0.5, 0.5]))
    # alone on the link: weighted max-min still grants the full capacity
    assert rates[0, 0] == pytest.approx(100.0)
    assert np.isinf(rates[1, 0])


def test_weighted_contention_splits_proportionally():
    ids = np.zeros((1, 2, 1), np.int32)           # both flows on link 0
    rates = batched_max_min(ids, np.array([100.0]),
                            weights=np.array([0.25, 0.75]))
    assert rates[0, 0] == pytest.approx(25.0)
    assert rates[1, 0] == pytest.approx(75.0)


# ---------------------------------------------------------------------------
# flowlet -> parent aggregation + Monte-Carlo front ends
# ---------------------------------------------------------------------------


def test_flow_rates_from_flowlets_unsorted_fallback(paper_compiled,
                                                    paper_setup):
    _, _, flows = paper_setup
    res = simulate_paths(paper_compiled, flows[:8], [0, 1],
                         strategy=PrimeSpraying(flowlets=2))
    rates = np.arange(res.num_flowlets * 2, dtype=np.float64).reshape(
        res.num_flowlets, 2)
    sorted_sum = flow_rates_from_flowlets(res, rates)      # reduceat path
    perm = np.random.default_rng(0).permutation(res.num_flowlets)
    res.flow_index = res.flow_index[perm]
    got = flow_rates_from_flowlets(res, rates[perm])       # scatter path
    np.testing.assert_allclose(got, sorted_sum)


def test_throughput_from_result_multipath(paper_compiled, paper_setup):
    _, _, flows = paper_setup
    res = simulate_paths(paper_compiled, flows, np.arange(8),
                         strategy=PrimeSpraying(flowlets=4))
    tp = throughput_from_result(res)
    assert tp.rates.shape == (len(flows), 8)
    assert tp.per_pair.shape == (16, 8)
    assert (tp.rates > 0).all()
    assert tp.per_pair.max() <= LINE_RATE + 1e-6


def test_monte_carlo_front_ends_accept_strategy(paper_compiled, paper_setup):
    _, wl, _ = paper_setup
    mc = monte_carlo_fim(paper_compiled, wl, np.arange(8),
                         strategy="prime-spray")
    assert mc.aggregate.shape == (8,)
    assert (mc.aggregate >= 0).all()
    tp = monte_carlo_throughput(paper_compiled, wl, np.arange(4),
                                strategy="congestion-aware")
    assert tp.rates.shape == (256, 4)


def test_weighted_fim_counts_comparable_across_strategies(paper_compiled,
                                                          paper_setup):
    """Demand weighting keeps total per-layer load equal across
    strategies, so FIM differences are imbalance, not volume."""
    _, _, flows = paper_setup
    seeds = [0, 1]
    a = simulate_paths(paper_compiled, flows, seeds)
    b = simulate_paths(paper_compiled, flows, seeds,
                       strategy=PrimeSpraying(flowlets=8))
    ca, cb = a.link_flow_counts(), b.link_flow_counts()
    lid = paper_compiled.link_layer
    for layer in range(len(paper_compiled.layer_names)):
        sel = np.flatnonzero(lid == layer)
        np.testing.assert_allclose(ca[:, sel].sum(axis=1),
                                   cb[:, sel].sum(axis=1), rtol=1e-9)
