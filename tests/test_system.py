"""End-to-end reproduction of the paper's use case (Section IV-A).

256 RoCE flows in the bipartite pattern on the 2-rack testbed:
  * standard ECMP -> substantial imbalance (paper: FIM 36.5%) and a wide
    per-pair throughput spread;
  * preprogrammed static routing -> balanced (paper: 6.2%) at line rate.
"""

import pytest

from repro.core import (
    FlowTracer, StaticRouting, analyze_paths, fim, monte_carlo_throughput,
    per_pair_throughput, static_route_assignment,
)


@pytest.fixture(scope="module")
def static_assignment(paper_setup):
    fab, wl, flows = paper_setup
    return static_route_assignment(fab, flows)


def test_testbed_matches_paper_dimensions(paper_setup):
    fab, wl, flows = paper_setup
    # paper: 4 leaves x 4 spines x 4 links = 64 links per direction; 256
    # flows -> ideal 4 flows/link
    assert len(fab.links_by_layer("leaf-to-spine")) == 64
    assert len(fab.links_by_layer("spine-to-leaf")) == 64
    assert len(fab.links_by_layer("leaf-to-host")) == 64
    assert wl.total_flows == 256
    assert len(flows) == 256


def test_ecmp_shows_imbalance(paper_setup, paper_traced_seed7):
    fab, wl, flows = paper_setup
    res = paper_traced_seed7
    assert len(res.paths) == 256
    agg = fim(res.paths, fab)
    # hash-realization dependent; the paper measured 36.5%.  any healthy
    # random hash lands far from balanced at n=4 flows/link.
    assert 15.0 < agg < 60.0, agg


def test_static_routing_balances(paper_setup, static_assignment):
    fab, wl, flows = paper_setup
    table, paths = static_assignment
    assert fim(paths, fab) == pytest.approx(0.0, abs=1e-9)
    # the static table is consumable by the tracer and reproduces the plan
    res = FlowTracer(fab, StaticRouting(fab, table), wl, flows).trace()
    got = {k: [l.name for l in v] for k, v in res.paths.items()}
    want = {k: [l.name for l in v] for k, v in paths.items()}
    assert got == want


def test_imbalance_reduction_matches_paper_claim(paper_setup, paper_traced_seed7,
                                                 static_assignment):
    """Paper abstract: 'a 30% reduction in imbalance'."""
    fab, wl, flows = paper_setup
    ecmp_paths = paper_traced_seed7.paths
    _, static_paths = static_assignment
    reduction = fim(ecmp_paths, fab) - fim(static_paths, fab)
    assert reduction >= 15.0  # paper: 36.5 - 6.2 = 30.3


def test_throughput_spread(paper_setup, paper_traced_seed7, static_assignment):
    """ECMP-vs-static throughput via the vectorized Monte-Carlo engine,
    anchored to the tracer + scalar model at the reference seed."""
    fab, wl, flows = paper_setup
    _, static_paths = static_assignment
    mc = monte_carlo_throughput(fab, flows, [7, 11, 42])
    tp_s = sorted(per_pair_throughput(flows, static_paths).values())
    # static: every pair at line rate (400 Gb/s); ECMP: visibly degraded
    assert all(abs(t - 400.0) < 1e-6 for t in tp_s)
    assert mc.per_pair.shape == (16, 3)
    assert mc.per_pair.min() < 350.0
    assert mc.per_pair.max() <= 400.0 + 1e-6
    # seed 7 of the sweep == the hop-by-hop trace fed through the scalar
    # max-min model (the engine is a drop-in replacement for that loop)
    tp_e = per_pair_throughput(flows, paper_traced_seed7.paths)
    vec = mc.pair_throughput_for_seed(0)
    for pair, rate in tp_e.items():
        assert vec[pair] == pytest.approx(rate, rel=1e-9)


def test_report_summary(paper_setup, paper_traced_seed7):
    fab, wl, flows = paper_setup
    res = paper_traced_seed7
    rep = analyze_paths(res.paths, fab)
    assert rep.total_flows == 256
    assert set(rep.per_layer_fim) == {
        "host-to-leaf", "leaf-to-host", "leaf-to-spine", "spine-to-leaf"}
    assert "FIM" in rep.summary()
    assert rep.collisions, "ECMP must produce over-ideal links"
