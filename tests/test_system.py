"""End-to-end reproduction of the paper's use case (Section IV-A).

256 RoCE flows in the bipartite pattern on the 2-rack testbed:
  * standard ECMP -> substantial imbalance (paper: FIM 36.5%) and a wide
    per-pair throughput spread;
  * preprogrammed static routing -> balanced (paper: 6.2%) at line rate.
"""

import pytest

from repro.core import (
    EcmpRouting, FlowTracer, StaticRouting, analyze_paths, bipartite_pairs,
    build_paper_testbed, fim, nic_ip, per_pair_throughput, server_name,
    static_route_assignment, synthesize_flows,
)


@pytest.fixture(scope="module")
def testbed():
    fab = build_paper_testbed()
    rack0 = [server_name(i) for i in range(8)]
    rack1 = [server_name(8 + i) for i in range(8)]
    wl = bipartite_pairs(rack0, rack1, flows_per_pair=16)
    flows = synthesize_flows(wl, nic_ip=nic_ip, nics_per_server=2)
    return fab, wl, flows


def test_testbed_matches_paper_dimensions(testbed):
    fab, wl, flows = testbed
    # paper: 4 leaves x 4 spines x 4 links = 64 links per direction; 256
    # flows -> ideal 4 flows/link
    assert len(fab.links_by_layer("leaf-to-spine")) == 64
    assert len(fab.links_by_layer("spine-to-leaf")) == 64
    assert len(fab.links_by_layer("leaf-to-host")) == 64
    assert wl.total_flows == 256
    assert len(flows) == 256


def test_ecmp_shows_imbalance(testbed):
    fab, wl, flows = testbed
    res = FlowTracer(fab, EcmpRouting(fab, seed=7), wl, flows).trace()
    assert len(res.paths) == 256
    agg = fim(res.paths, fab)
    # hash-realization dependent; the paper measured 36.5%.  any healthy
    # random hash lands far from balanced at n=4 flows/link.
    assert 15.0 < agg < 60.0, agg


def test_static_routing_balances(testbed):
    fab, wl, flows = testbed
    table, paths = static_route_assignment(fab, flows)
    assert fim(paths, fab) == pytest.approx(0.0, abs=1e-9)
    # the static table is consumable by the tracer and reproduces the plan
    res = FlowTracer(fab, StaticRouting(fab, table), wl, flows).trace()
    got = {k: [l.name for l in v] for k, v in res.paths.items()}
    want = {k: [l.name for l in v] for k, v in paths.items()}
    assert got == want


def test_imbalance_reduction_matches_paper_claim(testbed):
    """Paper abstract: 'a 30% reduction in imbalance'."""
    fab, wl, flows = testbed
    ecmp_paths = FlowTracer(fab, EcmpRouting(fab, seed=7), wl, flows).trace().paths
    _, static_paths = static_route_assignment(fab, flows)
    reduction = fim(ecmp_paths, fab) - fim(static_paths, fab)
    assert reduction >= 15.0  # paper: 36.5 - 6.2 = 30.3


def test_throughput_spread(testbed):
    fab, wl, flows = testbed
    ecmp_paths = FlowTracer(fab, EcmpRouting(fab, seed=7), wl, flows).trace().paths
    _, static_paths = static_route_assignment(fab, flows)
    tp_e = sorted(per_pair_throughput(flows, ecmp_paths).values())
    tp_s = sorted(per_pair_throughput(flows, static_paths).values())
    # static: every pair at line rate (400 Gb/s); ECMP: visibly degraded
    assert all(abs(t - 400.0) < 1e-6 for t in tp_s)
    assert min(tp_e) < 350.0
    assert max(tp_e) <= 400.0 + 1e-6


def test_report_summary(testbed):
    fab, wl, flows = testbed
    res = FlowTracer(fab, EcmpRouting(fab, seed=7), wl, flows).trace()
    rep = analyze_paths(res.paths, fab)
    assert rep.total_flows == 256
    assert set(rep.per_layer_fim) == {
        "host-to-leaf", "leaf-to-host", "leaf-to-spine", "spine-to-leaf"}
    assert "FIM" in rep.summary()
    assert rep.collisions, "ECMP must produce over-ideal links"
