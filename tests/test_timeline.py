"""Time-expanded simulation tests (core/timeline.py).

Contract coverage: a one-step schedule containing every channel must
reproduce the merged-snapshot FIM/rates/goodput **bit-identically**
under every registered strategy (the degenerate anchor — same idiom as
``min_bytes=inf == ECMP``); the committed multipod two-elephant
scenario makes the flattening bug visible (merged byte-FIM strictly
exceeds the duration-weighted phased FIM on every seed, and the
fully-overlapped schedule matches merged exactly); and the schedule
emitters / partition plumbing validate their inputs instead of silently
dropping traffic."""

import numpy as np
import pytest

from repro.core import (
    CH_BARRIER, CH_FSDP_AG, CH_FSDP_RS, CH_GRAD_AR, CH_MOE_A2A, LlmJobSpec,
    SCHEDULE_DP_OVERLAP, SCHEDULE_SEQUENTIAL, TimelineStep,
    build_multipod_fabric, build_paper_testbed, channel_name, compile_fabric,
    flow_channel, known_channels, llm_collective_phases, merged_step,
    monte_carlo_fim, monte_carlo_throughput, multipod_llm_schedule,
    paper_testbed_llm_schedule, partition_flows, simulate_timeline,
)


@pytest.fixture(scope="module")
def testbed_llm_schedule(paper_compiled):
    """(compiled fabric, flows, sequential schedule) on the paper testbed."""
    _, flows, _, schedule = paper_testbed_llm_schedule()
    return paper_compiled, flows, schedule


# ---------------------------------------------------------------------------
# the degenerate anchor: one step == merged snapshot, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", [
    "ecmp", "prime-spray", "prime-spray-elephant", "adaptive-spray-elephant",
    "congestion-aware",
])
def test_one_step_schedule_is_merged_snapshot(testbed_llm_schedule, strategy):
    comp, flows, schedule = testbed_llm_schedule
    seeds = [0, 7, 1234567]
    one = [merged_step(schedule)]
    tl = simulate_timeline(comp, flows, one, seeds, demand_mode="bytes",
                           transport="roce-nack", strategy=strategy)
    mf = monte_carlo_fim(comp, flows, seeds, demand_mode="bytes",
                         strategy=strategy)
    mt = monte_carlo_throughput(comp, flows, seeds, demand_mode="bytes",
                                transport="roce-nack", strategy=strategy)
    assert tl.num_steps == 1 and tl.weights[0] == 1.0
    np.testing.assert_array_equal(tl.fim, mf.aggregate)
    for layer, series in mf.per_layer.items():
        np.testing.assert_array_equal(tl.steps[0].fim.per_layer[layer],
                                      series)
    step = tl.steps[0].throughput
    np.testing.assert_array_equal(step.rates, mt.rates)
    np.testing.assert_array_equal(step.goodput, mt.goodput)
    np.testing.assert_array_equal(tl.goodput, mt.goodput.mean(axis=0))
    np.testing.assert_array_equal(tl.rates, mt.rates.mean(axis=0))


# ---------------------------------------------------------------------------
# the bug made visible: disjoint elephants, merged FIM > phased FIM
# ---------------------------------------------------------------------------


def test_merged_overstates_disjoint_elephants():
    """Two elephant collectives in disjoint steps: the grad all-reduce
    seam elephants carry ~15x the MoE shuffle's bytes, so the merged
    byte-FIM is essentially the all-reduce's own (high, few hot seams)
    while the duration-weighted phased FIM averages in the much flatter
    MoE step — the merged snapshot strictly overstates the imbalance a
    phase-sampling observer ever sees."""
    comp = compile_fabric(build_multipod_fabric())
    _, flows, _, _ = multipod_llm_schedule(param_bytes=20_000_000_000)
    sub = [f for f in flows
           if flow_channel(f) in (CH_GRAD_AR, CH_MOE_A2A)]
    sched = [TimelineStep("grad-all-reduce", (CH_GRAD_AR,)),
             TimelineStep("moe-all-to-all", (CH_MOE_A2A,))]
    seeds = np.arange(16)
    phased = simulate_timeline(comp, sub, sched, seeds, demand_mode="bytes")
    merged = simulate_timeline(comp, sub, [merged_step(sched)], seeds,
                               demand_mode="bytes")
    assert phased.num_steps == 2
    assert (merged.fim > phased.fim).all()
    # and the gap is the elephant's FIM edge, not float noise
    assert merged.fim.mean() > phased.fim.mean() * 1.02

    # a fully-overlapped schedule (both collectives in one step) IS the
    # merged snapshot, bit for bit
    overlap = [TimelineStep("overlapped", (CH_GRAD_AR, CH_MOE_A2A))]
    tl = simulate_timeline(comp, sub, overlap, seeds, demand_mode="bytes")
    np.testing.assert_array_equal(tl.fim, merged.fim)
    np.testing.assert_array_equal(tl.goodput, merged.goodput)


def test_phased_series_and_weights(testbed_llm_schedule):
    comp, flows, schedule = testbed_llm_schedule
    seeds = np.arange(4)
    tl = simulate_timeline(comp, flows, schedule, seeds,
                           demand_mode="bytes")
    assert tl.num_steps == len(schedule)
    np.testing.assert_allclose(tl.weights.sum(), 1.0)
    # equal default durations
    np.testing.assert_allclose(tl.weights, 1.0 / tl.num_steps)
    # the time-weighted total is exactly the weighted mean of the series
    np.testing.assert_allclose(
        tl.fim, np.einsum("k,ks->s", tl.weights, tl.step_fim()))
    # every step routed only its own channels
    for sr in tl.steps:
        assert {flow_channel(f) for f in sr.flows} <= set(sr.step.channels)
    assert sum(len(sr.flows) for sr in tl.steps) == len(flows)
    summary = tl.summary()
    assert {"fim", "goodput", "rate"} <= set(summary)


# ---------------------------------------------------------------------------
# schedule emitters
# ---------------------------------------------------------------------------


def test_llm_collective_phases_modes():
    spec = LlmJobSpec(num_hosts=8)
    ops, seq = llm_collective_phases(spec, SCHEDULE_SEQUENTIAL)
    assert [s.name for s in seq] == [
        "fwd-all-gather", "moe-all-to-all", "bwd-reduce-scatter",
        "grad-all-reduce", "barrier"]
    _, overlap = llm_collective_phases(spec, SCHEDULE_DP_OVERLAP)
    assert [s.name for s in overlap] == ["forward", "backward", "barrier"]
    # both modes cover exactly the emitted channels
    chans = {op.channel_id for op in ops}
    for sched in (seq, overlap):
        assert {c for s in sched for c in s.channels} >= chans
    with pytest.raises(ValueError, match="unknown schedule mode"):
        llm_collective_phases(spec, "pipelined")


def test_moe_free_spec_drops_moe_step():
    spec = LlmJobSpec(num_hosts=8, moe_layers=0)
    ops, seq = llm_collective_phases(spec)
    assert "moe-all-to-all" not in [s.name for s in seq]
    assert CH_MOE_A2A not in {op.channel_id for op in ops}


# ---------------------------------------------------------------------------
# validation: no traffic is ever silently dropped
# ---------------------------------------------------------------------------


def test_timeline_step_validation():
    with pytest.raises(ValueError, match="no channels"):
        TimelineStep("empty", ())
    with pytest.raises(ValueError, match="duration"):
        TimelineStep("bad", (1,), duration=0.0)


def test_channel_vocabulary_fully_registered():
    # every schedule channel resolves through the registry by name —
    # a CH_* constant no schedule exercise would otherwise rot unseen
    expected = {CH_GRAD_AR: "CH_GRAD_AR", CH_FSDP_AG: "CH_FSDP_AG",
                CH_FSDP_RS: "CH_FSDP_RS", CH_MOE_A2A: "CH_MOE_A2A",
                CH_BARRIER: "CH_BARRIER"}
    assert len(expected) == 5          # distinct channel ids
    known = known_channels()
    for cid, name in expected.items():
        assert channel_name(cid) == f"{cid} ({name})"
        assert f"{cid} ({name})" in known


def test_partition_rejects_stray_and_unlabeled(paper_setup_small):
    _, flows, _, schedule = paper_testbed_llm_schedule()
    with pytest.raises(ValueError, match=r"channels \[1"):
        partition_flows(flows, [TimelineStep("only-barrier", (5,))])
    _, _, plain_flows = paper_setup_small       # bipartite: no #ch labels
    with pytest.raises(ValueError, match="no '#ch<N>' label"):
        partition_flows(plain_flows, schedule)


def test_empty_schedule_empty_flows_and_idle_steps(testbed_llm_schedule):
    comp, flows, schedule = testbed_llm_schedule
    with pytest.raises(ValueError, match="at least one step"):
        simulate_timeline(comp, flows, [], [0])
    # a step whose channels no flow carries raises — unknown ids and
    # legitimately-empty collectives alike — naming the registered CH_*
    # vocabulary instead of silently simulating an idle step
    padded = list(schedule) + [TimelineStep("idle", (99,), duration=5.0)]
    with pytest.raises(ValueError, match=r"99.*known channels"):
        simulate_timeline(comp, flows, padded, [0, 1])
    with pytest.raises(ValueError, match="CH_GRAD_AR"):
        partition_flows(flows, padded)
    with pytest.raises(ValueError, match="empty"):
        simulate_timeline(comp, [], schedule, [0])


# ---------------------------------------------------------------------------
# heavyweight sweep (excluded from the CI tier-1 run)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_timeline_strategy_sweep_slow():
    """Multi-step x multi-strategy sweep at benchmark scale: the phased
    totals stay finite, ordered, and reproducible across repeat runs."""
    comp = compile_fabric(build_paper_testbed())
    _, flows, _, schedule = paper_testbed_llm_schedule(
        SCHEDULE_DP_OVERLAP)
    seeds = np.arange(64)
    results = {}
    for strategy in ("ecmp", "prime-spray-elephant",
                     "adaptive-spray-elephant"):
        tl = simulate_timeline(comp, flows, schedule, seeds,
                               demand_mode="bytes", transport="roce-nack",
                               strategy=strategy)
        assert np.isfinite(tl.fim).all() and np.isfinite(tl.goodput).all()
        results[strategy] = tl
    again = simulate_timeline(comp, flows, schedule, seeds,
                              demand_mode="bytes", transport="roce-nack",
                              strategy="adaptive-spray-elephant")
    np.testing.assert_array_equal(
        results["adaptive-spray-elephant"].goodput, again.goodput)
    # spraying the elephants must cut the phased byte-FIM vs ECMP
    assert (results["prime-spray-elephant"].fim.mean()
            < results["ecmp"].fim.mean())
