"""Algorithm 1: parallel path discovery — correctness & invariants."""

import pytest
from _propcheck import given, settings, strategies as st

from repro.core import (
    ADHOC, PERSISTENT, EcmpRouting, FlowTracer, LatencyModel,
    WorkloadDescription, auto_processes,
)
from repro.core.fabric import SERVER


@pytest.fixture(scope="module")
def setup(paper_setup_small):
    return paper_setup_small


def _names(paths):
    return {k: [l.name for l in v] for k, v in paths.items()}


def test_paths_are_topologically_valid(setup):
    fab, wl, flows = setup
    res = FlowTracer(fab, EcmpRouting(fab, seed=3), wl, flows).trace()
    by_id = {f.flow_id: f for f in flows}
    for fid, path in res.paths.items():
        flow = by_id[fid]
        assert path[0].src == flow.src
        assert path[-1].dst == flow.dst
        assert fab.kind(path[-1].dst) == SERVER
        for a, b in zip(path, path[1:]):
            assert a.dst == b.src, "links must chain through the topology"
        # cross-rack: host->leaf->spine->leaf->host = 4 links
        assert len(path) == 4


@pytest.mark.parametrize("threads", [1, 2, 8])
def test_thread_count_does_not_change_paths(setup, threads):
    fab, wl, flows = setup
    base = FlowTracer(fab, EcmpRouting(fab, seed=3), wl, flows).trace()
    par = FlowTracer(fab, EcmpRouting(fab, seed=3), wl, flows,
                     num_threads=threads).trace()
    assert _names(base.paths) == _names(par.paths)


def test_process_parallelism_matches_serial(setup):
    fab, wl, flows = setup
    base = FlowTracer(fab, EcmpRouting(fab, seed=3), wl, flows).trace()
    par = FlowTracer(fab, EcmpRouting(fab, seed=3), wl, flows,
                     num_processes=2, num_threads=2).trace()
    assert _names(base.paths) == _names(par.paths)


def test_connection_accounting(setup):
    """Persistent SSH reuses channels; ad-hoc reconnects per query."""
    fab, wl, flows = setup
    adhoc = FlowTracer(fab, EcmpRouting(fab, seed=3), wl, flows,
                       connection_mode=ADHOC).trace()
    persist = FlowTracer(fab, EcmpRouting(fab, seed=3), wl, flows,
                         connection_mode=PERSISTENT).trace()
    assert adhoc.stats.queries == persist.stats.queries
    assert adhoc.stats.connects == adhoc.stats.queries
    assert persist.stats.connects < adhoc.stats.connects / 4


def test_persistent_faster_with_latency(setup):
    """Paper Fig. 5: connection setup dominates -> persistent wins."""
    fab, wl, flows = setup
    small = WorkloadDescription(pairs=wl.pairs[:2])
    lat = LatencyModel(connect_s=0.003, query_s=0.0)
    t_adhoc = FlowTracer(fab, EcmpRouting(fab, seed=3), small, flows,
                         connection_mode=ADHOC, latency=lat).trace().wall_time_s
    t_persist = FlowTracer(fab, EcmpRouting(fab, seed=3), small, flows,
                           connection_mode=PERSISTENT, latency=lat).trace().wall_time_s
    assert t_persist < t_adhoc


def test_workload_filter_limits_tracing(setup):
    fab, wl, flows = setup
    one_pair = WorkloadDescription(pairs=[wl.pairs[0]])
    res = FlowTracer(fab, EcmpRouting(fab, seed=3), one_pair, flows).trace()
    assert len(res.paths) == 8  # only that pair's flows


@given(st.integers(1, 40))
@settings(max_examples=20, deadline=None)
def test_auto_processes(n_pairs):
    p = auto_processes(n_pairs)
    assert 1 <= p <= min(8, n_pairs)
