"""Training substrate: optimization, grad accumulation, checkpoint/resume."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore, save
from repro.configs import ARCHS
from repro.data import SyntheticDataset
from repro.models import Model
from repro.train import AdamWConfig, TrainConfig, init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def small_model():
    cfg = ARCHS["granite-3-2b"].reduced()
    return Model(cfg), cfg


@pytest.mark.slow
def test_loss_decreases(small_model):
    model, cfg = small_model
    tc = TrainConfig(optimizer=AdamWConfig(lr=3e-3, warmup_steps=2,
                                           decay_steps=1000))
    params, opt = init_train_state(model, tc, KEY)
    step = jax.jit(make_train_step(model, tc))
    ds = SyntheticDataset(vocab=cfg.vocab, seq_len=64, global_batch=8, seed=0)
    losses = []
    for i in range(25):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(i).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses
    assert all(np.isfinite(losses))


@pytest.mark.slow
def test_grad_accum_matches_full_batch(small_model):
    """accum=4 over one batch == single step on the same batch (same total
    gradient, same update), modulo bf16 noise."""
    model, cfg = small_model
    opt_cfg = AdamWConfig(lr=1e-3, grad_clip=0.0, weight_decay=0.0)
    ds = SyntheticDataset(vocab=cfg.vocab, seq_len=32, global_batch=8, seed=1)
    batch = {k: jnp.asarray(v) for k, v in ds.batch(0).items()}

    outs = []
    for accum in (1, 4):
        tc = TrainConfig(optimizer=opt_cfg, grad_accum=accum)
        params, opt = init_train_state(model, tc, KEY)
        step = jax.jit(make_train_step(model, tc))
        p2, _, m = step(params, opt, batch)
        outs.append((p2, float(m["loss"])))
    (p1, l1), (p4, l4) = outs
    assert abs(l1 - l4) < 5e-2
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-3)


def test_checkpoint_resume_is_exact(small_model):
    """train 3 + save + train 3  ==  restore + train 3 (bitwise)."""
    model, cfg = small_model
    tc = TrainConfig(optimizer=AdamWConfig(lr=1e-3))
    ds = SyntheticDataset(vocab=cfg.vocab, seq_len=32, global_batch=4, seed=2)
    step = jax.jit(make_train_step(model, tc))

    def run(params, opt, start, n):
        for i in range(start, start + n):
            batch = {k: jnp.asarray(v) for k, v in ds.batch(i).items()}
            params, opt, _ = step(params, opt, batch)
        return params, opt

    params, opt = init_train_state(model, tc, KEY)
    params, opt = run(params, opt, 0, 3)
    with tempfile.TemporaryDirectory() as d:
        save(d, 3, {"params": params, "opt": opt})
        pa, oa = run(params, opt, 3, 3)

        restored, rstep = restore(d, {"params": params, "opt": opt})
        assert rstep == 3
        pb, ob = run(restored["params"], restored["opt"], 3, 3)
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_checkpoint_gc_and_latest():
    with tempfile.TemporaryDirectory() as d:
        tree = {"x": jnp.arange(4)}
        for s in (1, 2, 3, 4, 5):
            save(d, s, tree, keep_last=2)
        assert latest_step(d) == 5
        kept = sorted(p for p in os.listdir(d) if p.startswith("step_"))
        assert kept == ["step_00000004", "step_00000005"]


def test_checkpoint_bf16_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        tree = {"w": jnp.ones((3, 3), jnp.bfloat16) * 1.5,
                "m": jnp.zeros((2,), jnp.float32)}
        save(d, 1, tree)
        out, _ = restore(d, tree)
        assert out["w"].dtype == jnp.bfloat16
        assert (out["w"] == tree["w"]).all()


def test_lr_schedule_shape():
    from repro.train import schedule
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, decay_steps=100, min_lr_ratio=0.1)
    lrs = [float(schedule(cfg, jnp.int32(s))) for s in range(0, 120, 5)]
    assert lrs[0] == 0.0
    assert max(lrs) == pytest.approx(1.0, abs=0.01)
    assert lrs[-1] == pytest.approx(0.1, abs=0.01)
