"""Differential tests: the vectorized engine must reproduce the hop-by-hop
``FlowTracer`` + ``EcmpRouting`` **exactly** — same paths, same link
loads, same FIM — across fabric shapes, hash-field modes, and seeds.
This is the contract that makes Monte-Carlo results from ``vector_sim``
statements about the real (traced) routing behaviour."""

import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core import (
    FIELDS_5TUPLE, FIELDS_IP_PAIR, FIELDS_VXLAN, EcmpRouting, FlowTracer,
    bipartite_pairs, build_multipod_fabric, build_paper_testbed,
    compile_fabric, ecmp_hash, fim, fim_from_counts, fim_vector,
    flow_fields_matrix, flow_hash_fields, link_flow_counts, monte_carlo_fim,
    nic_ip, per_layer_fim, server_name, simulate_paths, synthesize_flows,
)
from repro.core.vector_sim import ecmp_hash_vec

MODES = [FIELDS_5TUPLE, FIELDS_VXLAN, FIELDS_IP_PAIR]


def _tracer_paths(fab, wl, flows, seed, mode):
    res = FlowTracer(fab, EcmpRouting(fab, seed=seed, fields=mode),
                     wl, flows).trace()
    return {k: [l.name for l in v] for k, v in res.paths.items()}


def _vector_paths(result, seed_index):
    return {k: [l.name for l in v]
            for k, v in result.paths_for_seed(seed_index).items()}


# ---------------------------------------------------------------------------
# hash primitives
# ---------------------------------------------------------------------------


@given(st.integers(0, 2**63 - 1), st.integers(0, 2**32 - 1),
       st.integers(0, 2**32 - 1))
@settings(max_examples=50, deadline=None)
def test_ecmp_hash_vec_matches_scalar(seed, f0, f1):
    fields = np.array([[f0, f1]], np.uint64)
    seeds = np.array([seed], np.uint64)
    got = int(ecmp_hash_vec(fields, seeds[None, :])[0, 0])
    assert got == ecmp_hash([f0, f1], seed)


def test_flow_fields_matrix_matches_scalar(paper_setup):
    _, _, flows = paper_setup
    for mode in MODES:
        mat = flow_fields_matrix(flows, mode)
        for j, f in enumerate(flows):
            assert mat[j].tolist() == flow_hash_fields(f, mode)


# ---------------------------------------------------------------------------
# path / load / FIM identity on the paper testbed
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", MODES)
def test_paths_identical_paper_testbed(paper_setup, paper_compiled, mode):
    fab, wl, flows = paper_setup
    seeds = [0, 7, 1234567, 2**40 + 17]
    res = simulate_paths(paper_compiled, flows, seeds, fields=mode)
    for i, seed in enumerate(seeds):
        assert _vector_paths(res, i) == _tracer_paths(fab, wl, flows, seed, mode)


def test_link_counts_and_fim_identical(paper_setup, paper_compiled):
    fab, wl, flows = paper_setup
    seeds = [3, 99]
    res = simulate_paths(paper_compiled, flows, seeds)
    counts = res.link_flow_counts()
    agg, per_layer = fim_from_counts(counts, paper_compiled)
    for i, seed in enumerate(seeds):
        tr = FlowTracer(fab, EcmpRouting(fab, seed=seed), wl, flows).trace()
        dict_counts = link_flow_counts(tr.paths)
        for lid, link in enumerate(paper_compiled.links):
            assert counts[i, lid] == dict_counts.get(link.name, 0)
        assert agg[i] == pytest.approx(fim(tr.paths, fab), rel=1e-12)
        for layer, (val, _n) in per_layer_fim(tr.paths, fab).items():
            assert per_layer[layer][i] == pytest.approx(val, rel=1e-12)


def test_only_used_leaves_identical(multipod_small):
    """Partial workloads leave idle leaves; the per-seed used-device
    restriction must match the dict implementation."""
    fab, wl, flows = multipod_small
    flows = flows[: len(flows) // 2]
    comp = compile_fabric(fab)
    seeds = [0, 11]
    res = simulate_paths(comp, flows, seeds)
    agg, per_layer = fim_from_counts(res.link_flow_counts(), comp,
                                     only_used_leaves=True)
    for i, seed in enumerate(seeds):
        wl_half = wl
        tr = FlowTracer(fab, EcmpRouting(fab, seed=seed), wl_half, flows).trace()
        assert agg[i] == pytest.approx(
            fim(tr.paths, fab, only_used_leaves=True), rel=1e-12)
        for layer, (val, _n) in per_layer_fim(
                tr.paths, fab, only_used_leaves=True).items():
            assert per_layer[layer][i] == pytest.approx(val, rel=1e-12)


# ---------------------------------------------------------------------------
# randomized fabric shapes (property test)
# ---------------------------------------------------------------------------


@given(st.integers(1, 3), st.integers(2, 6), st.integers(1, 3),
       st.integers(0, 2**31), st.sampled_from(MODES))
@settings(max_examples=8, deadline=None)
def test_random_shapes_identical(spines, links_per, flows_per_pair, seed, mode):
    fab = build_paper_testbed(num_spines=spines,
                              links_per_leaf_spine=links_per,
                              servers_per_rack=4)
    rack0 = [server_name(i) for i in range(4)]
    rack1 = [server_name(4 + i) for i in range(4)]
    wl = bipartite_pairs(rack0, rack1, flows_per_pair=flows_per_pair)
    flows = synthesize_flows(wl, nic_ip=nic_ip, nics_per_server=2)
    res = simulate_paths(fab, flows, [seed], fields=mode)
    assert _vector_paths(res, 0) == _tracer_paths(fab, wl, flows, seed, mode)


@given(st.integers(2, 3), st.integers(2, 4), st.integers(0, 2**31))
@settings(max_examples=5, deadline=None)
def test_multipod_shapes_identical(pods, leaves_per_pod, seed):
    fab = build_multipod_fabric(num_pods=pods, hosts_per_pod=4,
                                leaves_per_pod=leaves_per_pod, num_spines=4)
    pod0 = [f"host-{i}" for i in range(4)]
    pod1 = [f"host-{4 + i}" for i in range(4)]
    wl = bipartite_pairs(pod0, pod1, flows_per_pair=2)
    flows = synthesize_flows(wl, nic_ip=nic_ip, nics_per_server=1)
    res = simulate_paths(fab, flows, [seed])
    assert _vector_paths(res, 0) == _tracer_paths(fab, wl, flows, seed,
                                                  FIELDS_5TUPLE)


# ---------------------------------------------------------------------------
# monte_carlo front end + murmur backend
# ---------------------------------------------------------------------------


def test_monte_carlo_fim_from_workload(paper_compiled, paper_setup):
    _, wl, flows = paper_setup
    mc = monte_carlo_fim(paper_compiled, wl, np.arange(64))
    assert mc.aggregate.shape == (64,)
    assert set(mc.per_layer) == {"host-to-leaf", "leaf-to-spine",
                                 "spine-to-leaf", "leaf-to-host"}
    # the paper's regime: substantial expected imbalance, strictly positive
    assert 15.0 < mc.aggregate.mean() < 60.0
    assert (mc.aggregate >= 0).all()
    s = mc.summary()
    assert s["aggregate"]["min"] <= s["aggregate"]["p50"] <= s["aggregate"]["max"]
    # workload synthesis inside monte_carlo_fim == explicit flow list
    mc2 = monte_carlo_fim(paper_compiled, flows, np.arange(64))
    np.testing.assert_allclose(mc.aggregate, mc2.aggregate)


def test_murmur_backend_valid_and_statistically_similar(paper_compiled,
                                                        paper_setup):
    fab, wl, flows = paper_setup
    res = simulate_paths(paper_compiled, flows, np.arange(16),
                         hash_backend="murmur")
    # topologically valid chains ending at the right host
    paths = res.paths_for_seed(0)
    by_id = {f.flow_id: f for f in flows}
    for fid, path in paths.items():
        assert path[0].src == by_id[fid].src
        assert path[-1].dst == by_id[fid].dst
        for a, b in zip(path, path[1:]):
            assert a.dst == b.src
    # same imbalance regime as the exact hash (both uniform avalanches)
    exact = fim_vector(simulate_paths(paper_compiled, flows, np.arange(16)))
    murmur = fim_vector(res)
    assert abs(exact.mean() - murmur.mean()) < 12.0


def test_unknown_backend_raises(paper_compiled, paper_setup):
    _, _, flows = paper_setup
    with pytest.raises(ValueError):
        simulate_paths(paper_compiled, flows[:4], [0], hash_backend="xxh3")


def test_sparse_nic_numbering_resolves_flows():
    """A fabric whose servers expose non-contiguous NIC indices (here 0
    and 4, as on a half-populated host) must synthesize workload traffic
    on exactly the recorded NICs — inferring ``range(max + 1)`` would
    invent link-less NICs 1-3 and either crash the walk or route ghost
    traffic."""
    import dataclasses as _dc

    from repro.core import monte_carlo_fim, resolve_flows
    from repro.core.fabric import build_paper_testbed as _build

    fab = _build()
    links = [
        _dc.replace(ln,
                    src_port=ln.src_port.replace("nic1p", "nic4p"),
                    dst_port=ln.dst_port.replace("nic1p", "nic4p"))
        for ln in fab.links
    ]
    from repro.core.fabric import Fabric
    sparse = Fabric(list(fab.devices.values()), links)
    comp = compile_fabric(sparse)
    assert comp.nic_indices == (0, 4)

    rack0 = [server_name(i) for i in range(8)]
    rack1 = [server_name(8 + i) for i in range(8)]
    wl = bipartite_pairs(rack0, rack1, flows_per_pair=4)
    flows = resolve_flows(comp, wl)
    used = {int(f.tuple5.src_ip.split(".")[1]) for f in flows}
    assert used == {0, 4}
    mc = monte_carlo_fim(comp, wl, [0, 1, 2])
    assert mc.aggregate.shape == (3,)


# ---------------------------------------------------------------------------
# SimSpec: the unified front-end contract
# ---------------------------------------------------------------------------


def test_simspec_equals_legacy_kwargs(paper_compiled, paper_setup):
    from repro.core import SimSpec
    _, _, flows = paper_setup
    seeds = [0, 7, 1234567]
    legacy = simulate_paths(paper_compiled, flows, seeds,
                            fields=FIELDS_IP_PAIR, demand_mode="bytes")
    spec = simulate_paths(paper_compiled, flows, seeds,
                          spec=SimSpec(fields=FIELDS_IP_PAIR,
                                       demand_mode="bytes"))
    np.testing.assert_array_equal(spec.link_ids, legacy.link_ids)
    np.testing.assert_array_equal(spec.flow_demand, legacy.flow_demand)
    # passing explicit kwargs that merely repeat the defaults is the
    # legacy path too, bit for bit
    dflt = simulate_paths(paper_compiled, flows, seeds,
                          strategy=None, demand_mode="uniform",
                          engine="numpy")
    base = simulate_paths(paper_compiled, flows, seeds)
    np.testing.assert_array_equal(dflt.link_ids, base.link_ids)


def test_simspec_and_kwargs_together_raise(paper_compiled, paper_setup):
    from repro.core import SimSpec
    _, _, flows = paper_setup
    with pytest.raises(ValueError, match="not both.*demand_mode"):
        simulate_paths(paper_compiled, flows[:4], [0], spec=SimSpec(),
                       demand_mode="bytes")
    with pytest.raises(ValueError, match="not both"):
        monte_carlo_fim(paper_compiled, flows[:4], [0], spec=SimSpec(),
                        engine="numpy")
    with pytest.raises(TypeError, match="SimSpec"):
        simulate_paths(paper_compiled, flows[:4], [0], spec="jax")


def test_simspec_resolve_validates_and_is_idempotent():
    from repro.core import SimSpec, WaveCongestionAware
    from repro.core.reordering import TransportProfile
    s = SimSpec(strategy="wave-congestion-aware", transport="roce-nack",
                engine="jax").resolve()
    assert isinstance(s.strategy, WaveCongestionAware)
    assert isinstance(s.transport, TransportProfile)
    assert s.hash_backend is not None          # engine-coupled concrete
    s2 = s.resolve()
    assert s2.strategy is s.strategy and s2.transport is s.transport
    for bad in (SimSpec(engine="cuda"), SimSpec(demand_mode="packets"),
                SimSpec(fields="l4"), SimSpec(max_hops=0)):
        with pytest.raises(ValueError):
            bad.resolve()


def test_simspec_spans_all_front_ends(paper_compiled, paper_setup):
    from repro.core import (
        SimSpec, monte_carlo_throughput, paper_testbed_llm_schedule,
        simulate_timeline,
    )
    _, wl, flows = paper_setup
    seeds = [0, 3]
    s = SimSpec(strategy="prime-spray", transport="roce-nack")
    tp_legacy = monte_carlo_throughput(paper_compiled, flows, seeds,
                                       strategy="prime-spray",
                                       transport="roce-nack")
    tp_spec = monte_carlo_throughput(paper_compiled, flows, seeds, spec=s)
    np.testing.assert_array_equal(tp_spec.goodput, tp_legacy.goodput)
    # simulate_timeline resolves strategy names through the same spec —
    # the name form works uniformly across all four front ends
    _, lflows, _, sched = paper_testbed_llm_schedule()
    tl_legacy = simulate_timeline(paper_compiled, lflows, sched, seeds,
                                  strategy="prime-spray",
                                  transport="roce-nack")
    tl_spec = simulate_timeline(paper_compiled, lflows, sched, seeds, spec=s)
    np.testing.assert_array_equal(tl_spec.fim, tl_legacy.fim)
    np.testing.assert_array_equal(tl_spec.goodput, tl_legacy.goodput)


def test_max_hops_spans_all_front_ends(paper_compiled, paper_setup):
    # regression (flowcheck FT-API-MISSING / FT-API-FUSED): max_hops was
    # absent from the aggregate front ends' legacy-kwarg surface, and the
    # fused jax delegations silently rebuilt the default instead of
    # forwarding spec.max_hops
    from repro.core import (
        SimSpec, monte_carlo_throughput, paper_testbed_llm_schedule,
        simulate_timeline,
    )
    _, wl, flows = paper_setup
    seeds = [0, 1]
    # testbed paths take >1 hop: an insufficient budget must fail loudly
    with pytest.raises(RuntimeError, match="did not terminate"):
        monte_carlo_fim(paper_compiled, flows, seeds, max_hops=1)
    with pytest.raises(RuntimeError, match="did not terminate"):
        monte_carlo_throughput(paper_compiled, flows, seeds, max_hops=1)
    _, lflows, _, sched = paper_testbed_llm_schedule()
    with pytest.raises(RuntimeError, match="did not terminate"):
        simulate_timeline(paper_compiled, lflows, sched, seeds, max_hops=1)
    # the fused device pipelines must honor the budget too
    for front in (monte_carlo_fim, monte_carlo_throughput):
        with pytest.raises(RuntimeError, match="did not terminate"):
            front(paper_compiled, flows, seeds,
                  spec=SimSpec(engine="jax", max_hops=1))
