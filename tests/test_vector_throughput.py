"""Differential tests: the batched max-min engine must reproduce the
scalar ``max_min_throughput`` reference within 1e-9 relative tolerance —
across fabric shapes, workloads, seed sweeps, and the edge cases the
scalar code special-cases (zero-link flows, residual exhaustion,
duplicate links in a path)."""

import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core import (
    batched_max_min, bipartite_pairs, build_paper_testbed, compile_fabric,
    max_min_rates, max_min_throughput,
    monte_carlo_throughput, nic_ip, pair_rate_matrix, per_pair_throughput,
    server_name, simulate_paths, synthesize_flows, throughput_from_result,
)
from repro.core.fabric import Link


def _assert_rates_match(res, flows, rates, seed_indices=None):
    """Vector rates (N, S) == scalar reference per materialized seed."""
    idxs = seed_indices if seed_indices is not None else range(res.num_seeds)
    for i in idxs:
        scalar = max_min_throughput(res.paths_for_seed(i))
        for j, f in enumerate(flows):
            want = scalar[f.flow_id]
            got = rates[j, i]
            if np.isinf(want):
                assert np.isinf(got)
            else:
                assert got == pytest.approx(want, rel=1e-9), (
                    f"flow {f.flow_id} seed index {i}: {got} != {want}")


# ---------------------------------------------------------------------------
# differential identity on the paper testbed + multipod
# ---------------------------------------------------------------------------


def test_rates_match_scalar_paper_testbed(paper_setup, paper_compiled):
    _, _, flows = paper_setup
    res = simulate_paths(paper_compiled, flows, [0, 7, 1234567, 2**40 + 17])
    _assert_rates_match(res, flows, max_min_rates(res))


def test_rates_match_scalar_multipod(multipod_small):
    fab, _, flows = multipod_small
    res = simulate_paths(compile_fabric(fab), flows, [3, 99])
    _assert_rates_match(res, flows, max_min_rates(res))


@given(st.integers(1, 3), st.integers(2, 6), st.integers(1, 3),
       st.integers(0, 2**31))
@settings(max_examples=6, deadline=None)
def test_random_shapes_rates_match(spines, links_per, flows_per_pair, seed):
    fab = build_paper_testbed(num_spines=spines,
                              links_per_leaf_spine=links_per,
                              servers_per_rack=4)
    rack0 = [server_name(i) for i in range(4)]
    rack1 = [server_name(4 + i) for i in range(4)]
    wl = bipartite_pairs(rack0, rack1, flows_per_pair=flows_per_pair)
    flows = synthesize_flows(wl, nic_ip=nic_ip, nics_per_server=2)
    res = simulate_paths(fab, flows, [seed, seed + 1])
    _assert_rates_match(res, flows, max_min_rates(res))


@given(st.integers(0, 2**31), st.integers(1, 17))
@settings(max_examples=4, deadline=None)
def test_seed_block_invariance(seed, block):
    """Blocked cache tiling must never change the rates."""
    fab, flows = _paper_small()
    res = simulate_paths(fab, flows, [seed, seed + 5, seed + 11])
    a = batched_max_min(res.link_ids, res.compiled.link_gbps,
                        assume_unique=True, seed_block=block)
    b = batched_max_min(res.link_ids, res.compiled.link_gbps,
                        assume_unique=True, seed_block=10**9)
    np.testing.assert_array_equal(a, b)


def _paper_small():
    fab = compile_fabric(build_paper_testbed(servers_per_rack=4))
    rack0 = [server_name(i) for i in range(4)]
    rack1 = [server_name(4 + i) for i in range(4)]
    wl = bipartite_pairs(rack0, rack1, flows_per_pair=4)
    return fab, synthesize_flows(wl, nic_ip=nic_ip, nics_per_server=2)


# ---------------------------------------------------------------------------
# per-pair aggregation + Monte-Carlo front end
# ---------------------------------------------------------------------------


def test_per_pair_matches_scalar(paper_setup, paper_compiled):
    _, _, flows = paper_setup
    res = simulate_paths(paper_compiled, flows, [7, 42])
    tp = throughput_from_result(res)
    for i in range(2):
        scalar = per_pair_throughput(flows, res.paths_for_seed(i))
        vec = tp.pair_throughput_for_seed(i)
        assert set(vec) == set(scalar)
        for pair, rate in scalar.items():
            assert vec[pair] == pytest.approx(rate, rel=1e-9)


def test_monte_carlo_front_end(paper_compiled, paper_setup):
    _, wl, flows = paper_setup
    mc = monte_carlo_throughput(paper_compiled, wl, np.arange(32))
    assert mc.rates.shape == (256, 32)
    assert mc.per_pair.shape == (16, 32)
    assert mc.num_seeds == 32
    # physically sane: positive, never above line rate
    assert (mc.rates > 0).all()
    assert mc.per_pair.max() <= 400.0 + 1e-6
    s = mc.summary()
    assert set(s) == {"flow_rate", "flow_goodput", "pair_total",
                      "pair_min", "pair_median"}
    assert s["pair_min"]["min"] <= s["pair_median"]["p50"] <= 400.0 + 1e-6
    # workload synthesis inside the front end == explicit flow list
    mc2 = monte_carlo_throughput(paper_compiled, flows, np.arange(32))
    np.testing.assert_allclose(mc.rates, mc2.rates)


def test_pair_rate_matrix_orders_pairs_first_seen(paper_setup):
    _, _, flows = paper_setup
    rates = np.ones((len(flows), 2))
    pairs, per_pair = pair_rate_matrix(flows, rates)
    seen = []
    for f in flows:
        if (f.src, f.dst) not in seen:
            seen.append((f.src, f.dst))
    assert pairs == seen
    # 16 flows per pair, rate 1 each
    np.testing.assert_allclose(per_pair, 16.0)


# ---------------------------------------------------------------------------
# edge cases (satellite): synthetic link-id tensors vs hand-built paths
# ---------------------------------------------------------------------------


def _line_links(caps):
    return [Link("a", f"p{i}", "b", f"q{i}", c, "layer")
            for i, c in enumerate(caps)]


def test_zero_link_flow_infinite_rate():
    """A flow traversing no links hits the scalar code's residual-exhausted
    branch and must come out inf from both engines."""
    links = _line_links([100.0])
    paths = {0: [links[0]], 1: []}
    scalar = max_min_throughput(paths)
    assert scalar[0] == pytest.approx(100.0)
    assert scalar[1] == float("inf")
    ids = np.array([[[0], [-1]]], np.int32)          # (H=1, N=2, S=1)
    rates = batched_max_min(ids, np.array([100.0]))
    assert rates[0, 0] == pytest.approx(100.0)
    assert np.isinf(rates[1, 0])


def test_all_zero_link_flows():
    ids = np.full((2, 3, 2), -1, np.int32)
    rates = batched_max_min(ids, np.array([100.0]))
    assert np.isinf(rates).all()


@given(st.integers(1, 16))
@settings(max_examples=10, deadline=None)
def test_all_flows_share_one_link(n_flows):
    links = _line_links([100.0])
    paths = {i: [links[0]] for i in range(n_flows)}
    scalar = max_min_throughput(paths)
    ids = np.zeros((1, n_flows, 1), np.int32)
    rates = batched_max_min(ids, np.array([100.0]))
    for i in range(n_flows):
        assert rates[i, 0] == pytest.approx(scalar[i], rel=1e-12)
        assert rates[i, 0] == pytest.approx(100.0 / n_flows)


@given(st.lists(st.floats(1.0, 1000.0), min_size=2, max_size=6),
       st.integers(0, 2**31))
@settings(max_examples=20, deadline=None)
def test_heterogeneous_capacities(caps, rngseed):
    """Random multi-hop paths over links of different capacity."""
    links = _line_links(caps)
    rng = np.random.default_rng(rngseed)
    n_flows, n_hops = 8, min(3, len(caps))
    idmat = rng.integers(0, len(caps), (n_hops, n_flows, 1)).astype(np.int32)
    # a few flows get shorter paths
    idmat[n_hops - 1, rng.integers(0, n_flows, 2), 0] = -1
    paths = {}
    for j in range(n_flows):
        hop_ids = [int(i) for i in idmat[:, j, 0] if i >= 0]
        dedup = list(dict.fromkeys(hop_ids))        # scalar uses sets
        paths[j] = [links[i] for i in dedup]
    scalar = max_min_throughput(paths)
    rates = batched_max_min(idmat, np.array(caps))
    for j in range(n_flows):
        want, got = scalar[j], rates[j, 0]
        if np.isinf(want):
            assert np.isinf(got)
        else:
            assert got == pytest.approx(want, rel=1e-9)


def test_duplicate_link_in_path_counted_once():
    """The scalar engine keys on link-name sets; a flow listed twice on a
    link must not be double-counted or double-drained."""
    links = _line_links([100.0, 50.0])
    paths = {0: [links[0], links[1]], 1: [links[0]]}
    scalar = max_min_throughput(paths)
    # duplicate link 0 entry for flow 0 in the tensor form
    ids = np.array([[[0], [0]], [[1], [-1]], [[0], [-1]]], np.int32)
    rates = batched_max_min(ids, np.array([100.0, 50.0]))
    assert rates[0, 0] == pytest.approx(scalar[0], rel=1e-12)
    assert rates[1, 0] == pytest.approx(scalar[1], rel=1e-12)


def test_batched_max_min_rejects_bad_shape():
    with pytest.raises(ValueError):
        batched_max_min(np.zeros((2, 3), np.int32), np.array([1.0]))


# ---------------------------------------------------------------------------
# dedup_link_ids: sort-based rewrite vs the original pairwise scan
# ---------------------------------------------------------------------------


def _dedup_link_ids_reference(link_ids):
    """The pre-vectorization O(H^2) pairwise scan, kept as the oracle."""
    ids = np.array(link_ids, copy=True)
    for h in range(1, ids.shape[0]):
        dup = (ids[h] == ids[0]) & (ids[0] >= 0)
        for g in range(1, h):
            dup |= (ids[h] == ids[g]) & (ids[g] >= 0)
        ids[h][dup] = -1
    return ids


@given(st.integers(1, 6), st.integers(1, 8), st.integers(1, 4),
       st.integers(0, 2**31))
@settings(max_examples=25, deadline=None)
def test_dedup_matches_pairwise_reference(h, n, s, rngseed):
    from repro.core.vector_throughput import dedup_link_ids

    rng = np.random.default_rng(rngseed)
    # small id range forces plenty of within-path duplicates; -1 holes
    # (short paths) must never be collapsed
    ids = rng.integers(-1, 4, (h, n, s)).astype(np.int32)
    got = dedup_link_ids(ids)
    np.testing.assert_array_equal(got, _dedup_link_ids_reference(ids))
    # input untouched, first occurrence kept
    assert got is not ids


def test_dedup_keeps_earliest_hop():
    from repro.core.vector_throughput import dedup_link_ids

    ids = np.array([[[2]], [[2]], [[1]], [[2]]], np.int32)   # (H=4, 1, 1)
    np.testing.assert_array_equal(
        dedup_link_ids(ids)[:, 0, 0], [2, -1, 1, -1])
