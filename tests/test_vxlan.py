"""Paper Section II: VXLAN encapsulation & ECMP hash entropy.

The paper argues encapsulation limits the fields available to transit
switches and makes collisions more likely.  Quantified on the testbed:
an RFC-compliant VTEP (outer UDP sport = folded inner-header hash)
preserves nearly all entropy, while hashing on the outer IP pair alone
(broken/legacy VTEP) roughly doubles the imbalance.

Runs on the vectorized engine (bit-identical to the hop-by-hop tracer —
see test_vector_sim.py), so the seed sweep is 8 seeds instead of 4 at a
fraction of the cost.
"""

import numpy as np

from repro.core import (
    FIELDS_5TUPLE, FIELDS_IP_PAIR, FIELDS_VXLAN, monte_carlo_fim,
)


def _mean_fim(compiled, flows, mode, seeds=8):
    mc = monte_carlo_fim(compiled, flows, np.arange(seeds), fields=mode)
    return float(mc.aggregate.mean())


def test_vxlan_sport_preserves_entropy(paper_compiled, paper_setup):
    _, _, flows = paper_setup
    five = _mean_fim(paper_compiled, flows, FIELDS_5TUPLE)
    vxlan = _mean_fim(paper_compiled, flows, FIELDS_VXLAN)
    assert abs(five - vxlan) < 10.0, (five, vxlan)


def test_ip_pair_hashing_collapses_entropy(paper_compiled, paper_setup):
    """16 NIC-pair combinations per server pair -> far fewer distinct
    hash inputs -> much worse imbalance (paper Section II)."""
    _, _, flows = paper_setup
    five = _mean_fim(paper_compiled, flows, FIELDS_5TUPLE)
    ip_pair = _mean_fim(paper_compiled, flows, FIELDS_IP_PAIR)
    assert ip_pair > five * 1.5, (five, ip_pair)
