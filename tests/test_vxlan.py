"""Paper Section II: VXLAN encapsulation & ECMP hash entropy.

The paper argues encapsulation limits the fields available to transit
switches and makes collisions more likely.  Quantified on the testbed:
an RFC-compliant VTEP (outer UDP sport = folded inner-header hash)
preserves nearly all entropy, while hashing on the outer IP pair alone
(broken/legacy VTEP) roughly doubles the imbalance.
"""

import statistics

import pytest

from repro.core import (
    FIELDS_5TUPLE, FIELDS_IP_PAIR, FIELDS_VXLAN, EcmpRouting, FlowTracer,
    bipartite_pairs, build_paper_testbed, fim, nic_ip, server_name,
    synthesize_flows,
)


def _mean_fim(mode, seeds=4):
    fab = build_paper_testbed()
    rack0 = [server_name(i) for i in range(8)]
    rack1 = [server_name(8 + i) for i in range(8)]
    wl = bipartite_pairs(rack0, rack1, flows_per_pair=16)
    flows = synthesize_flows(wl, nic_ip=nic_ip)
    vals = []
    for seed in range(seeds):
        res = FlowTracer(fab, EcmpRouting(fab, seed=seed, fields=mode),
                         wl, flows, num_threads=8).trace()
        vals.append(fim(res.paths, fab))
    return statistics.mean(vals)


def test_vxlan_sport_preserves_entropy():
    five = _mean_fim(FIELDS_5TUPLE)
    vxlan = _mean_fim(FIELDS_VXLAN)
    assert abs(five - vxlan) < 10.0, (five, vxlan)


def test_ip_pair_hashing_collapses_entropy():
    """16 NIC-pair combinations per server pair -> far fewer distinct
    hash inputs -> much worse imbalance (paper Section II)."""
    five = _mean_fim(FIELDS_5TUPLE)
    ip_pair = _mean_fim(FIELDS_IP_PAIR)
    assert ip_pair > five * 1.5, (five, ip_pair)
