"""Wave-parallel congestion-aware placement: differential + regression.

The sequential ``CongestionAware`` greedy loop is the reference.  The
divergence contract under test (the class docstring's): wherever the
cutover delegates — below the ``min_wave_load`` depth, or
heterogeneous per-flow weights at any depth — the wave is
**bit-identical** to sequential greedy; on the wave path itself
(homogeneous weights above the cutover) it converges to a different
member of the same local-optimum family whose demand-weighted FIM is
no worse than sequential's.  Both engines must agree bit-for-bit on
the wave path itself, and the symmetric-conflict repair dynamics must
converge (no livelock) under the documented tie-break.
"""

import dataclasses
import os

import numpy as np
import pytest

from repro.core import (
    CongestionAware, LEAF_TO_SPINE, WaveCongestionAware, bipartite_pairs,
    build_paper_testbed, compile_fabric, fim_vector, nic_ip, server_name,
    simulate_paths, synthesize_flows,
)
from repro.core.vector_sim import ENGINE_JAX, ENGINE_NUMPY

SEEDS = [0, 7, 1234567, 2**40 + 17]


def _flows(fab_kw=None, flows_per_pair=4, servers=16, hetero=False,
           rngseed=0):
    half = servers // 2
    rack0 = [server_name(i) for i in range(half)]
    rack1 = [server_name(half + i) for i in range(half)]
    wl = bipartite_pairs(rack0, rack1, flows_per_pair=flows_per_pair)
    flows = synthesize_flows(wl, nic_ip=nic_ip, nics_per_server=2)
    if hetero:
        rng = np.random.default_rng(rngseed)
        sizes = rng.choice([1 << 20, 64 << 20, 1 << 30], len(flows))
        flows = [dataclasses.replace(f, bytes=int(b))
                 for f, b in zip(flows, sizes)]
    return flows


@pytest.fixture(scope="module")
def paper_comp():
    return compile_fabric(build_paper_testbed())


# ---------------------------------------------------------------------------
# below the cutover: delegation, bit-identical to sequential greedy
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("demand_mode", ["uniform", "bytes"])
@pytest.mark.parametrize("engine", [ENGINE_NUMPY, ENGINE_JAX])
def test_wave_below_cutover_bit_identical(paper_comp, demand_mode, engine):
    flows = _flows(flows_per_pair=16, hetero=True)      # 256 < 7 * 256 links
    seq = simulate_paths(paper_comp, flows, SEEDS,
                         strategy=CongestionAware(),
                         demand_mode=demand_mode, engine=engine)
    wav = simulate_paths(paper_comp, flows, SEEDS,
                         strategy=WaveCongestionAware(),
                         demand_mode=demand_mode, engine=engine)
    np.testing.assert_array_equal(wav.link_ids, seq.link_ids)
    # the delegated result still reports the wave strategy's name
    assert wav.strategy == "wave-congestion-aware"


@pytest.mark.parametrize("shape", [
    dict(num_spines=2, links_per_leaf_spine=2),
    dict(num_spines=4, links_per_leaf_spine=2),
    dict(servers_per_rack=4, num_spines=3, links_per_leaf_spine=3),
])
@pytest.mark.parametrize("demand_mode", ["uniform", "bytes"])
def test_wave_randomized_fabrics_match_sequential(shape, demand_mode):
    """Randomized fabric shapes, both demand modes, both engines: small
    waves delegate, so the match with sequential greedy is exact."""
    fab = build_paper_testbed(**shape)
    comp = compile_fabric(fab)
    servers = 2 * shape.get("servers_per_rack", 8)
    flows = _flows(flows_per_pair=2, servers=servers, hetero=True,
                   rngseed=sum(shape.values()))
    seq = simulate_paths(comp, flows, SEEDS, strategy=CongestionAware(),
                         demand_mode=demand_mode)
    for engine in (ENGINE_NUMPY, ENGINE_JAX):
        wav = simulate_paths(comp, flows, SEEDS,
                             strategy=WaveCongestionAware(),
                             demand_mode=demand_mode, engine=engine)
        np.testing.assert_array_equal(wav.link_ids, seq.link_ids)


# ---------------------------------------------------------------------------
# above the cutover: documented divergence, FIM no worse than sequential
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("demand_mode", ["uniform", "bytes"])
def test_wave_above_cutover_fim_no_worse(paper_comp, demand_mode):
    """Homogeneous-weight waves above the depth cutover take the wave
    path (``demand_mode="bytes"`` on equal volumes normalizes to the
    same unit weights) and must land at or below sequential greedy's
    imbalance; the jax wave must be the numpy wave bit for bit."""
    flows = _flows(flows_per_pair=120)
    assert len(flows) / paper_comp.num_links >= 7.0   # wave path engaged
    seeds = np.arange(4)
    seq = simulate_paths(paper_comp, flows, seeds,
                         strategy=CongestionAware(),
                         demand_mode=demand_mode)
    wav = simulate_paths(paper_comp, flows, seeds,
                         strategy=WaveCongestionAware(),
                         demand_mode=demand_mode)
    assert fim_vector(wav).mean() <= fim_vector(seq).mean() + 1e-9
    # the jax wave is the same wave, bit for bit
    jx = simulate_paths(paper_comp, flows, seeds,
                        strategy=WaveCongestionAware(),
                        demand_mode=demand_mode, engine=ENGINE_JAX)
    np.testing.assert_array_equal(jx.link_ids, wav.link_ids)


@pytest.mark.parametrize("engine", [ENGINE_NUMPY, ENGINE_JAX])
def test_wave_hetero_demand_delegates_exactly(paper_comp, engine):
    """Genuinely unequal per-flow volumes delegate to the sequential
    chain even above the depth cutover (quantized repair cannot
    reproduce its heaviest-first ordering advantage — the documented
    interchangeability cutover), so byte-weighted placements stay
    bit-identical to ``CongestionAware`` at every scale."""
    flows = _flows(flows_per_pair=120, hetero=True)
    assert len(flows) / paper_comp.num_links >= 7.0
    seeds = np.arange(4)
    seq = simulate_paths(paper_comp, flows, seeds,
                         strategy=CongestionAware(),
                         demand_mode="bytes", engine=engine)
    wav = simulate_paths(paper_comp, flows, seeds,
                         strategy=WaveCongestionAware(),
                         demand_mode="bytes", engine=engine)
    np.testing.assert_array_equal(wav.link_ids, seq.link_ids)


# ---------------------------------------------------------------------------
# symmetric-conflict convergence (the atomic-commit regression)
# ---------------------------------------------------------------------------


def _spine_loads(comp, res, seed_idx):
    ids = res.link_ids[:, :, seed_idx]
    sel = ids[(ids >= 0)]
    counts = np.bincount(sel, minlength=comp.num_links)
    lid = comp.layer_names.index(LEAF_TO_SPINE)
    return counts[comp.link_layer == lid]


def test_wave_two_flow_symmetric_conflict_converges(paper_comp):
    """Two flows between distinct server pairs, forced onto the wave
    path: whenever hashed ECMP collides them onto one leaf->spine link
    the repair must separate them — and never flip-flop, because under
    the accept rule "equally good elsewhere" is not a move.  The
    sequential round-cap fallback makes separation deterministic even
    if the damped repair itself dawdles."""
    flows = _flows(flows_per_pair=1, servers=2)        # 2 flows, one pair
    assert len(flows) == 2
    strategy = WaveCongestionAware(tolerance=1.0, min_wave_load=0.0)
    seeds = list(range(64))
    res = simulate_paths(paper_comp, flows, seeds, strategy=strategy)
    ecmp = simulate_paths(paper_comp, flows, seeds)
    for k in range(len(seeds)):
        assert _spine_loads(paper_comp, res, k).max() <= 1, (
            f"seed {seeds[k]}: symmetric conflict did not separate")
        # where ECMP already balanced the pair there was no conflict to
        # repair, so the wave placement IS the ECMP placement
        if _spine_loads(paper_comp, ecmp, k).max() <= 1:
            np.testing.assert_array_equal(res.link_ids[:, :, k],
                                          ecmp.link_ids[:, :, k])


def test_wave_round_cap_residue_falls_back_sequential(paper_comp):
    """A 1-round cap leaves conflicted residue on a dense wave; the
    fallback must place it sequentially — valid paths, every flow
    present, and imbalance still clearly below hashed ECMP."""
    flows = _flows(flows_per_pair=16)
    strategy = WaveCongestionAware(max_rounds=1, min_wave_load=0.0)
    seeds = np.arange(4)
    res = simulate_paths(paper_comp, flows, seeds, strategy=strategy)
    by_id = {f.flow_id: f for f in flows}
    paths = res.paths_for_seed(0)
    assert set(paths) == set(by_id)
    for fid, path in paths.items():
        assert path[0].src == by_id[fid].src
        assert path[-1].dst == by_id[fid].dst
        for a, b in zip(path, path[1:]):
            assert a.dst == b.src
    ecmp = fim_vector(simulate_paths(paper_comp, flows, seeds))
    assert fim_vector(res).mean() < ecmp.mean() - 10.0


def test_wave_validation():
    with pytest.raises(ValueError, match="max_rounds"):
        WaveCongestionAware(max_rounds=0)
    with pytest.raises(ValueError, match="quantum"):
        WaveCongestionAware(quantum=0.0)
    with pytest.raises(ValueError, match="move_prob"):
        WaveCongestionAware(move_prob=0.0)
    with pytest.raises(ValueError, match="tolerance"):
        WaveCongestionAware(tolerance=0.5)
    with pytest.raises(ValueError, match="min_wave_load"):
        WaveCongestionAware(min_wave_load=-1.0)


# ---------------------------------------------------------------------------
# large-scale sweep (slow; env-scalable like the jax-engine sweep)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_wave_flow_sweep_no_worse_than_sequential(paper_comp):
    n_flows = int(os.environ.get("FLOWTRACER_SWEEP_FLOWS", 2560))
    flows = _flows(flows_per_pair=max(1, n_flows // 16))
    seeds = np.arange(8)
    seq = simulate_paths(paper_comp, flows, seeds,
                         strategy=CongestionAware())
    for engine in (ENGINE_NUMPY, ENGINE_JAX):
        wav = simulate_paths(paper_comp, flows, seeds,
                             strategy=WaveCongestionAware(), engine=engine)
        assert fim_vector(wav).mean() <= fim_vector(seq).mean() + 1e-9
